//! Cross-file tests for curlint v2: call-graph reachability
//! (hot-path-purity), typed-error boundaries, dead-pub liveness, and
//! how the v1 baseline ratchet interacts with v2 rule names. Each
//! fixture is a tiny multi-file crate fed through [`ItemGraph::build`].

use xtask::baseline::{self, Counts, Verdict};
use xtask::callgraph::CallGraph;
use xtask::itemgraph::{ItemGraph, Vis};
use xtask::rules::check_repo;

fn graph(files: &[(&str, &str)]) -> ItemGraph {
    let owned: Vec<(String, String)> =
        files.iter().map(|&(p, s)| (p.to_string(), s.to_string())).collect();
    ItemGraph::build(&owned)
}

fn rules_in(
    g: &ItemGraph,
    path: &str,
) -> Vec<(String, usize)> {
    check_repo(g, &[])
        .remove(path)
        .unwrap_or_default()
        .into_iter()
        .map(|v| (v.rule.to_string(), v.line))
        .collect()
}

// ------------------------------------------------- hot-path reachability

#[test]
fn diamond_reachability_reaches_the_join() {
    // entry → {left, right} → join: the join must be hot exactly once,
    // through whichever parent the BFS saw first.
    let g = graph(&[(
        "rust/src/serve/mod.rs",
        "// curlint: hot-entry\n\
         fn entry() { left(); right(); }\n\
         fn left() { join(); }\n\
         fn right() { join(); }\n\
         fn join(n: usize) { let v = vec![0u8; n]; drop(v); }\n",
    )]);
    let cg = CallGraph::build(&g);
    let hot = cg.hot_fn_names();
    for f in ["entry", "left", "right", "join"] {
        assert!(hot.contains(f), "{f} should be hot: {hot:?}");
    }
    let got = rules_in(&g, "rust/src/serve/mod.rs");
    assert_eq!(got.len(), 1, "one report for the one vec!: {got:?}");
    assert_eq!(got[0], ("hot-path-purity".to_string(), 5));
}

#[test]
fn purity_violation_names_the_call_chain() {
    let g = graph(&[
        (
            "rust/src/serve/mod.rs",
            "// curlint: hot-entry\n\
             fn decode() { crate::pipeline::helper(); }\n",
        ),
        (
            "rust/src/pipeline/mod.rs",
            "pub fn helper() { let s = x.to_vec(); drop(s); }\n",
        ),
    ]);
    let per_file = check_repo(&g, &[]);
    let vs = &per_file["rust/src/pipeline/mod.rs"];
    assert_eq!(vs.len(), 1, "{vs:?}");
    assert_eq!(vs[0].rule, "hot-path-purity");
    assert!(
        vs[0].msg.contains("decode → helper"),
        "chain in message: {}",
        vs[0].msg
    );
}

#[test]
fn method_name_collision_is_conservatively_hot() {
    // `.step()` resolves receiver-agnostically: both impls go hot, so
    // the allocation in the *other* type's step still fires.
    let g = graph(&[
        (
            "rust/src/serve/mod.rs",
            "// curlint: hot-entry\n\
             fn tick(w: &Worker) { w.step(); }\n\
             struct Worker;\n\
             impl Worker { fn step(&self) {} }\n",
        ),
        (
            "rust/src/backend/other.rs",
            "struct Sim;\n\
             impl Sim { fn step(&self) { let v: Vec<u8> = Vec::new(); drop(v); } }\n",
        ),
    ]);
    let got = rules_in(&g, "rust/src/backend/other.rs");
    assert_eq!(got, vec![("hot-path-purity".to_string(), 2)], "{got:?}");
}

#[test]
fn use_alias_calls_resolve_to_the_target() {
    let g = graph(&[
        (
            "rust/src/serve/mod.rs",
            "use crate::util::scratch::grow as ensure_cap;\n\
             // curlint: hot-entry\n\
             fn admit() { ensure_cap(); }\n",
        ),
        (
            "rust/src/util/scratch.rs",
            "pub fn grow() { let v = vec![0u8; 4]; drop(v); }\n",
        ),
    ]);
    let cg = CallGraph::build(&g);
    assert!(cg.hot_fn_names().contains("grow"), "{:?}", cg.hot_fn_names());
    let got = rules_in(&g, "rust/src/util/scratch.rs");
    assert_eq!(got, vec![("hot-path-purity".to_string(), 1)]);
}

#[test]
fn test_fns_never_enter_the_hot_set() {
    let g = graph(&[(
        "rust/src/serve/mod.rs",
        "// curlint: hot-entry\n\
         fn entry() {}\n\
         #[cfg(test)]\n\
         mod tests {\n\
             fn scratch() { let v = vec![0u8; 4]; drop(v); super::entry(); }\n\
         }\n",
    )]);
    assert!(rules_in(&g, "rust/src/serve/mod.rs").is_empty());
}

#[test]
fn kernel_module_fns_are_hot_without_annotation() {
    // The v1 kernel-purity floor: everything in a kernel module is an
    // entry, and callees in *other* files inherit hotness.
    let g = graph(&[
        (
            "rust/src/backend/native/math.rs",
            "pub fn matmul() { crate::util::scratch::grow(); }\n",
        ),
        (
            "rust/src/util/scratch.rs",
            "pub fn grow() { let v = vec![0u8; 4]; drop(v); }\n",
        ),
    ]);
    let got = rules_in(&g, "rust/src/util/scratch.rs");
    assert_eq!(got, vec![("hot-path-purity".to_string(), 1)]);
}

// ---------------------------------------------------------- typed-error

/// A second file naming the fixture's pub items, so `dead-pub` stays
/// out of a test that is about a different rule.
const USERS: (&str, &str) = (
    "rust/src/lib.rs",
    "fn users() { let _ = (admit, parse, parse2, score); }\n",
);

#[test]
fn bare_anyhow_in_pub_result_fn_fires() {
    let g = graph(&[
        (
            "rust/src/serve/mod.rs",
            "pub fn admit() -> Result<()> {\n\
                 Err(anyhow!(\"no free slot\"))\n\
             }\n",
        ),
        USERS,
    ]);
    let got = rules_in(&g, "rust/src/serve/mod.rs");
    assert_eq!(got, vec![("typed-error".to_string(), 2)]);
}

#[test]
fn format_bail_fires_and_typed_payload_passes() {
    let g = graph(&[
        (
            "rust/src/backend/mod.rs",
            "pub fn parse(s: &str) -> Result<Plan> {\n\
                 bail!(format!(\"bad spec {s}\"));\n\
             }\n\
             pub fn parse2(s: &str) -> Result<Plan> {\n\
                 bail!(SpecError { what: s.into() });\n\
             }\n",
        ),
        USERS,
    ]);
    let got = rules_in(&g, "rust/src/backend/mod.rs");
    assert_eq!(got, vec![("typed-error".to_string(), 2)], "{got:?}");
}

#[test]
fn private_fns_and_other_modules_are_not_boundaries() {
    let g = graph(&[
        (
            "rust/src/serve/mod.rs",
            "fn internal() -> Result<()> { bail!(\"scratch\") }\n",
        ),
        (
            "rust/src/eval/mod.rs",
            "pub fn score() -> Result<f64> { bail!(\"eval tool, not a boundary\") }\n",
        ),
        USERS,
    ]);
    assert!(rules_in(&g, "rust/src/serve/mod.rs").is_empty());
    assert!(rules_in(&g, "rust/src/eval/mod.rs").is_empty());
}

// ------------------------------------------------------------- dead-pub

#[test]
fn unreferenced_pub_item_fires() {
    let g = graph(&[
        ("rust/src/util/stats.rs", "pub fn orphan() -> u32 { 1 }\n"),
        ("rust/src/serve/mod.rs", "fn unrelated() {}\n"),
    ]);
    let got = rules_in(&g, "rust/src/util/stats.rs");
    assert_eq!(got, vec![("dead-pub".to_string(), 1)]);
}

#[test]
fn cross_file_and_reference_only_uses_count_as_live() {
    let g = graph(&[
        ("rust/src/util/stats.rs", "pub fn mean() -> f64 { 0.0 }\npub fn gib() -> f64 { 0.0 }\n"),
        ("rust/src/serve/mod.rs", "fn report() { let _ = crate::util::stats::mean(); }\n"),
    ]);
    // `mean` is used by serve; `gib` only by the bench harness, which is
    // scanned for references without being linted.
    let refs = vec![(
        "rust/benches/harness/main.rs".to_string(),
        "fn main() { let _ = curing::util::stats::gib(); }".to_string(),
    )];
    let vs = check_repo(&g, &refs);
    assert!(vs.get("rust/src/util/stats.rs").is_none(), "{vs:?}");
}

#[test]
fn restricted_test_and_associated_items_are_exempt() {
    let g = graph(&[
        (
            "rust/src/util/stats.rs",
            "pub(crate) fn internal() {}\n\
             #[cfg(test)]\n\
             pub fn test_helper() {}\n\
             pub struct Accum;\n\
             impl Accum { pub fn push(&mut self) {} pub const SEED: u64 = 7; }\n",
        ),
        // Accum itself is named elsewhere; its associated items are only
        // ever reached through it and must not need their own refs.
        ("rust/src/serve/mod.rs", "fn f(a: &mut crate::util::stats::Accum) { a.push(); }\n"),
    ]);
    assert!(check_repo(&g, &[]).get("rust/src/util/stats.rs").is_none());
}

#[test]
fn pub_field_does_not_leak_visibility_to_next_item() {
    // Regression: a trailing `pub` struct field used to leave pending
    // visibility set, turning the *next* private item pub (and thus a
    // dead-pub candidate).
    let g = graph(&[(
        "rust/src/serve/mod.rs",
        "pub struct Stats {\n\
             pub ok: usize,\n\
             pub failed: usize,\n\
         }\n\
         fn tally() {}\n\
         struct Slot;\n",
    )]);
    let find = |name: &str| {
        g.items
            .iter()
            .find(|it| it.name == name)
            .unwrap_or_else(|| panic!("{name} not parsed"))
    };
    assert_eq!(find("Stats").vis, Vis::Pub);
    assert_eq!(find("tally").vis, Vis::Private);
    assert_eq!(find("Slot").vis, Vis::Private);
}

// --------------------------------------------- baseline × v2 rule names

#[test]
fn v1_baseline_files_keep_ratcheting_under_v2() {
    // A checked-in baseline predating v2 may still hold retired
    // `kernel-purity` buckets: they parse, never match a v2 count, and
    // surface as stale (shrank-to-zero) rather than as errors.
    let text = "# header\n\
                3 kernel-purity rust/src/backend/native/math.rs\n\
                2 dead-pub rust/src/util/stats.rs\n";
    let base = baseline::parse(text).expect("v1 rule names stay parseable");
    let mut actual = Counts::new();
    actual.insert(
        ("rust/src/util/stats.rs".to_string(), "dead-pub".to_string()),
        2,
    );
    let verdicts = baseline::compare(&base, &actual);
    assert!(
        verdicts.iter().all(|(_, v)| !matches!(v, Verdict::Grew { .. })),
        "{verdicts:?}"
    );
    assert!(verdicts.iter().any(|((p, r), v)| {
        p == "rust/src/backend/native/math.rs"
            && r == "kernel-purity"
            && matches!(v, Verdict::Shrank { allowed: 3, actual: 0 })
    }));
}

//! Tests for the reader side of the recorded-run format: strict schema
//! validation, semantic invariants, and the delta-report classifier
//! (improved / regressed / neutral-within-noise / added / removed, with
//! unit mismatch as a hard error).

use xtask::bench::{check_invariants, diff, has_sensitivity_grid, parse_run, Class, Run};
use xtask::json::parse;

/// Build a one-workload v2 run with the given measurement rows
/// (key, value, unit, cv) — enough shape for the classifier tests.
fn run_with(workload: &str, mode: &str, rows: &[(&str, f64, &str, f64)]) -> Run {
    let ms: Vec<String> = rows
        .iter()
        .map(|(k, v, u, cv)| {
            format!(r#""{k}": {{"value": {v}, "unit": "{u}", "iters": 3, "cv": {cv}, "#)
                + r#""deterministic": false}"#
        })
        .collect();
    let text = format!(
        r#"{{"schema": 2, "engine": "native", "commit": "abc1234", "date": "2026-08-08",
            "mode": "{mode}",
            "workloads": {{"{workload}": {{"measurements": {{{}}}}}}}}}"#,
        ms.join(",")
    );
    parse_run(&parse(&text).expect("json")).expect("valid run")
}

fn parse_text(text: &str) -> Result<Run, String> {
    parse_run(&parse(text).map_err(|e| e.to_string())?)
}

// ------------------------------------------------------- schema validation

#[test]
fn accepts_a_well_formed_run() {
    let run = run_with("micro", "full", &[("matmul_ms", 1.25, "ms/iter", 0.02)]);
    assert_eq!(run.workloads.len(), 1);
    assert_eq!(run.n_measurements(), 1);
    let m = run.workload("micro").unwrap().measurement("matmul_ms").unwrap();
    assert_eq!(m.unit, "ms/iter");
    assert_eq!(m.iters, 3);
}

#[test]
fn rejects_unknown_unit() {
    let err = parse_text(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1, "unit": "furlongs", "deterministic": true}}}}}"#,
    )
    .unwrap_err();
    assert!(err.contains("unknown unit"), "{err}");
}

#[test]
fn rejects_non_finite_value() {
    // 1e999 overflows to +inf in the f64 parse — JSON itself cannot
    // spell NaN/inf, so overflow is how a non-finite value sneaks in.
    let err = parse_text(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1e999, "unit": "s", "deterministic": true}}}}}"#,
    )
    .unwrap_err();
    assert!(err.contains("non-finite"), "{err}");
}

#[test]
fn rejects_missing_deterministic_flag_and_bad_samples() {
    let err = parse_text(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1, "unit": "s"}}}}}"#,
    )
    .unwrap_err();
    assert!(err.contains("deterministic"), "{err}");

    let err = parse_text(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1, "unit": "s", "deterministic": true,
                   "samples": [1, "oops"]}}}}}"#,
    )
    .unwrap_err();
    assert!(err.contains("samples"), "{err}");
}

#[test]
fn rejects_v1_shape_with_a_pointer_to_migration() {
    // v1 files also said "schema": 2 but kept flat sections instead of a
    // `workloads` object — the strict reader must not half-read them.
    let err = parse_text(
        r#"{"schema": 2, "engine": "native",
            "rows": [{"name": "matmul", "p50_ms": 1.0}]}"#,
    )
    .unwrap_err();
    assert!(err.contains("workloads"), "{err}");
}

#[test]
fn rejects_wrong_schema_number() {
    let err = parse_text(r#"{"schema": 3, "workloads": {}}"#).unwrap_err();
    assert!(err.contains("schema"), "{err}");
}

// ----------------------------------------------------------- delta report

#[test]
fn classifies_improvement_regression_and_noise() {
    // tokens/s is higher-is-better; ms/iter is lower-is-better.
    let old = run_with(
        "serve_mixed",
        "full",
        &[
            ("tokens_per_s[slots=4]", 100.0, "tokens/s", 0.01),
            ("tok_p95_ms[slots=4]", 20.0, "ms/iter", 0.01),
            ("prefills[slots=4]", 8.0, "count", 0.0),
        ],
    );
    let new = run_with(
        "serve_mixed",
        "full",
        &[
            ("tokens_per_s[slots=4]", 120.0, "tokens/s", 0.01), // +20% -> improved
            ("tok_p95_ms[slots=4]", 26.0, "ms/iter", 0.01),     // +30% -> regressed
            ("prefills[slots=4]", 9.0, "count", 0.0),           // neutral unit
        ],
    );
    let report = diff(&old, &new).expect("diff");
    let class_of = |key: &str| {
        report.deltas.iter().find(|d| d.key == key).map(|d| d.class).expect("delta")
    };
    assert_eq!(class_of("tokens_per_s[slots=4]"), Class::Improved);
    assert_eq!(class_of("tok_p95_ms[slots=4]"), Class::Regressed);
    // A count changed by +12.5% — beyond the 3% floor, but counts have
    // no direction, so they can never "regress".
    assert_eq!(class_of("prefills[slots=4]"), Class::Neutral);
    assert_eq!(report.counts(), (1, 1, 1));
}

#[test]
fn noise_threshold_comes_from_recorded_cv() {
    // An 8% slowdown with 1% CVs is a regression...
    let old = run_with("micro", "full", &[("m", 10.0, "ms/iter", 0.01)]);
    let new = run_with("micro", "full", &[("m", 10.8, "ms/iter", 0.01)]);
    let report = diff(&old, &new).expect("diff");
    assert_eq!(report.deltas[0].class, Class::Regressed);

    // ...but the same 8% with a 5% CV on either side is within noise
    // (threshold = max(3%, 2*cv_old, 2*cv_new) = 10%).
    let noisy_old = run_with("micro", "full", &[("m", 10.0, "ms/iter", 0.05)]);
    let report = diff(&noisy_old, &new).expect("diff");
    assert_eq!(report.deltas[0].class, Class::Neutral);
    assert!((report.deltas[0].threshold - 0.10).abs() < 1e-12);

    // The floor is 3% even when both runs recorded zero variance.
    let exact_old = run_with("micro", "full", &[("m", 10.0, "ms/iter", 0.0)]);
    let exact_new = run_with("micro", "full", &[("m", 10.2, "ms/iter", 0.0)]);
    let report = diff(&exact_old, &exact_new).expect("diff");
    assert_eq!(report.deltas[0].class, Class::Neutral);
    assert!((report.deltas[0].threshold - 0.03).abs() < 1e-12);
}

#[test]
fn lists_added_and_removed_workloads_and_measurements() {
    let mut old = run_with("micro", "full", &[("kept", 1.0, "s", 0.0), ("gone", 2.0, "s", 0.0)]);
    old.workloads.push(run_with("retired", "full", &[("x", 1.0, "s", 0.0)]).workloads.remove(0));
    let mut new = run_with("micro", "full", &[("kept", 1.0, "s", 0.0), ("fresh", 3.0, "s", 0.0)]);
    new.workloads.push(run_with("kv_cur", "full", &[("x", 1.0, "s", 0.0)]).workloads.remove(0));

    let report = diff(&old, &new).expect("diff");
    assert_eq!(report.deltas.len(), 1); // only `kept` is shared
    assert_eq!(report.added, vec![("micro".to_string(), "fresh".to_string())]);
    assert_eq!(report.removed, vec![("micro".to_string(), "gone".to_string())]);
    assert_eq!(report.added_workloads, vec!["kv_cur".to_string()]);
    assert_eq!(report.removed_workloads, vec!["retired".to_string()]);
}

#[test]
fn unit_mismatch_is_a_hard_error() {
    let old = run_with("micro", "full", &[("m", 10.0, "ms/iter", 0.0)]);
    let new = run_with("micro", "full", &[("m", 10.0, "s", 0.0)]);
    let err = diff(&old, &new).unwrap_err();
    assert!(err.contains("unit mismatch"), "{err}");
    assert!(err.contains("ms/iter -> s"), "{err}");
}

#[test]
fn mode_mismatch_is_flagged_not_fatal() {
    let old = run_with("micro", "quick", &[("m", 10.0, "ms/iter", 0.0)]);
    let new = run_with("micro", "full", &[("m", 10.0, "ms/iter", 0.0)]);
    let report = diff(&old, &new).expect("diff");
    assert_eq!(report.mode_mismatch, Some(("quick".to_string(), "full".to_string())));
    let rendered = xtask::bench::render(&report, false);
    assert!(rendered.contains("WARNING"), "{rendered}");
}

#[test]
fn zero_baseline_gets_an_infinite_delta_not_a_panic() {
    let old = run_with("serve_mixed", "full", &[("slot_failures", 0.0, "count", 0.0)]);
    let new = run_with("serve_mixed", "full", &[("slot_failures", 3.0, "count", 0.0)]);
    let report = diff(&old, &new).expect("diff");
    assert!(report.deltas[0].rel.is_infinite());
    assert_eq!(report.deltas[0].class, Class::Neutral); // count: no direction
}

#[test]
fn annotations_cover_exactly_the_regressions() {
    let old = run_with(
        "micro",
        "full",
        &[("a", 10.0, "ms/iter", 0.0), ("b", 10.0, "ms/iter", 0.0)],
    );
    let new = run_with(
        "micro",
        "full",
        &[("a", 15.0, "ms/iter", 0.0), ("b", 10.1, "ms/iter", 0.0)],
    );
    let report = diff(&old, &new).expect("diff");
    let notes = xtask::bench::annotations(&report);
    assert_eq!(notes.len(), 1);
    assert!(notes[0].starts_with("::warning"), "{}", notes[0]);
    assert!(notes[0].contains("micro.a"), "{}", notes[0]);
}

// ------------------------------------------------------------- invariants

#[test]
fn kv_cur_live_bytes_must_sit_under_the_exact_bound() {
    let run = run_with(
        "kv_cur",
        "full",
        &[
            ("exact_slot_bytes", 1000.0, "bytes", 0.0),
            ("live_bytes[keep=0.5,slots=2,prompt=8]", 1500.0, "bytes", 0.0),
        ],
    );
    let errs = check_invariants(&run);
    assert!(errs.iter().any(|e| e.contains("exceeds exact bound")), "{errs:?}");
}

#[test]
fn kv_cur_live_bytes_must_be_monotone_in_keep() {
    let run = run_with(
        "kv_cur",
        "full",
        &[
            ("exact_slot_bytes", 10000.0, "bytes", 0.0),
            ("live_bytes[keep=0.25,slots=2,prompt=8]", 900.0, "bytes", 0.0),
            ("live_bytes[keep=0.5,slots=2,prompt=8]", 500.0, "bytes", 0.0),
            ("live_bytes[keep=1,slots=2,prompt=8]", 1000.0, "bytes", 0.0),
        ],
    );
    let errs = check_invariants(&run);
    assert!(errs.iter().any(|e| e.contains("not monotone in keep")), "{errs:?}");

    // A well-ordered mesh (within the 10% slack) passes.
    let ok = run_with(
        "kv_cur",
        "full",
        &[
            ("exact_slot_bytes", 10000.0, "bytes", 0.0),
            ("live_bytes[keep=0.25,slots=2,prompt=8]", 300.0, "bytes", 0.0),
            ("live_bytes[keep=0.5,slots=2,prompt=8]", 520.0, "bytes", 0.0),
            ("live_bytes[keep=1,slots=2,prompt=8]", 1000.0, "bytes", 0.0),
            // A different slot count is its own group — not compared
            // against the slots=2 points.
            ("live_bytes[keep=0.25,slots=4,prompt=8]", 9000.0, "bytes", 0.0),
        ],
    );
    assert!(check_invariants(&ok).is_empty(), "{:?}", check_invariants(&ok));
}

#[test]
fn peft_heal_needs_a_downward_du_loss_series() {
    let mk = |series: &[f64]| {
        let text = format!(
            r#"{{"schema": 2, "workloads": {{"peft_heal": {{
                "measurements": {{}},
                "series": {{"du_loss": [{}]}}}}}}}}"#,
            series.iter().map(f64::to_string).collect::<Vec<_>>().join(",")
        );
        parse_text(&text).expect("run")
    };
    // 24 steps trending down: first-quarter mean > last-quarter mean.
    let down: Vec<f64> = (0..24).map(|i| 3.0 - 0.1 * i as f64).collect();
    assert!(check_invariants(&mk(&down)).is_empty());

    // Too short.
    let errs = check_invariants(&mk(&down[..10]));
    assert!(errs.iter().any(|e| e.contains("< 20")), "{errs:?}");

    // Long enough but flat-to-rising.
    let up: Vec<f64> = (0..24).map(|i| 3.0 + 0.1 * i as f64).collect();
    let errs = check_invariants(&mk(&up));
    assert!(errs.iter().any(|e| e.contains("trend down")), "{errs:?}");

    // Missing series entirely.
    let none = parse_text(
        r#"{"schema": 2, "workloads": {"peft_heal": {"measurements": {}}}}"#,
    )
    .expect("run");
    let errs = check_invariants(&none);
    assert!(errs.iter().any(|e| e.contains("du_loss")), "{errs:?}");
}

#[test]
fn sensitivity_grid_detection() {
    let gridded = parse_text(
        r#"{"schema": 2, "workloads": {"kv_cur": {
            "params": {"grid_keep": [1, 0.5, 0.25], "grid_slots": [2, 4]},
            "measurements": {}}}}"#,
    )
    .expect("run");
    assert!(has_sensitivity_grid(&gridded));

    // One axis is a sweep, not a grid.
    let line = parse_text(
        r#"{"schema": 2, "workloads": {"prefill_heavy": {
            "params": {"grid_prompt": [16, 32, 64]},
            "measurements": {}}}}"#,
    )
    .expect("run");
    assert!(!has_sensitivity_grid(&line));
}

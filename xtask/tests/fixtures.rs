//! Fixture tests for curlint: every rule must fire on a seeded
//! violation, stay quiet on the idiomatic fix, ignore lookalikes inside
//! strings/comments/test code, and honor `// curlint: allow` pragmas.
//! The baseline ratchet's accept/reject behavior is pinned at the end.

use xtask::baseline::{self, Counts, Verdict};
use xtask::rules::check_source;

const LIB: &str = "rust/src/serve/mod.rs";

fn rules_at(path: &str, src: &str) -> Vec<(String, usize)> {
    check_source(path, src)
        .into_iter()
        .map(|v| (v.rule.to_string(), v.line))
        .collect()
}

// ---------------------------------------------------------------- panic

#[test]
fn bare_unwrap_fires_with_position() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let v = check_source(LIB, src);
    assert_eq!(v.len(), 1);
    assert_eq!(v[0].rule, "panic");
    assert_eq!((v[0].line, v[0].col), (2, 7));
    assert!(v[0].msg.contains("unwrap"));
}

#[test]
fn expect_with_message_fires() {
    let src = "fn f(x: Option<u32>) -> u32 { x.expect(\"always some\") }\n";
    assert_eq!(rules_at(LIB, src), vec![("panic".into(), 1)]);
}

#[test]
fn panic_family_macros_fire() {
    let src = "fn f() { panic!(\"boom\") }\nfn g() { todo!() }\nfn h() { unimplemented!() }\n";
    assert_eq!(
        rules_at(LIB, src),
        vec![("panic".into(), 1), ("panic".into(), 2), ("panic".into(), 3)]
    );
}

#[test]
fn panic_boundaries_fire() {
    let src = "fn f() {\n\
               std::panic::panic_any(Crash { seq });\n\
               let r = std::panic::catch_unwind(|| work());\n\
               }\n";
    assert_eq!(rules_at(LIB, src), vec![("panic".into(), 2), ("panic".into(), 3)]);
}

#[test]
fn pragma_justifies_panic_boundaries() {
    let src = "// curlint: allow(panic) -- crash injection; caught at the supervisor boundary\n\
               fn f() { std::panic::panic_any(Crash { seq }); }\n\
               // curlint: allow(panic) -- supervisor crash boundary\n\
               fn g() { let _ = std::panic::catch_unwind(|| work()); }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn fallible_expect_method_is_not_option_expect() {
    // The JSON parser's own `fn expect(&mut self, b: u8) -> Result<…>`:
    // a byte-char argument is not a panic message.
    let src = "fn obj(&mut self) -> R { self.expect(b'{')?; self.expect(b'}') }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn unwrap_lookalikes_do_not_fire() {
    let src = "fn f() -> usize {\n\
               // a comment saying unwrap() and panic!(…)\n\
               let s = \"unwrap()\";\n\
               let r = r#\"expect(\"nested\") unwrap()\"#;\n\
               let o = x.unwrap_or(3);\n\
               s.len() + r.len() + o\n}\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn cfg_test_code_is_exempt() {
    let src = "fn lib() -> u32 { 1 }\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   #[test]\n\
                   fn t() { assert_eq!(super::lib(), Some(1).unwrap()); }\n\
               }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn violation_before_test_mod_still_fires() {
    let src = "fn lib(x: Option<u32>) -> u32 { x.unwrap() }\n\
               #[cfg(test)]\nmod tests { fn t() { lib(None).unwrap(); } }\n";
    assert_eq!(rules_at(LIB, src), vec![("panic".into(), 1)]);
}

// ----------------------------------------------------------- float-sort

#[test]
fn partial_cmp_sort_fires() {
    let src = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    let rules: Vec<String> = check_source(LIB, src).into_iter().map(|v| v.rule.into()).collect();
    // both the unsound comparator and the unwrap on it
    assert!(rules.contains(&"float-sort".to_string()));
    assert!(rules.contains(&"panic".to_string()));
}

#[test]
fn keyless_float_sort_fires() {
    let src = "fn f(v: &mut Vec<(f64, usize)>) { v.sort_unstable_by(|a, b| cmp_somehow(a, b)); }\n";
    assert_eq!(rules_at(LIB, src), vec![("float-sort".into(), 1)]);
}

#[test]
fn total_cmp_and_nan_keys_pass() {
    let src = "fn f(v: &mut [f64], w: &[f64]) {\n\
               v.sort_by(|a, b| a.total_cmp(b));\n\
               idx.sort_by(|&a, &b| nan_last_desc(w[b]).total_cmp(&nan_last_desc(w[a])));\n\
               items.sort_unstable_by(|a, b| nan_last_asc(a.0).total_cmp(&nan_last_asc(b.0)));\n\
               }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn ord_cmp_sort_passes() {
    let src = "fn f(v: &mut Vec<(u32, u32)>) { v.sort_by(|a, b| b.1.cmp(&a.1)); }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn max_by_with_partial_cmp_fires() {
    let src = "fn f(v: &[f32]) { v.iter().max_by(|a, b| a.partial_cmp(b).expect(\"cmp\")); }\n";
    let rules: Vec<String> = check_source(LIB, src).into_iter().map(|v| v.rule.into()).collect();
    assert!(rules.contains(&"float-sort".to_string()));
}

// ------------------------------------------------------- safety-comment

#[test]
fn uncommented_unsafe_fires() {
    let src = "fn f(v: &[f32]) -> &[u8] {\n    unsafe { cast(v) }\n}\n";
    assert_eq!(rules_at(LIB, src), vec![("safety-comment".into(), 2)]);
}

#[test]
fn safety_comment_satisfies() {
    let src = "fn f(v: &[f32]) -> &[u8] {\n\
               // SAFETY: f32 has no invalid bit patterns and u8 alignment\n\
               // is never stricter; the view borrows `v`.\n\
               unsafe { cast(v) }\n}\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn distant_safety_comment_does_not_satisfy() {
    let src = "// SAFETY: way up here\nfn a() {}\nfn b() {}\nfn c() {}\n\
               fn f(v: &[f32]) -> &[u8] { unsafe { cast(v) } }\n";
    assert_eq!(rules_at(LIB, src), vec![("safety-comment".into(), 5)]);
}

// -------------------------------------------------------------- env-var

#[test]
fn stray_env_var_fires() {
    let src = "fn f() -> String { std::env::var(\"CURING_RUNDIR\").unwrap_or_default() }\n";
    assert_eq!(rules_at(LIB, src), vec![("env-var".into(), 1)]);
}

#[test]
fn env_var_in_config_module_passes() {
    let src = "fn var(name: &str) -> Option<String> { std::env::var(name).ok() }\n";
    assert!(rules_at("rust/src/util/config.rs", src).is_empty());
}

#[test]
fn env_args_is_fine_anywhere() {
    let src = "fn f() { for a in std::env::args() { drop(a); } }\n";
    assert!(rules_at(LIB, src).is_empty());
}

// ---------------------------------- hot-path-purity (kernel floor)

const KERNEL: &str = "rust/src/backend/native/math.rs";

#[test]
fn kernel_allocation_patterns_fire() {
    let src = "fn k(n: usize) {\n\
               let a = vec![0.0f32; n];\n\
               let b: Vec<f32> = Vec::new();\n\
               let c = xs.to_vec();\n\
               let d: Vec<f32> = ys.iter().copied().collect();\n\
               let t = Instant::now();\n\
               }\n";
    let got = rules_at(KERNEL, src);
    assert_eq!(got.len(), 5, "{got:?}");
    assert!(got.iter().all(|(r, _)| r == "hot-path-purity"));
    assert_eq!(
        got.iter().map(|&(_, l)| l).collect::<Vec<_>>(),
        vec![2, 3, 4, 5, 6]
    );
}

#[test]
fn same_code_outside_kernel_modules_passes() {
    // Outside kernel modules the floor is silent; allocation in a
    // non-hot fn only fires through call-graph reachability.
    let src = "fn k(n: usize) { let a = vec![0.0f32; n]; drop(a); }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn retired_kernel_purity_pragma_still_suppresses() {
    // v1 pragmas name `kernel-purity`; the alias keeps them valid.
    let src = "fn k(n: usize) {\n\
               let a = vec![0.0f32; n]; // curlint: allow(kernel-purity) -- table built once at setup\n\
               }\n";
    assert!(rules_at(KERNEL, src).is_empty());
}

// ------------------------------------------------------- blocking-recv

const SUPERVISOR: &str = "rust/src/serve/supervisor.rs";

#[test]
fn bare_recv_in_serve_fires() {
    let src = "fn pump(rx: &Receiver<Msg>) {\n\
               let m = rx.recv().unwrap();\n\
               drop(m);\n\
               }\n";
    let got = rules_at(SUPERVISOR, src);
    assert!(
        got.iter().any(|(r, l)| r == "blocking-recv" && *l == 2),
        "{got:?}"
    );
}

#[test]
fn blocking_iter_drain_in_serve_fires() {
    let src = "fn drain(rx: Receiver<Msg>) -> usize { rx.iter().count() }\n";
    let got = rules_at(SUPERVISOR, src);
    assert_eq!(got, vec![("blocking-recv".into(), 1)]);
}

#[test]
fn recv_timeout_and_try_iter_pass() {
    let src = "fn pump(rx: &Receiver<Msg>) -> usize {\n\
               let _ = rx.recv_timeout(TICK);\n\
               rx.try_iter().count()\n\
               }\n";
    assert!(rules_at(SUPERVISOR, src).is_empty());
}

#[test]
fn bare_recv_outside_serve_passes() {
    // Batch tools outside serve/ may block forever by design.
    let src = "fn pump(rx: &Receiver<Msg>) { let _ = rx.recv(); }\n";
    assert!(rules_at("rust/src/coordinator/mod.rs", src).is_empty());
}

// -------------------------------------------------------------- pragmas

#[test]
fn pragma_suppresses_same_line() {
    let src =
        "fn f(x: Option<u32>) -> u32 { x.unwrap() } // curlint: allow(panic) -- invariant: caller checked\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn pragma_suppresses_next_line() {
    let src = "// curlint: allow(panic) -- poisoned mutex is already fatal\n\
               fn f() { lock.lock().unwrap() }\n";
    assert!(rules_at(LIB, src).is_empty());
}

#[test]
fn pragma_scope_is_tight() {
    // The allow covers its own line + the next one, not the whole file.
    let src = "// curlint: allow(panic) -- first only\n\
               fn f() { a.unwrap() }\n\
               fn g() { b.unwrap() }\n";
    assert_eq!(rules_at(LIB, src), vec![("panic".into(), 3)]);
}

#[test]
fn pragma_for_other_rule_does_not_suppress() {
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() } // curlint: allow(env-var) -- wrong rule\n";
    assert_eq!(rules_at(LIB, src), vec![("panic".into(), 1)]);
}

#[test]
fn pragma_without_reason_is_itself_a_violation() {
    let src = "fn f() -> u32 { 3 } // curlint: allow(panic)\n";
    assert_eq!(rules_at(LIB, src), vec![("pragma".into(), 1)]);
}

#[test]
fn pragma_with_unknown_rule_is_malformed() {
    let src = "fn f() -> u32 { 3 } // curlint: allow(no-such-rule) -- why\n";
    assert_eq!(rules_at(LIB, src), vec![("pragma".into(), 1)]);
}

#[test]
fn pragma_can_cover_multiple_rules() {
    let src = "// curlint: allow(panic, float-sort) -- bench-only scratch path\n\
               fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    assert!(rules_at(LIB, src).is_empty());
}

// ---------------------------------------------------- baseline ratchet

fn counts(entries: &[(&str, &str, usize)]) -> Counts {
    entries.iter().map(|&(p, r, c)| ((p.to_string(), r.to_string()), c)).collect()
}

#[test]
fn ratchet_accepts_at_or_below_baseline() {
    let base = counts(&[("rust/src/util/json.rs", "panic", 3)]);
    let at = counts(&[("rust/src/util/json.rs", "panic", 3)]);
    let below = counts(&[("rust/src/util/json.rs", "panic", 1)]);
    assert!(baseline::compare(&base, &at)
        .iter()
        .all(|(_, v)| !matches!(v, Verdict::Grew { .. })));
    assert!(baseline::compare(&base, &below)
        .iter()
        .all(|(_, v)| !matches!(v, Verdict::Grew { .. })));
}

#[test]
fn ratchet_rejects_growth_and_new_buckets() {
    let base = counts(&[("rust/src/util/json.rs", "panic", 3)]);
    let grown = counts(&[("rust/src/util/json.rs", "panic", 4)]);
    let fresh = counts(&[
        ("rust/src/util/json.rs", "panic", 3),
        ("rust/src/serve/mod.rs", "panic", 1),
    ]);
    assert!(baseline::compare(&base, &grown)
        .iter()
        .any(|(_, v)| matches!(v, Verdict::Grew { .. })));
    let v = baseline::compare(&base, &fresh);
    assert!(v
        .iter()
        .any(|((p, _), v)| p == "rust/src/serve/mod.rs" && matches!(v, Verdict::Grew { .. })));
}

#[test]
fn baseline_serialization_round_trips_real_shape() {
    let base = counts(&[
        ("rust/src/peft/mod.rs", "panic", 1),
        ("rust/src/pipeline/mod.rs", "panic", 4),
        ("rust/src/util/json.rs", "panic", 3),
    ]);
    let text = baseline::serialize(&base);
    assert!(text.starts_with('#'), "keeps the how-to-regenerate header");
    assert_eq!(baseline::parse(&text).unwrap(), base);
}

// --------------------------------------------- end-to-end shaped fixture

#[test]
fn mixed_fixture_reports_each_class_once() {
    let src = "\
use std::time::Instant;

fn admit(q: &mut Queue) -> Slot {
    q.pop().expect(\"non-empty\")
}

fn order(scores: &mut Vec<f64>) {
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn cast(v: &[f32]) -> &[u8] {
    unsafe { transmute(v) }
}

fn rundir() -> String {
    std::env::var(\"CURING_RUNDIR\").unwrap_or_else(|_| \"runs\".into())
}
";
    let mut rules: Vec<String> =
        check_source(LIB, src).into_iter().map(|v| v.rule.to_string()).collect();
    rules.sort();
    rules.dedup();
    assert_eq!(rules, vec!["env-var", "float-sort", "panic", "safety-comment"]);
}

//! `xtask` — repo tooling, invoked as `cargo xtask <command>` (the alias
//! lives in `.cargo/config.toml`). The one command today is `lint`: the
//! **curlint** dependency-free static-analysis pass over `rust/src/**`,
//! with a `curlint.baseline` ratchet so grandfathered violations can
//! only ever shrink. See `rust/README.md` § curlint for the rule list
//! and the incident each rule encodes.

pub mod baseline;
pub mod lexer;
pub mod rules;

//! `xtask` — repo tooling, invoked as `cargo xtask <command>` (the alias
//! lives in `.cargo/config.toml`). Commands:
//!
//! - `lint` — the **curlint** dependency-free static-analysis pass over
//!   `rust/src/**`, with a `curlint.baseline` ratchet so grandfathered
//!   violations can only ever shrink. See `rust/README.md` § curlint.
//! - `bench-check` — validate a recorded benchmark run
//!   (`BENCH_native.json`, schema v2): units, finiteness, and the
//!   semantic invariants CI gates on.
//! - `bench-diff` — compare two recorded runs and classify every shared
//!   measurement as improved / regressed / within noise, using each
//!   row's recorded CV as the noise floor.
//!
//! Everything here is dependency-free by design (no serde, no dependency
//! on the `curing` crate): repo tooling must build even when the library
//! does not.

pub mod baseline;
pub mod bench;
pub mod callgraph;
pub mod itemgraph;
pub mod json;
pub mod lexer;
pub mod rules;
pub mod sarif;

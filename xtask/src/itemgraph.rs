//! The item-graph layer of curlint v2: a lightweight, dependency-free
//! parse of `rust/src/**` into modules, `fn`/`impl` items, visibility,
//! and `use` edges, built on the token stream from [`crate::lexer`].
//!
//! This is *not* a Rust front end. It recovers exactly the structure the
//! cross-file rules need and nothing more, with the imprecision
//! documented per field:
//!
//! * **Modules** come from file paths (`rust/src/serve/cluster.rs` →
//!   `serve::cluster`) plus inline `mod name { … }` blocks.
//! * **Items** are recognized by keyword (`fn`, `struct`, `enum`,
//!   `trait`, `const`, `static`, `type`, `mod`) at any brace depth; a
//!   `fn` records its signature and body token spans, its innermost
//!   `impl`/`trait` type (making it a *method*), whether its return
//!   type mentions `Result`, and whether a `// curlint: hot-entry`
//!   comment marks it as a hot-path root.
//! * **`use` edges** resolve `crate`/`super`/`self` prefixes against the
//!   importing module and expand `{…}` groups, `as` aliases, and `*`
//!   globs. External paths (`std::…`, `anyhow::…`) are kept verbatim;
//!   they simply never match a crate module during call resolution.
//!
//! Known, accepted imprecision: generic bounds can be mistaken for item
//! names in pathological signatures, `macro_rules!` bodies are scanned
//! as ordinary tokens (conservative for callers), and visibility is
//! three-valued only (`pub`, restricted `pub(…)`, private).

use crate::lexer::{lex, Comment, Tok, TokKind};

/// Three-valued visibility: `dead-pub` only fires on plain `pub`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vis {
    Pub,
    /// `pub(crate)`, `pub(super)`, `pub(in …)` — already ratcheted.
    Restricted,
    Private,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ItemKind {
    Fn,
    Struct,
    Enum,
    Union,
    Trait,
    Const,
    Static,
    TypeAlias,
    Mod,
}

/// One recognized item. `sig` and `body` are token-index ranges into
/// the owning [`SourceFile::toks`]; `body` is `None` for bodyless fns
/// (trait method declarations).
#[derive(Debug, Clone)]
pub struct Item {
    pub kind: ItemKind,
    pub name: String,
    /// Crate-relative module path, e.g. `["serve", "cluster"]`.
    pub module: Vec<String>,
    pub file: usize,
    pub line: usize,
    pub col: usize,
    pub vis: Vis,
    /// Defined inside an `impl` or `trait` block (a *method* for the
    /// receiver-agnostic call resolution).
    pub is_method: bool,
    /// The `impl`/`trait` type name, when `is_method`.
    pub self_ty: Option<String>,
    pub sig: (usize, usize),
    pub body: Option<(usize, usize)>,
    pub in_test: bool,
    /// Marked by a `// curlint: hot-entry` comment within 3 lines above
    /// the `fn` keyword.
    pub hot_entry: bool,
    /// The signature's return type mentions `Result`.
    pub returns_result: bool,
}

/// One `use` binding after prefix resolution.
#[derive(Debug, Clone)]
pub struct Import {
    /// The importing module.
    pub module: Vec<String>,
    /// The bound name (`c` for `use a::b as c`, last segment otherwise;
    /// empty for globs).
    pub name: String,
    /// Crate-relative target path — external crates keep their leading
    /// crate segment and simply never resolve to an item.
    pub target: Vec<String>,
    pub glob: bool,
}

/// A lexed file plus its derived structure.
pub struct SourceFile {
    /// Repo-root-relative path with `/` separators.
    pub path: String,
    pub module: Vec<String>,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// Token ranges covered by `#[cfg(test)]` / `#[test]` items.
    pub test_regions: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn in_test(&self, tok_idx: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| a <= tok_idx && tok_idx <= b)
    }
}

/// The whole-crate item graph.
#[derive(Default)]
pub struct ItemGraph {
    pub files: Vec<SourceFile>,
    pub items: Vec<Item>,
    pub imports: Vec<Import>,
}

impl ItemGraph {
    /// Parse a set of `(path, source)` files into one graph. Paths are
    /// expected repo-root-relative (`rust/src/…`).
    pub fn build(files: &[(String, String)]) -> ItemGraph {
        let mut g = ItemGraph::default();
        for (path, src) in files {
            let file_idx = g.files.len();
            let module = file_module(path);
            let (toks, comments) = lex(src);
            let test_regions = test_regions(&toks);
            let file = SourceFile {
                path: path.clone(),
                module,
                toks,
                comments,
                test_regions,
            };
            parse_items(&file, file_idx, &mut g.items, &mut g.imports);
            g.files.push(file);
        }
        g
    }

    /// Iterator over item indices that are fns.
    pub fn fns(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.items.len()).filter(|&i| self.items[i].kind == ItemKind::Fn)
    }
}

/// Module path from a file path: `rust/src/serve/cluster.rs` →
/// `["serve", "cluster"]`; `lib.rs`/`main.rs` → crate root; `x/mod.rs`
/// → `["x"]`. Paths outside `rust/src` get a path-shaped pseudo-module
/// so self-linted tooling files never collide with crate modules.
pub fn file_module(path: &str) -> Vec<String> {
    let p = path.replace('\\', "/");
    let Some(rel) = p.strip_prefix("rust/src/") else {
        return vec![format!("%{p}")];
    };
    if rel == "lib.rs" || rel == "main.rs" {
        return Vec::new();
    }
    let rel = rel.strip_suffix(".rs").unwrap_or(rel);
    let mut segs: Vec<String> = rel.split('/').map(str::to_string).collect();
    if segs.last().map(String::as_str) == Some("mod") {
        segs.pop();
    }
    segs
}

/// Token index spans covered by `#[cfg(test)]` / `#[test]` items.
/// (Moved here from `rules` in v2 — both the token rules and the item
/// graph need it.)
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            // Scan the attribute to its matching `]`, collecting idents.
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut names: Vec<&str> = Vec::new();
            while j < n {
                let t = &toks[j];
                if t.text == "[" {
                    depth += 1;
                } else if t.text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Ident {
                    names.push(&t.text);
                }
                j += 1;
            }
            let is_test = (names.contains(&"cfg") && names.contains(&"test"))
                || names.first() == Some(&"test");
            i = j + 1;
            if !is_test {
                continue;
            }
            // Skip further attributes stacked on the same item.
            while i + 1 < n && toks[i].text == "#" && toks[i + 1].text == "[" {
                let mut depth = 0usize;
                while i < n {
                    if toks[i].text == "[" {
                        depth += 1;
                    } else if toks[i].text == "]" {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    i += 1;
                }
            }
            // The item body: to `;` at depth 0, or the matched brace block.
            let start = i;
            let mut depth = 0usize;
            while i < n {
                let t = &toks[i];
                if t.text == "{" {
                    depth += 1;
                } else if t.text == "}" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.text == ";" && depth == 0 {
                    break;
                }
                i += 1;
            }
            regions.push((start, i.min(n.saturating_sub(1))));
        }
        i += 1;
    }
    regions
}

/// The normalized text of a `curlint:` control comment: the comment
/// body with comment sigils and leading whitespace stripped. Pragmas
/// and `hot-entry` marks must *start* the comment — prose that merely
/// mentions the syntax (docs, this file) is not a control comment.
pub fn control_text(c: &Comment) -> &str {
    c.text
        .trim_start_matches(['/', '*', '!'])
        .trim_start()
}

/// What a brace opens, for the scope stack.
enum Open {
    Mod(String),
    /// `impl`/`trait` block with its (best-effort) type name.
    Impl(Option<String>),
    /// A fn body, holding the item index to patch with the body span.
    Fn(usize),
    Other,
}

/// Linear item scan over one file's token stream.
fn parse_items(file: &SourceFile, file_idx: usize, items: &mut Vec<Item>, imports: &mut Vec<Import>) {
    let toks = &file.toks;
    let n = toks.len();
    let mut stack: Vec<Open> = Vec::new();
    let mut pending_open: Option<Open> = None;
    let mut pending_vis = Vis::Private;
    let mut i = 0usize;

    // Current module path = file module + inline `mod` frames.
    let cur_module = |stack: &[Open], file: &SourceFile| -> Vec<String> {
        let mut m = file.module.clone();
        for fr in stack {
            if let Open::Mod(name) = fr {
                m.push(name.clone());
            }
        }
        m
    };
    let cur_impl = |stack: &[Open]| -> Option<String> {
        stack.iter().rev().find_map(|fr| match fr {
            Open::Impl(ty) => Some(ty.clone().unwrap_or_default()),
            _ => None,
        })
    };

    while i < n {
        let t = &toks[i];
        match t.text.as_str() {
            "{" => {
                stack.push(pending_open.take().unwrap_or(Open::Other));
                if let Some(Open::Fn(idx)) = stack.last() {
                    items[*idx].body = Some((i, i));
                }
                pending_vis = Vis::Private;
            }
            "}" => {
                if let Some(Open::Fn(idx)) = stack.pop() {
                    if let Some((start, _)) = items[idx].body {
                        items[idx].body = Some((start, i + 1));
                    }
                }
                pending_vis = Vis::Private;
            }
            // `pub` always directly precedes its item keyword (modulo
            // `unsafe`/`async`/`extern "C"`), so any separator between a
            // `pub` and the next keyword means the `pub` belonged to
            // something else — e.g. a struct field. Without this reset a
            // trailing `pub` field leaks onto the next file-level item.
            ";" | "," => {
                pending_open = None;
                pending_vis = Vis::Private;
            }
            "pub" if t.kind == TokKind::Ident => {
                pending_vis = if toks.get(i + 1).map(|t| t.text.as_str()) == Some("(") {
                    // Skip the restriction parens.
                    let mut j = i + 1;
                    let mut depth = 0usize;
                    while j < n {
                        if toks[j].text == "(" {
                            depth += 1;
                        } else if toks[j].text == ")" {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        j += 1;
                    }
                    i = j;
                    Vis::Restricted
                } else {
                    Vis::Pub
                };
            }
            "mod" if t.kind == TokKind::Ident => {
                if let Some(name) = ident_text(toks.get(i + 1)) {
                    items.push(Item {
                        kind: ItemKind::Mod,
                        name: name.to_string(),
                        module: cur_module(&stack, file),
                        file: file_idx,
                        line: t.line,
                        col: t.col,
                        vis: pending_vis,
                        is_method: false,
                        self_ty: None,
                        sig: (i, i + 2),
                        body: None,
                        in_test: file.in_test(i),
                        hot_entry: false,
                        returns_result: false,
                    });
                    pending_open = Some(Open::Mod(name.to_string()));
                    pending_vis = Vis::Private;
                    i += 1;
                }
            }
            "impl" if t.kind == TokKind::Ident => {
                pending_open = Some(Open::Impl(impl_type_name(toks, i + 1)));
                pending_vis = Vis::Private;
            }
            "trait" if t.kind == TokKind::Ident => {
                if let Some(name) = ident_text(toks.get(i + 1)) {
                    items.push(Item {
                        kind: ItemKind::Trait,
                        name: name.to_string(),
                        module: cur_module(&stack, file),
                        file: file_idx,
                        line: t.line,
                        col: t.col,
                        vis: pending_vis,
                        is_method: false,
                        self_ty: None,
                        sig: (i, i + 2),
                        body: None,
                        in_test: file.in_test(i),
                        hot_entry: false,
                        returns_result: false,
                    });
                    pending_open = Some(Open::Impl(Some(name.to_string())));
                    pending_vis = Vis::Private;
                    i += 1;
                }
            }
            "fn" if t.kind == TokKind::Ident => {
                if let Some(name) = ident_text(toks.get(i + 1)) {
                    let sig_end = fn_sig_end(toks, i);
                    let hot = file.comments.iter().any(|c| {
                        control_text(c)
                            .strip_prefix("curlint:")
                            .is_some_and(|d| d.trim_start().starts_with("hot-entry"))
                            && c.end_line + 3 >= t.line
                            && c.end_line <= t.line
                    });
                    let idx = items.len();
                    items.push(Item {
                        kind: ItemKind::Fn,
                        name: name.to_string(),
                        module: cur_module(&stack, file),
                        file: file_idx,
                        line: t.line,
                        col: t.col,
                        vis: pending_vis,
                        is_method: cur_impl(&stack).is_some(),
                        self_ty: cur_impl(&stack).filter(|s| !s.is_empty()),
                        sig: (i, sig_end),
                        body: None,
                        in_test: file.in_test(i),
                        hot_entry: hot,
                        returns_result: sig_returns_result(toks, i, sig_end),
                    });
                    pending_open = Some(Open::Fn(idx));
                    pending_vis = Vis::Private;
                    // Jump to the signature end so sig-internal keywords
                    // (`impl Trait`, `fn` pointer types) don't re-trigger.
                    i = sig_end.saturating_sub(1).max(i);
                }
            }
            "struct" | "enum" | "union" if t.kind == TokKind::Ident => {
                if let Some(name) = ident_text(toks.get(i + 1)) {
                    let kind = match t.text.as_str() {
                        "struct" => ItemKind::Struct,
                        "enum" => ItemKind::Enum,
                        _ => ItemKind::Union,
                    };
                    items.push(Item {
                        kind,
                        name: name.to_string(),
                        module: cur_module(&stack, file),
                        file: file_idx,
                        line: t.line,
                        col: t.col,
                        vis: pending_vis,
                        is_method: false,
                        self_ty: None,
                        sig: (i, i + 2),
                        body: None,
                        in_test: file.in_test(i),
                        hot_entry: false,
                        returns_result: false,
                    });
                    pending_vis = Vis::Private;
                    i += 1;
                }
            }
            "const" | "static" | "type" if t.kind == TokKind::Ident => {
                // `const fn` / `static mut NAME` / associated `type` all
                // reduce to "next non-keyword ident is the name"; a
                // following `fn` is handled by its own branch.
                let mut j = i + 1;
                while matches!(ident_text(toks.get(j)), Some("mut")) {
                    j += 1;
                }
                if let Some(name) = ident_text(toks.get(j)) {
                    if name != "fn" {
                        let kind = match t.text.as_str() {
                            "const" => ItemKind::Const,
                            "static" => ItemKind::Static,
                            _ => ItemKind::TypeAlias,
                        };
                        let imp = cur_impl(&stack);
                        items.push(Item {
                            kind,
                            name: name.to_string(),
                            module: cur_module(&stack, file),
                            file: file_idx,
                            line: t.line,
                            col: t.col,
                            vis: pending_vis,
                            is_method: imp.is_some(),
                            self_ty: imp,
                            sig: (i, j + 1),
                            body: None,
                            in_test: file.in_test(i),
                            hot_entry: false,
                            returns_result: false,
                        });
                        pending_vis = Vis::Private;
                        i = j;
                    }
                }
            }
            "use" if t.kind == TokKind::Ident => {
                let mut j = i + 1;
                let start = j;
                while j < n && toks[j].text != ";" {
                    j += 1;
                }
                parse_use(&toks[start..j], &cur_module(&stack, file), imports);
                pending_vis = Vis::Private;
                i = j;
            }
            _ => {}
        }
        i += 1;
    }
}

fn ident_text(t: Option<&Tok>) -> Option<&str> {
    t.filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
}

/// Best-effort type name of an `impl` header: idents at angle-depth 0
/// between `impl` and `{`, taking the last path segment after `for` if
/// present (`impl Backend for FaultyBackend<B>` → `FaultyBackend`),
/// else the first path's last segment (`impl fmt::Display` → nothing —
/// no `for` means the first path IS the self type, e.g. `impl Foo`).
fn impl_type_name(toks: &[Tok], start: usize) -> Option<String> {
    let mut angle = 0i32;
    let mut saw_for = false;
    let mut pre: Vec<&str> = Vec::new();
    let mut post: Vec<&str> = Vec::new();
    let mut j = start;
    while j < toks.len() {
        let t = &toks[j];
        match t.text.as_str() {
            "{" | ";" if angle <= 0 => break,
            "<" => angle += 1,
            ">" => {
                // `->` in fn-pointer bounds: the '>' belongs to the arrow.
                if j == 0 || toks[j - 1].text != "-" {
                    angle -= 1;
                }
            }
            "where" if angle <= 0 && t.kind == TokKind::Ident => break,
            "for" if angle <= 0 && t.kind == TokKind::Ident => saw_for = true,
            _ if angle <= 0 && t.kind == TokKind::Ident => {
                if saw_for {
                    post.push(&t.text);
                } else {
                    pre.push(&t.text);
                }
            }
            _ => {}
        }
        j += 1;
    }
    let segs = if saw_for { post } else { pre };
    segs.last().map(|s| s.to_string())
}

/// Token index one past a fn signature: the first `{` or `;` at
/// paren/bracket depth 0 after the parameter list.
fn fn_sig_end(toks: &[Tok], fn_idx: usize) -> usize {
    let mut depth = 0i32;
    let mut j = fn_idx + 1;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "{" | ";" if depth <= 0 => return j,
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// Whether the `->` return type inside `sig` mentions `Result`.
fn sig_returns_result(toks: &[Tok], fn_idx: usize, sig_end: usize) -> bool {
    let mut j = fn_idx;
    while j + 1 < sig_end {
        if toks[j].text == "-" && toks[j + 1].text == ">" {
            return toks[j + 2..sig_end].iter().any(|t| t.text == "Result");
        }
        j += 1;
    }
    false
}

/// Parse the token slice of one `use …` statement (without the `;`)
/// into [`Import`]s, expanding groups, aliases and globs.
fn parse_use(toks: &[Tok], module: &[String], imports: &mut Vec<Import>) {
    parse_use_tree(toks, &mut 0, module, &[], imports);
}

fn parse_use_tree(
    toks: &[Tok],
    i: &mut usize,
    module: &[String],
    prefix: &[String],
    imports: &mut Vec<Import>,
) {
    let mut path: Vec<String> = prefix.to_vec();
    loop {
        let Some(t) = toks.get(*i) else { break };
        match t.text.as_str() {
            "*" => {
                imports.push(Import {
                    module: module.to_vec(),
                    name: String::new(),
                    target: path.clone(),
                    glob: true,
                });
                *i += 1;
                return;
            }
            "{" => {
                *i += 1;
                loop {
                    match toks.get(*i).map(|t| t.text.as_str()) {
                        Some("}") => {
                            *i += 1;
                            return;
                        }
                        Some(",") => {
                            *i += 1;
                        }
                        Some(_) => parse_use_tree(toks, i, module, &path, imports),
                        None => return,
                    }
                }
            }
            "as" if t.kind == TokKind::Ident => {
                if let Some(alias) = ident_text(toks.get(*i + 1)) {
                    imports.push(Import {
                        module: module.to_vec(),
                        name: alias.to_string(),
                        target: path.clone(),
                        glob: false,
                    });
                    *i += 2;
                }
                return;
            }
            ":" => {
                *i += 1; // the path continues after `::`
            }
            "," | "}" => {
                // End of this tree inside a group: bind the last segment.
                bind_last(&path, module, imports);
                return;
            }
            _ if t.kind == TokKind::Ident => {
                resolve_seg(&mut path, &t.text, module);
                *i += 1;
                // Lookahead: end of statement binds the last segment.
                match toks.get(*i).map(|t| t.text.as_str()) {
                    None => {
                        bind_last(&path, module, imports);
                        return;
                    }
                    Some(":") | Some("{") | Some("as") | Some("*") => {}
                    Some(_) => {
                        bind_last(&path, module, imports);
                        return;
                    }
                }
            }
            _ => {
                *i += 1;
            }
        }
    }
    if !path.is_empty() {
        bind_last(&path, module, imports);
    }
}

/// Append one path segment, resolving `crate`/`super`/`self` relative
/// to `module` when they lead the path.
fn resolve_seg(path: &mut Vec<String>, seg: &str, module: &[String]) {
    match seg {
        "crate" if path.is_empty() => {}
        "self" if path.is_empty() => path.extend_from_slice(module),
        "super" => {
            if path.is_empty() {
                path.extend_from_slice(module);
            }
            path.pop();
        }
        "self" => {} // `{self, …}`: the group prefix is the target
        _ => path.push(seg.to_string()),
    }
}

fn bind_last(path: &[String], module: &[String], imports: &mut Vec<Import>) {
    if let Some(name) = path.last() {
        imports.push(Import {
            module: module.to_vec(),
            name: name.clone(),
            target: path.to_vec(),
            glob: false,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph(src: &str) -> ItemGraph {
        ItemGraph::build(&[("rust/src/serve/mod.rs".to_string(), src.to_string())])
    }

    fn find<'g>(g: &'g ItemGraph, name: &str) -> &'g Item {
        g.items.iter().find(|it| it.name == name).unwrap()
    }

    #[test]
    fn file_modules() {
        assert_eq!(file_module("rust/src/lib.rs"), Vec::<String>::new());
        assert_eq!(file_module("rust/src/serve/mod.rs"), vec!["serve"]);
        assert_eq!(file_module("rust/src/serve/cluster.rs"), vec!["serve", "cluster"]);
        assert_eq!(
            file_module("rust/src/backend/native/math.rs"),
            vec!["backend", "native", "math"]
        );
        assert!(file_module("xtask/src/main.rs")[0].starts_with('%'));
    }

    #[test]
    fn fns_and_methods() {
        let g = graph(
            "pub fn free() -> Result<()> { helper() }\n\
             fn helper() {}\n\
             struct S;\n\
             impl S { pub fn method(&self) -> anyhow::Result<u32> { Ok(1) } }\n\
             impl std::fmt::Display for S {\n\
                 fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result { write!(f, \"s\") }\n\
             }",
        );
        let free = find(&g, "free");
        assert_eq!((free.vis, free.is_method, free.returns_result), (Vis::Pub, false, true));
        assert!(free.body.is_some());
        let m = find(&g, "method");
        assert_eq!(m.self_ty.as_deref(), Some("S"));
        assert!(m.returns_result);
        let f = find(&g, "fmt");
        assert_eq!(f.self_ty.as_deref(), Some("S"));
        assert_eq!(find(&g, "helper").vis, Vis::Private);
    }

    #[test]
    fn inline_mods_and_tests() {
        let g = graph(
            "pub fn outer() {}\n\
             mod inner { pub fn nested() {} }\n\
             #[cfg(test)]\nmod tests { fn t() {} #[test] fn case() {} }",
        );
        assert_eq!(find(&g, "outer").module, vec!["serve"]);
        assert_eq!(find(&g, "nested").module, vec!["serve", "inner"]);
        assert!(find(&g, "case").in_test);
        assert!(find(&g, "t").in_test);
        assert!(!find(&g, "outer").in_test);
    }

    #[test]
    fn use_resolution() {
        let g = graph(
            "use crate::backend::native::math;\n\
             use super::{Request, ServeStats as Stats};\n\
             use crate::util::stats::*;\n\
             use std::sync::mpsc::channel;",
        );
        let find_import = |name: &str| g.imports.iter().find(|im| im.name == name).unwrap();
        assert_eq!(find_import("math").target, vec!["backend", "native", "math"]);
        // file module is ["serve"]; super:: of it is the crate root.
        assert_eq!(find_import("Request").target, vec!["Request"]);
        assert_eq!(find_import("Stats").target, vec!["ServeStats"]);
        assert!(g.imports.iter().any(|im| im.glob && im.target == ["util", "stats"]));
        assert_eq!(find_import("channel").target, vec!["std", "sync", "mpsc", "channel"]);
    }

    #[test]
    fn hot_entry_and_restricted_vis() {
        let g = graph(
            "// curlint: hot-entry\n\
             pub fn decode() {}\n\
             pub(crate) fn internal() {}\n\
             /// Mentions `// curlint: hot-entry` in prose only.\n\
             pub fn cold() {}",
        );
        assert!(find(&g, "decode").hot_entry);
        assert!(!find(&g, "cold").hot_entry);
        assert_eq!(find(&g, "internal").vis, Vis::Restricted);
    }

    #[test]
    fn raw_identifier_fn_names() {
        let g = graph("pub fn r#type() {} fn caller() { r#type() }");
        assert_eq!(find(&g, "type").kind, ItemKind::Fn);
    }

    #[test]
    fn impl_type_names() {
        let toks = lex("impl<B: Backend> Backend for FaultyBackend<B> { }").0;
        assert_eq!(impl_type_name(&toks, 1).as_deref(), Some("FaultyBackend"));
        let toks = lex("impl fmt::Display for KvPolicy { }").0;
        assert_eq!(impl_type_name(&toks, 1).as_deref(), Some("KvPolicy"));
        let toks = lex("impl NativeBackend { }").0;
        assert_eq!(impl_type_name(&toks, 1).as_deref(), Some("NativeBackend"));
    }
}

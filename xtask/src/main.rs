//! `cargo xtask lint` — run curlint over `rust/src/**` and enforce the
//! `curlint.baseline` ratchet. Exit codes: 0 clean (or fully
//! grandfathered), 1 new violations or a grown bucket, 2 usage/IO error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xtask::baseline::{self, Counts, Verdict};
use xtask::rules::{check_source, Violation};

const USAGE: &str = "\
usage: cargo xtask lint [options]

options:
  --update-baseline   rewrite curlint.baseline from the current violations
                      (review the diff: counts should only ever shrink)
  --list              print grandfathered violations too, not just new ones
  --root <dir>        repo root (default: auto-detected from cwd)
  -h, --help          this message
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut update = false;
    let mut list = false;
    let mut root: Option<PathBuf> = None;
    let mut cmd: Option<String> = None;
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            "--update-baseline" => update = true,
            "--list" => list = true,
            "--root" => match it.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => {
                    eprintln!("--root needs a directory\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            other if cmd.is_none() && !other.starts_with('-') => cmd = Some(other.to_string()),
            other => {
                eprintln!("unknown option `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    match cmd.as_deref() {
        Some("lint") => {}
        Some(other) => {
            eprintln!("unknown command `{other}` (only `lint`)\n{USAGE}");
            return ExitCode::from(2);
        }
        None => {
            eprintln!("missing command\n{USAGE}");
            return ExitCode::from(2);
        }
    }

    let root = match root.or_else(find_repo_root) {
        Some(r) => r,
        None => {
            eprintln!("curlint: could not find the repo root (looked for rust/src upward)");
            return ExitCode::from(2);
        }
    };
    match run_lint(&root, update, list) {
        Ok(clean) => {
            if clean {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("curlint: {e}");
            ExitCode::from(2)
        }
    }
}

/// Walk upward from cwd to the first directory containing `rust/src`.
fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries =
            std::fs::read_dir(&d).map_err(|e| format!("read_dir {}: {e}", d.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", d.display()))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn run_lint(root: &Path, update: bool, list: bool) -> Result<bool, String> {
    let src_root = root.join("rust/src");
    let baseline_path = root.join("curlint.baseline");

    let files = rs_files(&src_root)?;
    let n_files = files.len();
    let mut actual = Counts::new();
    let mut by_file: Vec<(String, Vec<Violation>)> = Vec::new();
    let mut total = 0usize;
    for file in files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&file)
            .map_err(|e| format!("read {}: {e}", file.display()))?;
        let violations = check_source(&rel, &src);
        total += violations.len();
        for v in &violations {
            *actual.entry((rel.clone(), v.rule.to_string())).or_insert(0) += 1;
        }
        if !violations.is_empty() {
            by_file.push((rel, violations));
        }
    }

    if update {
        std::fs::write(&baseline_path, baseline::serialize(&actual))
            .map_err(|e| format!("write {}: {e}", baseline_path.display()))?;
        println!(
            "curlint: baseline rewritten with {total} violation(s) across {} bucket(s)",
            actual.len()
        );
        return Ok(true);
    }

    let base_text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("read {}: {e}", baseline_path.display())),
    };
    let base = baseline::parse(&base_text)?;

    let comparisons = baseline::compare(&base, &actual);
    let mut grew = 0usize;
    let mut stale = 0usize;
    for ((path, rule), verdict) in &comparisons {
        match verdict {
            Verdict::Grew { allowed, actual } => {
                grew += 1;
                eprintln!(
                    "curlint: {path}: [{rule}] {actual} violation(s), baseline allows \
                     {allowed} — fix them or `// curlint: allow({rule}) -- <reason>`"
                );
            }
            Verdict::Shrank { allowed, actual } => {
                stale += 1;
                println!(
                    "curlint: {path}: [{rule}] improved to {actual} (baseline {allowed}) \
                     — tighten with `cargo xtask lint --update-baseline`"
                );
            }
            Verdict::AtBaseline => {}
        }
    }

    // Print the offending sites: every violation in a grown bucket, or
    // everything under --list.
    for (path, violations) in &by_file {
        for v in violations {
            let bucket_grew = comparisons.iter().any(|((p, r), verdict)| {
                p == path && r == v.rule && matches!(verdict, Verdict::Grew { .. })
            });
            if list || bucket_grew {
                println!("{path}:{}:{}: [{}] {}", v.line, v.col, v.rule, v.msg);
            }
        }
    }

    let grandfathered = total - comparisons
        .iter()
        .map(|((p, r), _)| {
            let allowed = base.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
            let n = actual.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
            n.saturating_sub(allowed)
        })
        .sum::<usize>();
    println!(
        "curlint: {total} violation(s) ({grandfathered} grandfathered, {n_files} file(s) \
         scanned){}",
        if stale > 0 { ", baseline is stale" } else { "" }
    );
    if grew > 0 {
        eprintln!("curlint: FAILED — {grew} bucket(s) above the baseline");
        return Ok(false);
    }
    println!("curlint: ok");
    Ok(true)
}

//! `cargo xtask <command>` — repo tooling.
//!
//! - `lint`: run curlint over `rust/src/**` and enforce the
//!   `curlint.baseline` ratchet. Exit codes: 0 clean (or fully
//!   grandfathered), 1 new violations or a grown bucket, 2 usage/IO.
//! - `bench-check <run.json>`: validate a v2 recorded benchmark run.
//!   Exit codes: 0 valid, 1 validation/invariant failures, 2 usage/IO.
//! - `bench-diff <old.json> <new.json>`: per-measurement delta report.
//!   Exit codes: 0 ok, 1 regressions under `--fail-on-regression`,
//!   2 usage/IO/unit-mismatch.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xtask::baseline::{self, Counts, Verdict};
use xtask::bench;
use xtask::rules::{check_source, Violation};

const USAGE: &str = "\
usage: cargo xtask <command> [options]

commands:
  lint                       curlint over rust/src/** with the baseline ratchet
  bench-check <run.json>     validate a v2 recorded benchmark run
  bench-diff <old> <new>     delta report between two recorded runs

lint options:
  --update-baseline   rewrite curlint.baseline from the current violations
                      (review the diff: counts should only ever shrink)
  --list              print grandfathered violations too, not just new ones
  --root <dir>        repo root (default: auto-detected from cwd)

bench-check options:
  --require-workloads a,b,c  fail unless every named workload is present
  --require-grid             fail unless some workload swept a sensitivity grid

bench-diff options:
  --fail-on-regression       exit 1 when any measurement regressed beyond noise
  --annotate                 emit GitHub Actions ::warning lines for regressions
  --verbose                  list within-noise rows too

  -h, --help          this message
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut update = false;
    let mut list = false;
    let mut require_grid = false;
    let mut fail_on_regression = false;
    let mut annotate = false;
    let mut verbose = false;
    let mut require_workloads: Vec<String> = Vec::new();
    let mut root: Option<PathBuf> = None;
    let mut cmd: Option<String> = None;
    let mut operands: Vec<PathBuf> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            "--update-baseline" => update = true,
            "--list" => list = true,
            "--require-grid" => require_grid = true,
            "--fail-on-regression" => fail_on_regression = true,
            "--annotate" => annotate = true,
            "--verbose" => verbose = true,
            "--require-workloads" => match it.next() {
                Some(names) => {
                    require_workloads
                        .extend(names.split(',').map(str::trim).map(str::to_string));
                }
                None => {
                    eprintln!("--require-workloads needs a comma-separated list\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--root" => match it.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => {
                    eprintln!("--root needs a directory\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            other if cmd.is_none() && !other.starts_with('-') => cmd = Some(other.to_string()),
            other if cmd.is_some() && !other.starts_with('-') => {
                operands.push(PathBuf::from(other));
            }
            other => {
                eprintln!("unknown option `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    match cmd.as_deref() {
        Some("lint") => {
            let root = match root.or_else(find_repo_root) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "curlint: could not find the repo root (looked for rust/src upward)"
                    );
                    return ExitCode::from(2);
                }
            };
            match run_lint(&root, update, list) {
                Ok(true) => ExitCode::SUCCESS,
                Ok(false) => ExitCode::from(1),
                Err(e) => {
                    eprintln!("curlint: {e}");
                    ExitCode::from(2)
                }
            }
        }
        Some("bench-check") => {
            let [run] = operands.as_slice() else {
                eprintln!("bench-check needs exactly one run file\n{USAGE}");
                return ExitCode::from(2);
            };
            run_bench_check(run, &require_workloads, require_grid)
        }
        Some("bench-diff") => {
            let [old, new] = operands.as_slice() else {
                eprintln!("bench-diff needs exactly two run files\n{USAGE}");
                return ExitCode::from(2);
            };
            run_bench_diff(old, new, fail_on_regression, annotate, verbose)
        }
        Some(other) => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            ExitCode::from(2)
        }
        None => {
            eprintln!("missing command\n{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn run_bench_check(path: &Path, require_workloads: &[String], require_grid: bool) -> ExitCode {
    let run = match bench::load_run(path) {
        Ok(run) => run,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return ExitCode::from(2);
        }
    };
    let mut errs = bench::check_invariants(&run);
    for name in require_workloads {
        if !name.is_empty() && run.workload(name).is_none() {
            errs.push(format!("required workload `{name}` is missing"));
        }
    }
    if require_grid && !bench::has_sensitivity_grid(&run) {
        errs.push(
            "no sensitivity grid: expected some workload with >= 2 `grid_*` param \
             axes covering >= 4 points"
                .to_string(),
        );
    }
    println!(
        "bench-check: {} — engine {}, mode {}, date {}, {} workload(s), {} measurement(s)",
        path.display(),
        run.engine,
        run.mode,
        run.date,
        run.workloads.len(),
        run.n_measurements()
    );
    for w in &run.workloads {
        println!("  {:<14} {} measurement(s)", w.name, w.measurements.len());
    }
    if errs.is_empty() {
        println!("bench-check: ok");
        ExitCode::SUCCESS
    } else {
        for e in &errs {
            eprintln!("bench-check: {e}");
        }
        eprintln!("bench-check: FAILED — {} problem(s)", errs.len());
        ExitCode::from(1)
    }
}

fn run_bench_diff(
    old_path: &Path,
    new_path: &Path,
    fail_on_regression: bool,
    annotate: bool,
    verbose: bool,
) -> ExitCode {
    let (old, new) = match (bench::load_run(old_path), bench::load_run(new_path)) {
        (Ok(o), Ok(n)) => (o, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-diff: {e}");
            return ExitCode::from(2);
        }
    };
    let report = match bench::diff(&old, &new) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-diff: {e}");
            return ExitCode::from(2);
        }
    };
    println!(
        "bench-diff: {} ({}) -> {} ({})",
        old_path.display(),
        old.commit.as_deref().unwrap_or("no commit"),
        new_path.display(),
        new.commit.as_deref().unwrap_or("no commit")
    );
    print!("{}", bench::render(&report, verbose));
    if annotate {
        for line in bench::annotations(&report) {
            println!("{line}");
        }
    }
    let (_, regressed, _) = report.counts();
    if fail_on_regression && regressed > 0 {
        eprintln!("bench-diff: FAILED — {regressed} regression(s) beyond noise");
        return ExitCode::from(1);
    }
    ExitCode::SUCCESS
}

/// Walk upward from cwd to the first directory containing `rust/src`.
fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries =
            std::fs::read_dir(&d).map_err(|e| format!("read_dir {}: {e}", d.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", d.display()))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn run_lint(root: &Path, update: bool, list: bool) -> Result<bool, String> {
    let src_root = root.join("rust/src");
    let baseline_path = root.join("curlint.baseline");

    let files = rs_files(&src_root)?;
    let n_files = files.len();
    let mut actual = Counts::new();
    let mut by_file: Vec<(String, Vec<Violation>)> = Vec::new();
    let mut total = 0usize;
    for file in files {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&file)
            .map_err(|e| format!("read {}: {e}", file.display()))?;
        let violations = check_source(&rel, &src);
        total += violations.len();
        for v in &violations {
            *actual.entry((rel.clone(), v.rule.to_string())).or_insert(0) += 1;
        }
        if !violations.is_empty() {
            by_file.push((rel, violations));
        }
    }

    if update {
        std::fs::write(&baseline_path, baseline::serialize(&actual))
            .map_err(|e| format!("write {}: {e}", baseline_path.display()))?;
        println!(
            "curlint: baseline rewritten with {total} violation(s) across {} bucket(s)",
            actual.len()
        );
        return Ok(true);
    }

    let base_text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("read {}: {e}", baseline_path.display())),
    };
    let base = baseline::parse(&base_text)?;

    let comparisons = baseline::compare(&base, &actual);
    let mut grew = 0usize;
    let mut stale = 0usize;
    for ((path, rule), verdict) in &comparisons {
        match verdict {
            Verdict::Grew { allowed, actual } => {
                grew += 1;
                eprintln!(
                    "curlint: {path}: [{rule}] {actual} violation(s), baseline allows \
                     {allowed} — fix them or `// curlint: allow({rule}) -- <reason>`"
                );
            }
            Verdict::Shrank { allowed, actual } => {
                stale += 1;
                println!(
                    "curlint: {path}: [{rule}] improved to {actual} (baseline {allowed}) \
                     — tighten with `cargo xtask lint --update-baseline`"
                );
            }
            Verdict::AtBaseline => {}
        }
    }

    // Print the offending sites: every violation in a grown bucket, or
    // everything under --list.
    for (path, violations) in &by_file {
        for v in violations {
            let bucket_grew = comparisons.iter().any(|((p, r), verdict)| {
                p == path && r == v.rule && matches!(verdict, Verdict::Grew { .. })
            });
            if list || bucket_grew {
                println!("{path}:{}:{}: [{}] {}", v.line, v.col, v.rule, v.msg);
            }
        }
    }

    let grandfathered = total - comparisons
        .iter()
        .map(|((p, r), _)| {
            let allowed = base.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
            let n = actual.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
            n.saturating_sub(allowed)
        })
        .sum::<usize>();
    println!(
        "curlint: {total} violation(s) ({grandfathered} grandfathered, {n_files} file(s) \
         scanned){}",
        if stale > 0 { ", baseline is stale" } else { "" }
    );
    if grew > 0 {
        eprintln!("curlint: FAILED — {grew} bucket(s) above the baseline");
        return Ok(false);
    }
    println!("curlint: ok");
    Ok(true)
}

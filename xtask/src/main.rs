//! `cargo xtask <command>` — repo tooling.
//!
//! - `lint`: run curlint (token rules + the cross-file item/call-graph
//!   rules) over `rust/src/**`, the token rules over `xtask/src/**`
//!   (self-lint), and enforce the `curlint.baseline` ratchet. Exit
//!   codes: 0 clean (or fully grandfathered), 1 new violations or a
//!   grown bucket, 2 usage/IO.
//! - `bench-check <run.json>`: validate a v2 recorded benchmark run.
//!   Exit codes: 0 valid, 1 validation/invariant failures, 2 usage/IO.
//! - `bench-diff <old.json> <new.json>`: per-measurement delta report.
//!   Exit codes: 0 ok, 1 regressions under the fail flags, 2
//!   usage/IO/unit-mismatch.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xtask::baseline::{self, Counts, Verdict};
use xtask::bench;
use xtask::itemgraph::ItemGraph;
use xtask::rules::{check_repo, check_source, explain, Violation, RULE_NAMES};
use xtask::sarif;

const USAGE: &str = "\
usage: cargo xtask <command> [options]

commands:
  lint                       curlint over rust/src/** (+ xtask/src self-lint)
                             with the baseline ratchet
  bench-check <run.json>     validate a v2 recorded benchmark run
  bench-diff <old> <new>     delta report between two recorded runs

lint options:
  --update-baseline   rewrite curlint.baseline from the current violations
                      (review the diff: counts should only ever shrink)
  --list              print grandfathered violations too, not just new ones
  --emit sarif        write a SARIF 2.1.0 report to stdout (human output
                      moves to stderr); exit codes are unchanged
  --explain <rule>    print the incident + invariant behind a rule and exit
  --root <dir>        repo root (default: auto-detected from cwd)

bench-check options:
  --require-workloads a,b,c  fail unless every named workload is present
  --require-grid             fail unless some workload swept a sensitivity grid

bench-diff options:
  --fail-on-regression       exit 1 when any measurement regressed beyond noise
  --fail-on-regression-deterministic
                             exit 1 only for regressed *deterministic*
                             (non-timing) measurements; skips itself with a
                             notice when the two runs used different modes
  --annotate                 emit GitHub Actions ::warning lines for regressions
  --verbose                  list within-noise rows too

  -h, --help          this message
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut update = false;
    let mut list = false;
    let mut require_grid = false;
    let mut fail_on_regression = false;
    let mut fail_on_det_regression = false;
    let mut annotate = false;
    let mut verbose = false;
    let mut emit: Option<String> = None;
    let mut explain_rule: Option<String> = None;
    let mut require_workloads: Vec<String> = Vec::new();
    let mut root: Option<PathBuf> = None;
    let mut cmd: Option<String> = None;
    let mut operands: Vec<PathBuf> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-h" | "--help" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            "--update-baseline" => update = true,
            "--list" => list = true,
            "--require-grid" => require_grid = true,
            "--fail-on-regression" => fail_on_regression = true,
            "--fail-on-regression-deterministic" => fail_on_det_regression = true,
            "--annotate" => annotate = true,
            "--verbose" => verbose = true,
            "--emit" => match it.next() {
                Some(fmt) => emit = Some(fmt),
                None => {
                    eprintln!("--emit needs a format (sarif)\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--explain" => match it.next() {
                Some(rule) => explain_rule = Some(rule),
                None => {
                    eprintln!("--explain needs a rule name\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--require-workloads" => match it.next() {
                Some(names) => {
                    require_workloads
                        .extend(names.split(',').map(str::trim).map(str::to_string));
                }
                None => {
                    eprintln!("--require-workloads needs a comma-separated list\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--root" => match it.next() {
                Some(d) => root = Some(PathBuf::from(d)),
                None => {
                    eprintln!("--root needs a directory\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            other if cmd.is_none() && !other.starts_with('-') => cmd = Some(other.to_string()),
            other if cmd.is_some() && !other.starts_with('-') => {
                operands.push(PathBuf::from(other));
            }
            other => {
                eprintln!("unknown option `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    match cmd.as_deref() {
        Some("lint") => {
            if let Some(rule) = explain_rule {
                return match explain(&rule) {
                    Some(text) => {
                        println!("{text}");
                        ExitCode::SUCCESS
                    }
                    None => {
                        eprintln!(
                            "curlint: no rule named `{rule}` (rules: {})",
                            RULE_NAMES.join(", ")
                        );
                        ExitCode::from(2)
                    }
                };
            }
            let sarif_mode = match emit.as_deref() {
                None => false,
                Some("sarif") => true,
                Some(other) => {
                    eprintln!("curlint: unknown --emit format `{other}` (only: sarif)");
                    return ExitCode::from(2);
                }
            };
            let root = match root.or_else(find_repo_root) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "curlint: could not find the repo root (looked for rust/src upward)"
                    );
                    return ExitCode::from(2);
                }
            };
            match run_lint(&root, update, list, sarif_mode) {
                Ok(true) => ExitCode::SUCCESS,
                Ok(false) => ExitCode::from(1),
                Err(e) => {
                    eprintln!("curlint: {e}");
                    ExitCode::from(2)
                }
            }
        }
        Some("bench-check") => {
            let [run] = operands.as_slice() else {
                eprintln!("bench-check needs exactly one run file\n{USAGE}");
                return ExitCode::from(2);
            };
            run_bench_check(run, &require_workloads, require_grid)
        }
        Some("bench-diff") => {
            let [old, new] = operands.as_slice() else {
                eprintln!("bench-diff needs exactly two run files\n{USAGE}");
                return ExitCode::from(2);
            };
            run_bench_diff(old, new, fail_on_regression, fail_on_det_regression, annotate, verbose)
        }
        Some(other) => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            ExitCode::from(2)
        }
        None => {
            eprintln!("missing command\n{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn run_bench_check(path: &Path, require_workloads: &[String], require_grid: bool) -> ExitCode {
    let run = match bench::load_run(path) {
        Ok(run) => run,
        Err(e) => {
            eprintln!("bench-check: {e}");
            return ExitCode::from(2);
        }
    };
    let mut errs = bench::check_invariants(&run);
    for name in require_workloads {
        if !name.is_empty() && run.workload(name).is_none() {
            errs.push(format!("required workload `{name}` is missing"));
        }
    }
    if require_grid && !bench::has_sensitivity_grid(&run) {
        errs.push(
            "no sensitivity grid: expected some workload with >= 2 `grid_*` param \
             axes covering >= 4 points"
                .to_string(),
        );
    }
    println!(
        "bench-check: {} — engine {}, mode {}, date {}, {} workload(s), {} measurement(s)",
        path.display(),
        run.engine,
        run.mode,
        run.date,
        run.workloads.len(),
        run.n_measurements()
    );
    for w in &run.workloads {
        println!("  {:<14} {} measurement(s)", w.name, w.measurements.len());
    }
    if errs.is_empty() {
        println!("bench-check: ok");
        ExitCode::SUCCESS
    } else {
        for e in &errs {
            eprintln!("bench-check: {e}");
        }
        eprintln!("bench-check: FAILED — {} problem(s)", errs.len());
        ExitCode::from(1)
    }
}

fn run_bench_diff(
    old_path: &Path,
    new_path: &Path,
    fail_on_regression: bool,
    fail_on_det_regression: bool,
    annotate: bool,
    verbose: bool,
) -> ExitCode {
    let (old, new) = match (bench::load_run(old_path), bench::load_run(new_path)) {
        (Ok(o), Ok(n)) => (o, n),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-diff: {e}");
            return ExitCode::from(2);
        }
    };
    let report = match bench::diff(&old, &new) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("bench-diff: {e}");
            return ExitCode::from(2);
        }
    };
    println!(
        "bench-diff: {} ({}) -> {} ({})",
        old_path.display(),
        old.commit.as_deref().unwrap_or("no commit"),
        new_path.display(),
        new.commit.as_deref().unwrap_or("no commit")
    );
    print!("{}", bench::render(&report, verbose));
    if annotate {
        for line in bench::annotations(&report) {
            println!("{line}");
        }
    }
    let (_, regressed, _) = report.counts();
    if fail_on_regression && regressed > 0 {
        eprintln!("bench-diff: FAILED — {regressed} regression(s) beyond noise");
        return ExitCode::from(1);
    }
    if fail_on_det_regression {
        if let Some((om, nm)) = &report.mode_mismatch {
            println!(
                "bench-diff: NOTE — runs used different modes ({om} vs {nm}); the \
                 deterministic gate does not apply across modes and was skipped"
            );
        } else {
            let det_regressed = report.n_deterministic_regressions();
            if det_regressed > 0 {
                eprintln!(
                    "bench-diff: FAILED — {det_regressed} deterministic (non-timing) \
                     regression(s); these are bit-accuracy/size invariants, not noise"
                );
                return ExitCode::from(1);
            }
        }
    }
    ExitCode::SUCCESS
}

/// Walk upward from cwd to the first directory containing `rust/src`.
fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries =
            std::fs::read_dir(&d).map_err(|e| format!("read_dir {}: {e}", d.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir {}: {e}", d.display()))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Read every `.rs` under `dir` as `(repo-relative path, source)`.
fn read_sources(root: &Path, dir: &Path) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    for file in rs_files(dir)? {
        let rel = file
            .strip_prefix(root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&file)
            .map_err(|e| format!("read {}: {e}", file.display()))?;
        out.push((rel, src));
    }
    Ok(out)
}

fn run_lint(root: &Path, update: bool, list: bool, sarif_mode: bool) -> Result<bool, String> {
    let baseline_path = root.join("curlint.baseline");

    // Informational lines go to stdout normally, to stderr when stdout
    // carries the SARIF document.
    let say = |line: String| {
        if sarif_mode {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };

    // rust/src gets the full rule set (token + cross-file over the item
    // graph); tests/benches/examples are reference-only for `dead-pub`.
    let lib_sources = read_sources(root, &root.join("rust/src"))?;
    let mut refs_only: Vec<(String, String)> = Vec::new();
    for dir in ["rust/tests", "rust/benches", "rust/examples"] {
        let d = root.join(dir);
        if d.is_dir() {
            refs_only.extend(read_sources(root, &d)?);
        }
    }
    let graph = ItemGraph::build(&lib_sources);
    let mut by_file: BTreeMap<String, Vec<Violation>> = check_repo(&graph, &refs_only);

    // Self-lint: the token rules over xtask/src/** (the linter must hold
    // itself to the invariants it enforces; zero violations, ratcheted
    // through the same baseline).
    let tool_sources = read_sources(root, &root.join("xtask/src"))?;
    for (rel, src) in &tool_sources {
        let violations = check_source(rel, src);
        if !violations.is_empty() {
            by_file.insert(rel.clone(), violations);
        }
    }

    let n_files = lib_sources.len() + tool_sources.len();
    let mut actual = Counts::new();
    let mut total = 0usize;
    for (rel, violations) in &by_file {
        total += violations.len();
        for v in violations {
            *actual.entry((rel.clone(), v.rule.to_string())).or_insert(0) += 1;
        }
    }

    if update {
        std::fs::write(&baseline_path, baseline::serialize(&actual))
            .map_err(|e| format!("write {}: {e}", baseline_path.display()))?;
        say(format!(
            "curlint: baseline rewritten with {total} violation(s) across {} bucket(s)",
            actual.len()
        ));
        return Ok(true);
    }

    let base_text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(format!("read {}: {e}", baseline_path.display())),
    };
    let base = baseline::parse(&base_text)?;

    let comparisons = baseline::compare(&base, &actual);
    let mut grew = 0usize;
    let mut stale = 0usize;
    for ((path, rule), verdict) in &comparisons {
        match verdict {
            Verdict::Grew { allowed, actual } => {
                grew += 1;
                eprintln!(
                    "curlint: {path}: [{rule}] {actual} violation(s), baseline allows \
                     {allowed} — fix them or `// curlint: allow({rule}) -- <reason>`"
                );
            }
            Verdict::Shrank { allowed, actual } => {
                stale += 1;
                say(format!(
                    "curlint: {path}: [{rule}] improved to {actual} (baseline {allowed}) \
                     — tighten with `cargo xtask lint --update-baseline`"
                ));
            }
            Verdict::AtBaseline => {}
        }
    }

    // Print the offending sites: every violation in a grown bucket, or
    // everything under --list. In SARIF mode every violation is emitted,
    // grown buckets as `error`, grandfathered ones as `warning`.
    let bucket_grew = |path: &str, rule: &str| {
        comparisons.iter().any(|((p, r), verdict)| {
            p == path && r == rule && matches!(verdict, Verdict::Grew { .. })
        })
    };
    let mut rows: Vec<sarif::Row> = Vec::new();
    for (path, violations) in &by_file {
        for v in violations {
            let is_new = bucket_grew(path, v.rule);
            if list || is_new {
                say(format!("{path}:{}:{}: [{}] {}", v.line, v.col, v.rule, v.msg));
            }
            if sarif_mode {
                rows.push(sarif::Row {
                    rule: v.rule.to_string(),
                    path: path.clone(),
                    line: v.line,
                    col: v.col,
                    msg: v.msg.clone(),
                    new: is_new,
                });
            }
        }
    }
    if sarif_mode {
        print!("{}", sarif::emit(&rows)?);
    }

    let grandfathered = total
        - comparisons
            .iter()
            .map(|((p, r), _)| {
                let allowed = base.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
                let n = actual.get(&(p.clone(), r.clone())).copied().unwrap_or(0);
                n.saturating_sub(allowed)
            })
            .sum::<usize>();
    say(format!(
        "curlint: {total} violation(s) ({grandfathered} grandfathered, {n_files} file(s) \
         scanned){}",
        if stale > 0 { ", baseline is stale" } else { "" }
    ));
    if grew > 0 {
        eprintln!("curlint: FAILED — {grew} bucket(s) above the baseline");
        return Ok(false);
    }
    say("curlint: ok".to_string());
    Ok(true)
}

//! `cargo xtask bench-check` / `bench-diff` — the reader side of the
//! recorded-run format (`BENCH_native.json`, schema v2, written by
//! `rust/src/util/record.rs`).
//!
//! `bench-check` validates a recorded run: strict schema (every
//! measurement has a finite value and a known, oriented unit) plus the
//! semantic invariants CI used to check with inline scripts — the
//! CUR-KV live-bytes orderings and the Du heal-loss trend.
//!
//! `bench-diff` compares two recorded runs per measurement: the unit
//! decides which direction is an improvement, and the recorded CVs set
//! a per-row noise threshold, so a change only counts as a regression
//! when it exceeds what the samples say is noise. A unit mismatch
//! between the runs is a hard error — a number that changed meaning
//! cannot be classified.

use crate::json::{parse, Value};
use std::path::Path;

/// Whether a bigger number is better, worse, or neither.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Higher,
    Lower,
    Neutral,
}

/// The closed unit table — must match `Unit::ALL` in
/// `rust/src/util/record.rs` (CI runs bench-check on a freshly
/// generated file, so drift between the two tables fails fast).
pub const KNOWN_UNITS: &[(&str, Direction)] = &[
    ("tokens/s", Direction::Higher),
    ("steps/s", Direction::Higher),
    ("ms/iter", Direction::Lower),
    ("s", Direction::Lower),
    ("bytes", Direction::Lower),
    ("ratio", Direction::Higher),
    ("nats", Direction::Lower),
    ("ppl", Direction::Lower),
    ("count", Direction::Neutral),
];

pub fn unit_direction(unit: &str) -> Option<Direction> {
    KNOWN_UNITS.iter().find(|(u, _)| *u == unit).map(|(_, d)| *d)
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub value: f64,
    pub unit: String,
    pub iters: usize,
    pub cv: f64,
    pub deterministic: bool,
    pub n_samples: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub name: String,
    pub params: Vec<(String, Value)>,
    pub measurements: Vec<(String, Measurement)>,
    pub series: Vec<(String, Vec<f64>)>,
}

impl Workload {
    pub fn measurement(&self, key: &str) -> Option<&Measurement> {
        self.measurements.iter().find(|(k, _)| k == key).map(|(_, m)| m)
    }
}

#[derive(Debug, Clone)]
pub struct Run {
    pub engine: String,
    pub commit: Option<String>,
    pub date: String,
    pub mode: String,
    pub workloads: Vec<Workload>,
}

impl Run {
    pub fn workload(&self, name: &str) -> Option<&Workload> {
        self.workloads.iter().find(|w| w.name == name)
    }

    pub fn n_measurements(&self) -> usize {
        self.workloads.iter().map(|w| w.measurements.len()).sum()
    }
}

pub fn load_run(path: &Path) -> Result<Run, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    let v = parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_run(&v).map_err(|e| format!("{}: {e}", path.display()))
}

/// Strict v2 parse. Every violation is an error, not a warning: an
/// unreadable barometer is worse than none.
pub fn parse_run(v: &Value) -> Result<Run, String> {
    let schema = v.get("schema").and_then(Value::as_f64);
    if schema != Some(2.0) {
        return Err(format!(
            "schema must be 2 (recorded-run v2), found {:?} — v1 files are only \
             readable by the library's migration path, regenerate with `cargo bench`",
            schema
        ));
    }
    let ws = v
        .get("workloads")
        .and_then(Value::as_obj)
        .ok_or_else(|| "no `workloads` object".to_string())?;
    let mut run = Run {
        engine: v.get("engine").and_then(Value::as_str).unwrap_or("unknown").to_string(),
        commit: v.get("commit").and_then(Value::as_str).map(str::to_string),
        date: v.get("date").and_then(Value::as_str).unwrap_or("").to_string(),
        mode: v.get("mode").and_then(Value::as_str).unwrap_or("full").to_string(),
        workloads: Vec::new(),
    };
    for (name, wv) in ws {
        run.workloads.push(parse_workload(name, wv)?);
    }
    Ok(run)
}

fn parse_workload(name: &str, v: &Value) -> Result<Workload, String> {
    let mut w = Workload { name: name.to_string(), ..Default::default() };
    if let Some(params) = v.get("params").and_then(Value::as_obj) {
        w.params = params.to_vec();
    }
    let ms = v
        .get("measurements")
        .and_then(Value::as_obj)
        .ok_or_else(|| format!("workload `{name}` has no `measurements` object"))?;
    for (key, mv) in ms {
        let ctx = format!("{name}.{key}");
        let value = mv
            .get("value")
            .and_then(Value::as_f64)
            .ok_or_else(|| format!("{ctx}: no numeric `value`"))?;
        if !value.is_finite() {
            return Err(format!("{ctx}: non-finite value"));
        }
        let unit = mv
            .get("unit")
            .and_then(Value::as_str)
            .ok_or_else(|| format!("{ctx}: no `unit`"))?
            .to_string();
        if unit_direction(&unit).is_none() {
            return Err(format!("{ctx}: unknown unit `{unit}`"));
        }
        let iters = mv.get("iters").and_then(Value::as_f64).unwrap_or(1.0);
        if iters < 1.0 {
            return Err(format!("{ctx}: iters < 1"));
        }
        let cv = mv.get("cv").and_then(Value::as_f64).unwrap_or(0.0);
        if !cv.is_finite() || cv < 0.0 {
            return Err(format!("{ctx}: bad cv {cv}"));
        }
        let deterministic = match mv.get("deterministic") {
            Some(Value::Bool(b)) => *b,
            Some(_) => return Err(format!("{ctx}: `deterministic` is not a bool")),
            None => return Err(format!("{ctx}: no `deterministic` flag")),
        };
        let n_samples = match mv.get("samples") {
            Some(Value::Arr(a)) => {
                if a.iter().any(|s| s.as_f64().map(|f| !f.is_finite()).unwrap_or(true)) {
                    return Err(format!("{ctx}: non-numeric samples"));
                }
                a.len()
            }
            Some(_) => return Err(format!("{ctx}: `samples` is not an array")),
            None => 0,
        };
        w.measurements.push((
            key.to_string(),
            Measurement { value, unit, iters: iters as usize, cv, deterministic, n_samples },
        ));
    }
    if let Some(series) = v.get("series").and_then(Value::as_obj) {
        for (key, sv) in series {
            let arr =
                sv.as_arr().ok_or_else(|| format!("{name}.series.{key}: not an array"))?;
            let mut vals = Vec::with_capacity(arr.len());
            for x in arr {
                match x.as_f64() {
                    Some(f) if f.is_finite() => vals.push(f),
                    _ => return Err(format!("{name}.series.{key}: non-numeric entries")),
                }
            }
            w.series.push((key.to_string(), vals));
        }
    }
    Ok(w)
}

// ------------------------------------------------------------- invariants

/// Split a grid-point key `metric[a=1,b=0.5]` into the metric name and
/// its coordinates. A bare key returns no coordinates.
pub fn split_key(key: &str) -> (&str, Vec<(&str, &str)>) {
    let Some(open) = key.find('[') else { return (key, Vec::new()) };
    if !key.ends_with(']') {
        return (key, Vec::new());
    }
    let base = &key[..open];
    let coords = key[open + 1..key.len() - 1]
        .split(',')
        .filter_map(|kv| kv.split_once('='))
        .collect();
    (base, coords)
}

/// Semantic invariants over a validated run — the checks CI previously
/// ran as an inline script against the v1 file. Only workloads that are
/// present are checked. Returns human-readable failures.
pub fn check_invariants(run: &Run) -> Vec<String> {
    let mut errs = Vec::new();
    if let Some(kv) = run.workload("kv_cur") {
        check_kv_cur(kv, &mut errs);
    }
    if let Some(heal) = run.workload("peft_heal") {
        match heal.series.iter().find(|(k, _)| k == "du_loss") {
            None => errs.push("peft_heal: no `du_loss` series".to_string()),
            Some((_, s)) => {
                if s.len() < 20 {
                    errs.push(format!("peft_heal: du_loss series has {} steps (< 20)", s.len()));
                } else {
                    let q = s.len() / 4;
                    let head: f64 = s[..q].iter().sum::<f64>() / q as f64;
                    let tail: f64 = s[s.len() - q..].iter().sum::<f64>() / q as f64;
                    if tail >= head {
                        errs.push(format!(
                            "peft_heal: du_loss does not trend down (first-quarter mean \
                             {head:.4}, last-quarter mean {tail:.4})"
                        ));
                    }
                }
            }
        }
    }
    errs
}

/// The CUR-KV cache must actually shrink: every live-bytes point sits
/// under the exact-ring bound, and at fixed (slots, prompt) the
/// footprint is monotone in the keep ratio (with slack — live bytes
/// are a scheduling-dependent mean).
fn check_kv_cur(kv: &Workload, errs: &mut Vec<String>) {
    let Some(bound) = kv.measurement("exact_slot_bytes").map(|m| m.value) else {
        errs.push("kv_cur: no `exact_slot_bytes` measurement".to_string());
        return;
    };
    // (other-coords, keep, live-bytes) triples from live_bytes[...] keys.
    let mut points: Vec<(String, f64, f64)> = Vec::new();
    for (key, m) in &kv.measurements {
        let (base, coords) = split_key(key);
        if base != "live_bytes" {
            continue;
        }
        if m.value > bound * 1.001 {
            errs.push(format!("kv_cur: {key} = {:.0} exceeds exact bound {bound:.0}", m.value));
        }
        let mut keep = None;
        let mut others = Vec::new();
        for (ck, cv) in coords {
            if ck == "keep" {
                keep = cv.parse::<f64>().ok();
            } else {
                others.push(format!("{ck}={cv}"));
            }
        }
        if let Some(keep) = keep {
            points.push((others.join(","), keep, m.value));
        }
    }
    // Monotone in keep per fixed other-coords: lower keep must not hold
    // more bytes (10% slack for the scheduling-dependent mean).
    points.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    for pair in points.windows(2) {
        let (g0, k0, v0) = &pair[0];
        let (g1, k1, v1) = &pair[1];
        if g0 == g1 && k0 < k1 && *v0 > *v1 * 1.10 {
            errs.push(format!(
                "kv_cur[{g0}]: live bytes not monotone in keep \
                 (keep={k0}: {v0:.0} B > keep={k1}: {v1:.0} B)"
            ));
        }
    }
}

/// `--require-grid`: at least one workload swept a real sensitivity
/// mesh (>= 2 grid axes whose cartesian product covers >= 4 points).
pub fn has_sensitivity_grid(run: &Run) -> bool {
    run.workloads.iter().any(|w| {
        let axes: Vec<usize> = w
            .params
            .iter()
            .filter(|(k, _)| k.starts_with("grid_"))
            .filter_map(|(_, v)| v.as_arr().map(<[Value]>::len))
            .collect();
        axes.len() >= 2 && axes.iter().product::<usize>() >= 4
    })
}

// ------------------------------------------------------------------ diff

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    Improved,
    Regressed,
    Neutral,
}

/// One measurement present in both runs, classified.
#[derive(Debug, Clone)]
pub struct Delta {
    pub workload: String,
    pub key: String,
    pub unit: String,
    pub old: f64,
    pub new: f64,
    /// Relative change (new-old)/|old|; +-inf when old == 0 != new.
    pub rel: f64,
    /// Noise threshold this row had to clear: max(3%, 2*cv_old, 2*cv_new).
    pub threshold: f64,
    /// Both sides recorded this measurement as deterministic (a
    /// non-timing quantity — bytes, counts, losses): a regression here
    /// is a semantic change, never noise, so it can gate CI even when
    /// timing rows cannot.
    pub deterministic: bool,
    pub class: Class,
}

#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub deltas: Vec<Delta>,
    /// Measurements only in the new run, as (workload, key).
    pub added: Vec<(String, String)>,
    /// Measurements only in the old run, as (workload, key).
    pub removed: Vec<(String, String)>,
    pub added_workloads: Vec<String>,
    pub removed_workloads: Vec<String>,
    /// Set when the runs were recorded in different modes (quick vs
    /// full) — the deltas are then apples to oranges.
    pub mode_mismatch: Option<(String, String)>,
}

impl DiffReport {
    pub fn counts(&self) -> (usize, usize, usize) {
        let improved = self.deltas.iter().filter(|d| d.class == Class::Improved).count();
        let regressed = self.deltas.iter().filter(|d| d.class == Class::Regressed).count();
        (improved, regressed, self.deltas.len() - improved - regressed)
    }

    /// Regressions on rows both runs recorded as deterministic — the
    /// subset `--fail-on-regression-deterministic` gates on.
    pub fn n_deterministic_regressions(&self) -> usize {
        self.deltas
            .iter()
            .filter(|d| d.class == Class::Regressed && d.deterministic)
            .count()
    }
}

/// Compare two recorded runs measurement by measurement. A shared key
/// whose unit changed between the runs is a hard error.
pub fn diff(old: &Run, new: &Run) -> Result<DiffReport, String> {
    let mut report = DiffReport::default();
    if old.mode != new.mode {
        report.mode_mismatch = Some((old.mode.clone(), new.mode.clone()));
    }
    let mut unit_errors = Vec::new();
    for nw in &new.workloads {
        let Some(ow) = old.workload(&nw.name) else {
            report.added_workloads.push(nw.name.clone());
            continue;
        };
        for (key, nm) in &nw.measurements {
            let Some(om) = ow.measurement(key) else {
                report.added.push((nw.name.clone(), key.clone()));
                continue;
            };
            if om.unit != nm.unit {
                unit_errors.push(format!(
                    "{}.{key}: unit changed {} -> {}",
                    nw.name, om.unit, nm.unit
                ));
                continue;
            }
            report.deltas.push(classify(&nw.name, key, om, nm));
        }
        for (key, _) in &ow.measurements {
            if nw.measurement(key).is_none() {
                report.removed.push((nw.name.clone(), key.clone()));
            }
        }
    }
    for ow in &old.workloads {
        if new.workload(&ow.name).is_none() {
            report.removed_workloads.push(ow.name.clone());
        }
    }
    if !unit_errors.is_empty() {
        return Err(format!(
            "unit mismatch between runs (a number that changed meaning cannot be \
             classified):\n  {}",
            unit_errors.join("\n  ")
        ));
    }
    Ok(report)
}

fn classify(workload: &str, key: &str, om: &Measurement, nm: &Measurement) -> Delta {
    let rel = if om.value == 0.0 {
        if nm.value == 0.0 {
            0.0
        } else if nm.value > 0.0 {
            f64::INFINITY
        } else {
            f64::NEG_INFINITY
        }
    } else {
        (nm.value - om.value) / om.value.abs()
    };
    let threshold = 0.03_f64.max(2.0 * om.cv).max(2.0 * nm.cv);
    let dir = unit_direction(&om.unit).unwrap_or(Direction::Neutral);
    let class = if rel.abs() <= threshold || dir == Direction::Neutral {
        Class::Neutral
    } else if (rel > 0.0) == (dir == Direction::Higher) {
        Class::Improved
    } else {
        Class::Regressed
    };
    Delta {
        workload: workload.to_string(),
        key: key.to_string(),
        unit: om.unit.clone(),
        old: om.value,
        new: nm.value,
        rel,
        threshold,
        deterministic: om.deterministic && nm.deterministic,
        class,
    }
}

// ------------------------------------------------------------- rendering

fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

fn fmt_rel(rel: f64) -> String {
    if rel.is_infinite() {
        if rel > 0.0 { "+inf%".to_string() } else { "-inf%".to_string() }
    } else {
        format!("{:+.1}%", 100.0 * rel)
    }
}

/// Human delta report: per-workload tables of changed rows (neutral
/// rows are summarized, not listed, unless `verbose`).
pub fn render(report: &DiffReport, verbose: bool) -> String {
    let mut out = String::new();
    if let Some((om, nm)) = &report.mode_mismatch {
        out.push_str(&format!(
            "WARNING: comparing a `{om}` run against a `{nm}` run — \
             iteration policies differ, deltas are indicative only\n\n"
        ));
    }
    let mut by_workload: Vec<&str> = report.deltas.iter().map(|d| d.workload.as_str()).collect();
    by_workload.dedup();
    for w in by_workload {
        let rows: Vec<&Delta> = report
            .deltas
            .iter()
            .filter(|d| d.workload == w && (verbose || d.class != Class::Neutral))
            .collect();
        let n_all = report.deltas.iter().filter(|d| d.workload == w).count();
        out.push_str(&format!("workload {w} ({n_all} shared measurement(s))\n"));
        if rows.is_empty() {
            out.push_str("  all within noise\n");
        }
        for d in rows {
            let glyph = match d.class {
                Class::Improved => "improved ",
                Class::Regressed => "REGRESSED",
                Class::Neutral => "neutral  ",
            };
            out.push_str(&format!(
                "  {glyph} {:<52} {:>14} -> {:>14} {:<8} ({}, noise {:.1}%)\n",
                d.key,
                fmt_num(d.old),
                fmt_num(d.new),
                d.unit,
                fmt_rel(d.rel),
                100.0 * d.threshold
            ));
        }
    }
    for (w, k) in &report.added {
        out.push_str(&format!("added   {w}.{k}\n"));
    }
    for (w, k) in &report.removed {
        out.push_str(&format!("removed {w}.{k}\n"));
    }
    for w in &report.added_workloads {
        out.push_str(&format!("added workload   {w}\n"));
    }
    for w in &report.removed_workloads {
        out.push_str(&format!("removed workload {w}\n"));
    }
    let (improved, regressed, neutral) = report.counts();
    out.push_str(&format!(
        "\n{improved} improved, {regressed} regressed, {neutral} within noise\n"
    ));
    out
}

/// GitHub Actions annotations for regressions (non-blocking warnings).
pub fn annotations(report: &DiffReport) -> Vec<String> {
    report
        .deltas
        .iter()
        .filter(|d| d.class == Class::Regressed)
        .map(|d| {
            format!(
                "::warning title=bench regression::{}.{} {} -> {} {} ({}, noise {:.1}%)",
                d.workload,
                d.key,
                fmt_num(d.old),
                fmt_num(d.new),
                d.unit,
                fmt_rel(d.rel),
                100.0 * d.threshold
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_grid_keys() {
        let (base, coords) = split_key("live_bytes[keep=0.5,slots=4]");
        assert_eq!(base, "live_bytes");
        assert_eq!(coords, vec![("keep", "0.5"), ("slots", "4")]);
        assert_eq!(split_key("plain").0, "plain");
        assert!(split_key("plain").1.is_empty());
    }

    #[test]
    fn unit_table_is_oriented() {
        assert_eq!(unit_direction("tokens/s"), Some(Direction::Higher));
        assert_eq!(unit_direction("ms/iter"), Some(Direction::Lower));
        assert_eq!(unit_direction("count"), Some(Direction::Neutral));
        assert_eq!(unit_direction("furlongs"), None);
    }
}

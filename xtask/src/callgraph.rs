//! The approximate call graph for curlint v2's cross-file rules, built
//! on [`crate::itemgraph`]. Resolution is *documented approximation*,
//! tuned so that imprecision errs toward over-approximating
//! reachability (purity stays strict) and under-approximating liveness
//! evidence only where a miss would flag working code:
//!
//! * **Free calls** `f(…)` resolve through the caller's module, its
//!   `use` imports (aliases included), then glob imports.
//! * **Path calls** `a::b::f(…)` resolve `crate`/`self`/`super`/`Self`
//!   prefixes, imported names, and child `mod`s; `Type::method(…)`
//!   falls back to the `(self type, name)` method index.
//! * **Method calls** `.m(…)` resolve *receiver-agnostically*: every
//!   crate method named `m` becomes a callee — except names on
//!   [`STD_METHODS`], which collide with std prelude methods on
//!   slices/`Vec`/`Option`/iterators and would connect essentially all
//!   code to all code. A crate method shadowing a std name is still
//!   reachable via `Type::name(…)` paths and free calls.
//! * Macro bodies are scanned as ordinary tokens; turbofish and
//!   `<T as Trait>::` paths are skipped (unresolvable without types).
//!
//! The three rule passes on top:
//!
//! * [`CallGraph::hot_path_purity`] — BFS from `// curlint: hot-entry`
//!   fns plus every fn in [`crate::rules::KERNEL_MODULES`] (the v1
//!   `kernel-purity` floor, kept as a strict superset); each reachable
//!   fn body must pass [`crate::rules::purity_scan`]. Kernel-module
//!   files are skipped here only because `check_source` already scans
//!   them wholesale under the same rule name.
//! * [`CallGraph::typed_error`] — pub fns (including pub-trait default
//!   methods) in `serve/` and `backend/` returning `Result` must not
//!   construct `anyhow!("…")` / `bail!("…")` with a bare message.
//! * [`CallGraph::dead_pub`] — plain-`pub` non-method items whose name
//!   never appears in any *other* file (crate sources plus the
//!   tests/benches/examples reference set) are flagged.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::itemgraph::{ItemGraph, ItemKind, Vis};
use crate::lexer::TokKind;
use crate::rules::{purity_scan, suffix_match, Violation, KERNEL_MODULES};

/// Method names shared with std prelude types. Receiver-agnostic `.m(`
/// edges on these are suppressed (see module docs).
const STD_METHODS: &[&str] = &[
    "abs", "and_then", "as_bytes", "as_mut", "as_ref", "as_slice", "as_str",
    "ceil", "chunks", "clear", "clone", "cloned", "cmp", "collect",
    "contains", "contains_key", "copied", "copy_from_slice", "count",
    "default", "drain", "drop", "entry", "enumerate", "eq", "err", "extend",
    "fill", "filter", "filter_map", "first", "flat_map", "flatten", "floor",
    "flush", "fmt", "fold", "from", "get", "get_mut", "get_or_insert_with",
    "hash", "insert", "into", "into_iter", "is_empty", "is_some", "is_none",
    "iter", "iter_mut", "join", "last", "len", "lock", "map", "map_err",
    "max", "min", "next", "ok", "or_else", "parse", "pop", "position",
    "push", "read", "recv", "remove", "replace", "resize", "rev", "reverse",
    "send", "skip", "sort", "spawn", "split_at", "sqrt", "sum", "swap",
    "take", "to_owned", "to_string", "to_vec", "truncate", "try_into",
    "unwrap_or", "unwrap_or_default", "unwrap_or_else", "windows", "write",
    "zip",
];

/// Keywords and tuple-ctor lookalikes that sit before `(` without being
/// fn calls.
const NOT_CALLS: &[&str] = &[
    "if", "else", "match", "while", "for", "loop", "return", "in", "as",
    "let", "fn", "move", "mut", "ref", "box", "await", "where", "impl",
    "dyn", "Some", "None", "Ok", "Err", "Box", "Vec", "String",
];

pub struct CallGraph<'a> {
    g: &'a ItemGraph,
    /// `calls[caller item idx] -> callee item idxs` (fn items only).
    calls: BTreeMap<usize, Vec<usize>>,
    /// Trait names declared `pub` (for effective-pub of default methods).
    pub_traits: BTreeSet<String>,
}

impl<'a> CallGraph<'a> {
    pub fn build(g: &'a ItemGraph) -> CallGraph<'a> {
        // ---- indexes
        let mut free: BTreeMap<(Vec<String>, String), Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut typed: BTreeMap<(String, String), Vec<usize>> = BTreeMap::new();
        let mut child_mods: BTreeSet<(Vec<String>, String)> = BTreeSet::new();
        let mut pub_traits: BTreeSet<String> = BTreeSet::new();
        for (idx, it) in g.items.iter().enumerate() {
            match it.kind {
                ItemKind::Fn => {
                    if it.is_method {
                        methods.entry(it.name.clone()).or_default().push(idx);
                        if let Some(ty) = &it.self_ty {
                            typed.entry((ty.clone(), it.name.clone())).or_default().push(idx);
                        }
                    } else {
                        free.entry((it.module.clone(), it.name.clone()))
                            .or_default()
                            .push(idx);
                    }
                }
                ItemKind::Mod => {
                    child_mods.insert((it.module.clone(), it.name.clone()));
                }
                ItemKind::Trait => {
                    if it.vis == Vis::Pub {
                        pub_traits.insert(it.name.clone());
                    }
                }
                _ => {}
            }
        }
        let mut named_imports: BTreeMap<(Vec<String>, String), Vec<Vec<String>>> =
            BTreeMap::new();
        let mut globs: BTreeMap<Vec<String>, Vec<Vec<String>>> = BTreeMap::new();
        for im in &g.imports {
            if im.glob {
                globs.entry(im.module.clone()).or_default().push(im.target.clone());
            } else {
                named_imports
                    .entry((im.module.clone(), im.name.clone()))
                    .or_default()
                    .push(im.target.clone());
            }
        }

        // Resolve one absolute candidate path (`…::name`) to fn items.
        let resolve_abs = |path: &[String]| -> Vec<usize> {
            let Some((name, modpath)) = path.split_last() else { return Vec::new() };
            let mut out = Vec::new();
            if let Some(fns) = free.get(&(modpath.to_vec(), name.clone())) {
                out.extend_from_slice(fns);
            }
            // `…::Type::method` — the second-to-last segment as a type.
            if out.is_empty() {
                if let Some(ty) = modpath.last() {
                    if let Some(ms) = typed.get(&(ty.clone(), name.clone())) {
                        out.extend_from_slice(ms);
                    }
                }
            }
            out
        };

        // ---- edge extraction
        let mut calls: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for idx in g.fns() {
            let it = &g.items[idx];
            let Some((a, b)) = it.body else { continue };
            let toks = &g.files[it.file].toks;
            let mut out: Vec<usize> = Vec::new();
            let text = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
            for i in a..b.min(toks.len()) {
                let t = &toks[i];
                if t.kind != TokKind::Ident || text(i + 1) != "(" {
                    continue;
                }
                let name = t.text.as_str();
                let prev = if i > 0 { text(i - 1) } else { "" };
                if prev == "." {
                    // Receiver-agnostic method call.
                    if !STD_METHODS.contains(&name) {
                        if let Some(ms) = methods.get(name) {
                            out.extend_from_slice(ms);
                        }
                    }
                    continue;
                }
                if prev == "fn" || NOT_CALLS.contains(&name) || text(i + 1) == "!" {
                    continue;
                }
                let prev2 = if i > 1 { text(i - 2) } else { "" };
                if prev == ":" && prev2 == ":" {
                    // Path call: walk `ident::`* segments backwards.
                    let mut segs = vec![name.to_string()];
                    let mut j = i;
                    let mut bad = false;
                    while j >= 2 && text(j - 1) == ":" && text(j - 2) == ":" {
                        if j >= 3 && toks[j - 3].kind == TokKind::Ident {
                            segs.push(toks[j - 3].text.clone());
                            j -= 3;
                        } else {
                            // turbofish / `<T as Trait>::` — unresolvable.
                            bad = true;
                            break;
                        }
                    }
                    if bad {
                        continue;
                    }
                    segs.reverse();
                    for cand in candidate_paths(
                        &segs,
                        &it.module,
                        it.self_ty.as_deref(),
                        &named_imports,
                        &globs,
                        &child_mods,
                    ) {
                        out.extend(resolve_abs(&cand));
                    }
                    // Unqualified `Type::method(` with a local/glob type.
                    if segs.len() == 2 {
                        if let Some(ms) = typed.get(&(segs[0].clone(), segs[1].clone())) {
                            out.extend_from_slice(ms);
                        }
                    }
                    continue;
                }
                // Bare call: same module, then imports, then globs.
                let mut hit = false;
                if let Some(fns) = free.get(&(it.module.clone(), name.to_string())) {
                    out.extend_from_slice(fns);
                    hit = true;
                }
                if !hit {
                    if let Some(targets) =
                        named_imports.get(&(it.module.clone(), name.to_string()))
                    {
                        for tgt in targets {
                            out.extend(resolve_abs(tgt));
                        }
                    }
                    if let Some(gs) = globs.get(&it.module) {
                        for gmod in gs {
                            let mut p = gmod.clone();
                            p.push(name.to_string());
                            out.extend(resolve_abs(&p));
                        }
                    }
                }
            }
            out.sort_unstable();
            out.dedup();
            calls.insert(idx, out);
        }
        CallGraph { g, calls, pub_traits }
    }

    /// BFS from hot entries (annotated fns plus every fn defined in a
    /// [`KERNEL_MODULES`] file). Returns `fn idx -> BFS parent`
    /// (entries map to themselves). Test fns are never entered.
    fn hot_reach(&self) -> BTreeMap<usize, usize> {
        let mut parent: BTreeMap<usize, usize> = BTreeMap::new();
        let mut queue: VecDeque<usize> = VecDeque::new();
        for idx in self.g.fns() {
            let it = &self.g.items[idx];
            if it.in_test {
                continue;
            }
            let in_kernel_file =
                KERNEL_MODULES.iter().any(|k| suffix_match(&self.g.files[it.file].path, k));
            if it.hot_entry || in_kernel_file {
                parent.insert(idx, idx);
                queue.push_back(idx);
            }
        }
        while let Some(cur) = queue.pop_front() {
            if let Some(callees) = self.calls.get(&cur) {
                for &next in callees {
                    if self.g.items[next].in_test || parent.contains_key(&next) {
                        continue;
                    }
                    parent.insert(next, cur);
                    queue.push_back(next);
                }
            }
        }
        parent
    }

    /// Names of all hot-reachable fns (test hook).
    pub fn hot_fn_names(&self) -> BTreeSet<String> {
        self.hot_reach().keys().map(|&i| self.g.items[i].name.clone()).collect()
    }

    /// The `entry → … → fn` chain for one reachable fn, as names.
    fn chain(&self, parent: &BTreeMap<usize, usize>, mut idx: usize) -> String {
        let mut names = vec![self.g.items[idx].name.clone()];
        while let Some(&p) = parent.get(&idx) {
            if p == idx {
                break;
            }
            names.push(self.g.items[p].name.clone());
            idx = p;
        }
        names.reverse();
        names.join(" → ")
    }

    /// `hot-path-purity`: every hot-reachable fn body passes
    /// [`purity_scan`]. Returns `(file idx, violation)` pre-pragma.
    pub fn hot_path_purity(&self) -> Vec<(usize, Violation)> {
        let parent = self.hot_reach();
        let mut out = Vec::new();
        for (&idx, _) in &parent {
            let it = &self.g.items[idx];
            let Some(span) = it.body else { continue };
            let file = &self.g.files[it.file];
            // Kernel-module files are scanned wholesale by `check_source`
            // under the same rule name; skipping avoids double reports.
            if KERNEL_MODULES.iter().any(|k| suffix_match(&file.path, k)) {
                continue;
            }
            for mut v in purity_scan(&file.toks, span, &[]) {
                v.msg = format!("{} (hot path: {})", v.msg, self.chain(&parent, idx));
                out.push((it.file, v));
            }
        }
        out
    }

    /// Whether a fn is callable from outside the crate-internal module
    /// tree: declared `pub`, or a default method of a `pub trait`.
    fn effective_pub(&self, idx: usize) -> bool {
        let it = &self.g.items[idx];
        it.vis == Vis::Pub
            || (it.is_method
                && it.self_ty.as_deref().is_some_and(|ty| self.pub_traits.contains(ty)))
    }

    /// `typed-error`: pub fns in `serve/` and `backend/` returning
    /// `Result` must not build bare-message `anyhow!` / `bail!` errors
    /// (string or `format!` first argument — a typed payload like
    /// `bail!(ServeError::Overloaded)` stays downcastable and passes).
    pub fn typed_error(&self) -> Vec<(usize, Violation)> {
        let mut out = Vec::new();
        for idx in self.g.fns() {
            let it = &self.g.items[idx];
            let boundary = matches!(
                it.module.first().map(String::as_str),
                Some("serve") | Some("backend")
            );
            if !boundary || it.in_test || !it.returns_result || !self.effective_pub(idx) {
                continue;
            }
            let Some((a, b)) = it.body else { continue };
            let toks = &self.g.files[it.file].toks;
            let text = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
            for i in a..b.min(toks.len()) {
                let t = &toks[i];
                if t.kind != TokKind::Ident
                    || !(t.text == "anyhow" || t.text == "bail")
                    || text(i + 1) != "!"
                    || text(i + 2) != "("
                {
                    continue;
                }
                let first_arg = toks.get(i + 3);
                let bare = match first_arg {
                    Some(arg) => arg.kind == TokKind::Str || arg.text == "format",
                    None => false,
                };
                if bare {
                    out.push((
                        it.file,
                        Violation {
                            rule: "typed-error",
                            line: t.line,
                            col: t.col,
                            msg: format!(
                                "bare `{}!(\"…\")` in pub `{}` — callers can't downcast; \
                                 wrap a typed error (`ServeError`, `BackendError`, …)",
                                t.text, it.name
                            ),
                        },
                    ));
                }
            }
        }
        out
    }

    /// `dead-pub`: plain-`pub`, non-method, non-test items defined at
    /// file level whose name never occurs in any other source file
    /// (including `refs_only` — tests/benches/examples scanned for
    /// references without being linted). Name collisions make this
    /// under-report, never over-report.
    pub fn dead_pub(&self, refs_only: &[(String, String)]) -> Vec<(usize, Violation)> {
        // name -> set of graph-file idxs where it occurs as an ident.
        let mut occurs: BTreeMap<&str, BTreeSet<usize>> = BTreeMap::new();
        for (fi, f) in self.g.files.iter().enumerate() {
            for t in &f.toks {
                if t.kind == TokKind::Ident {
                    occurs.entry(t.text.as_str()).or_default().insert(fi);
                }
            }
        }
        let mut extern_names: BTreeSet<String> = BTreeSet::new();
        for (_, src) in refs_only {
            for t in crate::lexer::lex(src).0 {
                if t.kind == TokKind::Ident {
                    extern_names.insert(t.text);
                }
            }
        }
        let mut out = Vec::new();
        for (idx, it) in self.g.items.iter().enumerate() {
            let file = &self.g.files[it.file];
            let file_is_lib = file.path.ends_with("lib.rs") || file.path.ends_with("main.rs");
            if it.vis != Vis::Pub
                || it.in_test
                // Associated items (methods, associated consts/types) are
                // reachable via their receiver; name-occurrence counting
                // cannot see that, so they are out of scope.
                || it.is_method
                || it.module != self.g.files[it.file].module
                || file.module.first().map(String::as_str).unwrap_or("").starts_with('%')
            {
                continue;
            }
            // `pub mod` declarations in lib.rs are the crate surface.
            if file_is_lib && it.kind == ItemKind::Mod {
                continue;
            }
            let referenced_elsewhere = occurs
                .get(it.name.as_str())
                .is_some_and(|fs| fs.iter().any(|&fi| fi != it.file))
                || extern_names.contains(&it.name);
            if !referenced_elsewhere {
                out.push((
                    it.file,
                    Violation {
                        rule: "dead-pub",
                        line: it.line,
                        col: it.col,
                        msg: format!(
                            "pub {} `{}` is never referenced outside {} — reduce \
                             visibility or justify with a pragma",
                            kind_word(it.kind),
                            it.name,
                            file.path
                        ),
                    },
                ));
            }
        }
        out
    }
}

fn kind_word(k: ItemKind) -> &'static str {
    match k {
        ItemKind::Fn => "fn",
        ItemKind::Struct => "struct",
        ItemKind::Enum => "enum",
        ItemKind::Union => "union",
        ItemKind::Trait => "trait",
        ItemKind::Const => "const",
        ItemKind::Static => "static",
        ItemKind::TypeAlias => "type",
        ItemKind::Mod => "mod",
    }
}

/// Absolute candidate paths for one `a::…::f` path call from `module`.
fn candidate_paths(
    segs: &[String],
    module: &[String],
    self_ty: Option<&str>,
    named_imports: &BTreeMap<(Vec<String>, String), Vec<Vec<String>>>,
    globs: &BTreeMap<Vec<String>, Vec<Vec<String>>>,
    child_mods: &BTreeSet<(Vec<String>, String)>,
) -> Vec<Vec<String>> {
    let mut out: Vec<Vec<String>> = Vec::new();
    let first = segs[0].as_str();
    match first {
        "crate" => out.push(segs[1..].to_vec()),
        "self" => {
            let mut p = module.to_vec();
            p.extend_from_slice(&segs[1..]);
            out.push(p);
        }
        "super" => {
            let mut p = module.to_vec();
            let mut rest = segs;
            while rest.first().map(String::as_str) == Some("super") {
                p.pop();
                rest = &rest[1..];
            }
            p.extend_from_slice(rest);
            out.push(p);
        }
        "Self" => {
            if let Some(ty) = self_ty {
                // `Self::helper(…)` — rewrite to `Type::helper`-shaped.
                let mut p = module.to_vec();
                p.push(ty.to_string());
                p.extend_from_slice(&segs[1..]);
                out.push(p);
            }
        }
        _ => {
            if let Some(targets) = named_imports.get(&(module.to_vec(), first.to_string())) {
                for tgt in targets {
                    let mut p = tgt.clone();
                    p.extend_from_slice(&segs[1..]);
                    out.push(p);
                }
            }
            if child_mods.contains(&(module.to_vec(), first.to_string())) {
                let mut p = module.to_vec();
                p.extend_from_slice(segs);
                out.push(p);
            }
            if let Some(gs) = globs.get(module) {
                for gmod in gs {
                    let mut p = gmod.clone();
                    p.extend_from_slice(segs);
                    out.push(p);
                }
            }
        }
    }
    out
}

//! The violation ratchet: `curlint.baseline` grandfathers the long tail
//! of pre-existing violations per `(file, rule)` while CI guarantees the
//! counts only ever shrink. Burned-down modules simply have no entry.
//!
//! Format (one grandfathered bucket per line, `#` comments allowed):
//!
//! ```text
//! <count> <rule> <path>
//! ```

use std::collections::BTreeMap;

/// `(path, rule) -> grandfathered violation count`, ordered for stable
/// serialization.
pub type Counts = BTreeMap<(String, String), usize>;

/// Parse a baseline file. Unparseable lines are hard errors — a corrupt
/// ratchet must never silently allow violations.
pub fn parse(text: &str) -> Result<Counts, String> {
    let mut out = Counts::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (count, rule, path) = match (parts.next(), parts.next(), parts.next()) {
            (Some(c), Some(r), Some(p)) => (c, r, p),
            _ => return Err(format!("baseline line {}: expected `<count> <rule> <path>`", ln + 1)),
        };
        if parts.next().is_some() {
            return Err(format!("baseline line {}: trailing fields", ln + 1));
        }
        let count: usize = count
            .parse()
            .map_err(|_| format!("baseline line {}: bad count `{count}`", ln + 1))?;
        if count == 0 {
            return Err(format!(
                "baseline line {}: zero-count entry — delete the line instead",
                ln + 1
            ));
        }
        if out.insert((path.to_string(), rule.to_string()), count).is_some() {
            return Err(format!("baseline line {}: duplicate entry", ln + 1));
        }
    }
    Ok(out)
}

/// Serialize counts in the checked-in format (sorted, zero-free).
pub fn serialize(counts: &Counts) -> String {
    let mut out = String::from(
        "# curlint baseline — grandfathered violation counts per (file, rule).\n\
         # The ratchet only tightens: CI fails when any count grows, and this\n\
         # file is regenerated (shrinking) with `cargo xtask lint --update-baseline`.\n",
    );
    for ((path, rule), count) in counts {
        if *count > 0 {
            out.push_str(&format!("{count} {rule} {path}\n"));
        }
    }
    out
}

/// One bucket's ratchet verdict.
#[derive(Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Count grew past the baseline (or appeared with no entry): fail.
    Grew { allowed: usize, actual: usize },
    /// Count shrank below the baseline: pass, but the file is stale.
    Shrank { allowed: usize, actual: usize },
    /// Exactly at the baseline.
    AtBaseline,
}

/// Compare actual counts against the baseline, per bucket. Buckets absent
/// from both sides never appear; baseline entries for clean (or deleted)
/// files come back as `Shrank { actual: 0 }`.
pub fn compare(baseline: &Counts, actual: &Counts) -> Vec<((String, String), Verdict)> {
    let mut out = Vec::new();
    for (key, &n) in actual {
        let allowed = baseline.get(key).copied().unwrap_or(0);
        let verdict = if n > allowed {
            Verdict::Grew { allowed, actual: n }
        } else if n < allowed {
            Verdict::Shrank { allowed, actual: n }
        } else {
            Verdict::AtBaseline
        };
        out.push((key.clone(), verdict));
    }
    for (key, &allowed) in baseline {
        if !actual.contains_key(key) {
            out.push((key.clone(), Verdict::Shrank { allowed, actual: 0 }));
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(entries: &[(&str, &str, usize)]) -> Counts {
        entries
            .iter()
            .map(|&(p, r, c)| ((p.to_string(), r.to_string()), c))
            .collect()
    }

    #[test]
    fn round_trip() {
        let c = counts(&[("rust/src/a.rs", "panic", 3), ("rust/src/b.rs", "env-var", 1)]);
        assert_eq!(parse(&serialize(&c)).unwrap(), c);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("nonsense").is_err());
        assert!(parse("x panic rust/src/a.rs").is_err());
        assert!(parse("0 panic rust/src/a.rs").is_err());
        assert!(parse("1 panic rust/src/a.rs extra").is_err());
        assert!(parse("1 panic rust/src/a.rs\n1 panic rust/src/a.rs").is_err());
        assert!(parse("# comment\n\n2 panic rust/src/a.rs\n").is_ok());
    }

    #[test]
    fn ratchet_verdicts() {
        let base = counts(&[("a.rs", "panic", 2), ("b.rs", "panic", 1)]);
        let actual = counts(&[("a.rs", "panic", 3), ("c.rs", "panic", 1)]);
        let v = compare(&base, &actual);
        assert_eq!(
            v,
            vec![
                (("a.rs".into(), "panic".into()), Verdict::Grew { allowed: 2, actual: 3 }),
                (("b.rs".into(), "panic".into()), Verdict::Shrank { allowed: 1, actual: 0 }),
                (("c.rs".into(), "panic".into()), Verdict::Grew { allowed: 0, actual: 1 }),
            ]
        );
    }

    #[test]
    fn at_baseline_passes() {
        let base = counts(&[("a.rs", "panic", 2)]);
        let v = compare(&base, &base);
        assert_eq!(v, vec![(("a.rs".into(), "panic".into()), Verdict::AtBaseline)]);
    }
}

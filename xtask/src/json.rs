//! Minimal JSON reader for the bench tooling. `xtask` is intentionally
//! dependency-free (no serde, and no dependency on the `curing` crate —
//! repo tooling must build even when the library does not), so this is
//! its own small recursive-descent parser. Read-only: the bench
//! commands never write JSON.

/// A parsed JSON value. Objects preserve file order (report order).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
}

pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("json error at byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(b) => {
                    let len = if b < 0x80 {
                        1
                    } else if b >> 5 == 0b110 {
                        2
                    } else if b >> 4 == 0b1110 {
                        3
                    } else {
                        4
                    };
                    let end = (self.pos + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[self.pos..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_recorded_run_shape() {
        let v = parse(
            r#"{"schema": 2, "workloads": {"kv_cur": {"measurements":
               {"tps[keep=0.5]": {"value": 120.5, "unit": "tokens/s"}}}}}"#,
        )
        .expect("parse");
        let m = v
            .get("workloads")
            .and_then(|w| w.get("kv_cur"))
            .and_then(|w| w.get("measurements"))
            .and_then(|m| m.get("tps[keep=0.5]"))
            .expect("path");
        assert_eq!(m.get("value").and_then(Value::as_f64), Some(120.5));
        assert_eq!(m.get("unit").and_then(Value::as_str), Some("tokens/s"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }
}

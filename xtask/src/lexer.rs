//! A minimal Rust lexer for curlint: just enough token structure to
//! tell code from comments, strings, char literals and lifetimes, with
//! `line:col` positions on every token. No `syn`, no regex — the
//! offline-build guarantee (see `rust/vendor/`) extends to the lint.
//!
//! Fidelity notes (deliberate simplifications, fine for linting):
//! * String/char contents are discarded — rules only need to know *that*
//!   a string sits somewhere, never what it says.
//! * Numeric literals are one token including suffixes (`1e`, `-`, `12`
//!   may split — rules never look at numbers).
//! * Non-ASCII bytes outside comments/strings are skipped; Rust sources
//!   in this repo only use Unicode in comments and string literals.

/// What a token is; `text` is only meaningful for `Ident` and `Punct`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
    pub col: usize,
}

/// A comment with its line span (block comments may span many lines).
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: usize,
    pub end_line: usize,
}

struct Cursor<'a> {
    src: &'a [u8],
    i: usize,
    line: usize,
    col: usize,
}

impl<'a> Cursor<'a> {
    fn at(&self, j: usize) -> u8 {
        if j < self.src.len() {
            self.src[j]
        } else {
            0
        }
    }

    fn advance(&mut self, upto: usize) {
        while self.i < upto && self.i < self.src.len() {
            if self.src[self.i] == b'\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }
}

fn is_id_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_id_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Index just past one character's content starting at `i` inside a
/// char/byte-char literal: an escape (`\n`, `\u{3bb}`, `\'`) or a single
/// UTF-8 scalar. The caller checks whether a closing quote follows.
fn one_char_end(src: &[u8], i: usize) -> usize {
    let at = |j: usize| if j < src.len() { src[j] } else { 0 };
    if at(i) == b'\\' {
        if at(i + 1) == b'u' && at(i + 2) == b'{' {
            let mut j = i + 3;
            while j < src.len() && src[j] != b'}' {
                j += 1;
            }
            j + 1
        } else {
            i + 2
        }
    } else {
        i + utf8_len(at(i))
    }
}

/// Byte length of one UTF-8 scalar from its lead byte (1 for ASCII and
/// for malformed leads — the cursor then just moves byte-by-byte).
fn utf8_len(b: u8) -> usize {
    if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else if b >> 3 == 0b11110 {
        4
    } else {
        1
    }
}

/// Tokenize `src`, returning code tokens and the comment list separately
/// (rules match tokens; the `// SAFETY:` and pragma checks read comments).
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut c = Cursor { src: src.as_bytes(), i: 0, line: 1, col: 1 };
    let n = c.src.len();

    while c.i < n {
        let b = c.src[c.i];
        let (line, col) = (c.line, c.col);

        if b == b' ' || b == b'\t' || b == b'\r' || b == b'\n' {
            c.advance(c.i + 1);
            continue;
        }

        // Line comment.
        if b == b'/' && c.at(c.i + 1) == b'/' {
            let mut j = c.i;
            while j < n && c.src[j] != b'\n' {
                j += 1;
            }
            comments.push(Comment {
                text: String::from_utf8_lossy(&c.src[c.i..j]).into_owned(),
                line,
                end_line: line,
            });
            c.advance(j);
            continue;
        }

        // Block comment (Rust block comments nest).
        if b == b'/' && c.at(c.i + 1) == b'*' {
            let start = c.i;
            let mut depth = 0usize;
            let mut j = c.i;
            while j < n {
                if c.src[j] == b'/' && c.at(j + 1) == b'*' {
                    depth += 1;
                    j += 2;
                } else if c.src[j] == b'*' && c.at(j + 1) == b'/' {
                    depth -= 1;
                    j += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    j += 1;
                }
            }
            c.advance(j);
            comments.push(Comment {
                text: String::from_utf8_lossy(&c.src[start..j]).into_owned(),
                line,
                end_line: c.line,
            });
            continue;
        }

        // Raw / byte-raw string: r"..", r#".."#, br"..", br#".."#.
        let raw_at = if b == b'r' && matches!(c.at(c.i + 1), b'"' | b'#') {
            Some(c.i + 1)
        } else if b == b'b' && c.at(c.i + 1) == b'r' && matches!(c.at(c.i + 2), b'"' | b'#') {
            Some(c.i + 2)
        } else {
            None
        };
        if let Some(start) = raw_at {
            let mut j = start;
            let mut hashes = 0usize;
            while c.at(j) == b'#' {
                hashes += 1;
                j += 1;
            }
            if c.at(j) == b'"' {
                j += 1;
                // Find `"` followed by `hashes` '#'s.
                let close = loop {
                    match c.src[j..].iter().position(|&x| x == b'"') {
                        None => break n,
                        Some(p) => {
                            let q = j + p + 1;
                            if c.src[q..].len() >= hashes
                                && c.src[q..q + hashes].iter().all(|&x| x == b'#')
                            {
                                break q + hashes;
                            }
                            j = q;
                        }
                    }
                };
                toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
                c.advance(close);
                continue;
            }
            // `r#ident` raw identifier: one Ident token carrying the
            // unprefixed name (`r#type` names the same item as `type`,
            // so the item graph must see a single `type` ident, not an
            // `r` + `#` + `type` split that reads as an item named `r`).
            if b == b'r' && c.at(c.i + 1) == b'#' && is_id_start(c.at(c.i + 2)) {
                let mut j = c.i + 2;
                while j < n && is_id_cont(c.src[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Ident,
                    text: String::from_utf8_lossy(&c.src[c.i + 2..j]).into_owned(),
                    line,
                    col,
                });
                c.advance(j);
                continue;
            }
            // Stray hash after `r`/`br`: fall through.
        }

        // Byte string / byte char.
        if b == b'b' && c.at(c.i + 1) == b'"' {
            let mut j = c.i + 2;
            while j < n {
                match c.src[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            c.advance(j);
            continue;
        }
        if b == b'b' && c.at(c.i + 1) == b'\'' {
            let j = one_char_end(c.src, c.i + 2);
            c.advance(if c.at(j) == b'\'' { j + 1 } else { c.i + 2 });
            continue;
        }

        // String literal.
        if b == b'"' {
            let mut j = c.i + 1;
            while j < n {
                match c.src[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line, col });
            c.advance(j);
            continue;
        }

        // Char literal vs lifetime: `'a'` is a char, `'a ` is a lifetime.
        // Non-ASCII is disambiguated by *bounded* lookahead — exactly one
        // (possibly escaped, possibly multi-byte) scalar then a close
        // quote makes a char literal (`'λ'`); anything else leaves the
        // quote behind as a lifetime/stray mark instead of swallowing
        // code up to the next apostrophe anywhere in the file.
        if b == b'\'' {
            if is_id_start(c.at(c.i + 1)) {
                let mut j = c.i + 1;
                while j < n && is_id_cont(c.src[j]) {
                    j += 1;
                }
                if c.at(j) == b'\'' {
                    c.advance(j + 1); // char literal like 'a'
                } else {
                    c.advance(j); // lifetime
                }
                continue;
            }
            let j = one_char_end(c.src, c.i + 1);
            c.advance(if c.at(j) == b'\'' { j + 1 } else { c.i + 1 });
            continue;
        }

        if is_id_start(b) {
            let mut j = c.i;
            while j < n && is_id_cont(c.src[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: String::from_utf8_lossy(&c.src[c.i..j]).into_owned(),
                line,
                col,
            });
            c.advance(j);
            continue;
        }

        if b.is_ascii_digit() {
            let mut j = c.i;
            while j < n && (is_id_cont(c.src[j]) || c.src[j] == b'.') {
                // A dot continues the number only before another digit
                // (`1.5`); `0..n` ranges and `x.1.cmp(…)` tuple-field
                // method calls stop it.
                if c.src[j] == b'.' && !c.at(j + 1).is_ascii_digit() {
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: String::from_utf8_lossy(&c.src[c.i..j]).into_owned(),
                line,
                col,
            });
            c.advance(j);
            continue;
        }

        if b.is_ascii() {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: (b as char).to_string(),
                line,
                col,
            });
        }
        c.advance(c.i + 1);
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_code() {
        let src = "// unwrap()\nlet s = \"unwrap()\"; /* expect( */ real()";
        assert_eq!(idents(src), vec!["let", "s", "real"]);
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let src = "let m = r#\"a \"quoted\" unwrap()\"#; next";
        assert_eq!(idents(src), vec!["let", "m", "next"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let esc = '\\''; g(c, esc) }";
        let ids = idents(src);
        assert!(ids.contains(&"g".to_string()));
        // 'a must lex as a lifetime, not swallow code as a char literal.
        assert!(ids.contains(&"str".to_string()));
    }

    #[test]
    fn nested_block_comment() {
        let src = "/* outer /* inner */ still comment */ after";
        assert_eq!(idents(src), vec!["after"]);
    }

    #[test]
    fn positions_are_one_based() {
        let (toks, _) = lex("ab\n  cd");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn raw_identifiers_are_one_ident_not_a_raw_string_start() {
        // `r#type` must not be read as `r#"…` (raw string) nor split
        // into an ident `r` — the item graph would otherwise record a
        // fn named `r`.
        let src = "fn r#type(r#else: usize) { r#loop() }";
        assert_eq!(idents(src), vec!["fn", "type", "else", "usize", "loop"]);
        // And a real raw string right after a raw ident still lexes.
        let (toks, _) = lex("let r#match = r#\"unwrap()\"#;");
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
        assert!(toks.iter().all(|t| t.text != "unwrap"));
    }

    #[test]
    fn multibyte_char_literals_and_lifetimes() {
        // 'λ' is a two-byte scalar: a char literal, not a swallow-all.
        let src = "let c = 'λ'; let u = '\\u{3bb}'; g(c, u)";
        assert_eq!(idents(src), vec!["let", "c", "let", "u", "g", "c", "u"]);
        // A non-ASCII lifetime-ish quote must not consume code up to
        // the next apostrophe elsewhere in the file.
        let src = "fn f(x: &'λ str) { h() } // it's fine";
        assert!(idents(src).contains(&"h".to_string()));
    }

    #[test]
    fn block_comment_closing_on_its_opening_line() {
        let src = "/* one line */ after(); /* a */ /* b */ tail()";
        assert_eq!(idents(src), vec!["after", "tail"]);
        let (_, comments) = lex(src);
        assert_eq!(comments.len(), 3);
        for c in &comments {
            assert_eq!(c.line, c.end_line);
            assert_eq!(c.line, 1);
        }
        // Same-line close followed by a nested open on one line.
        let src = "/* x /* y */ z */ code()";
        assert_eq!(idents(src), vec!["code"]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let src = "p.expect(b'{'); q(b\"unwrap()\")";
        let ids = idents(src);
        assert_eq!(ids, vec!["p", "expect", "q"]);
        // b'{' is not a Str token — `expect(b'{')` must not look like
        // `expect("msg")` to the panic rule.
        let (toks, _) = lex("expect(b'{')");
        assert!(toks.iter().all(|t| t.kind != TokKind::Str));
    }
}

//! The curlint rule set. Each rule encodes an invariant this repo has
//! already been burned by (see `rust/README.md` § curlint for the
//! incident behind each one):
//!
//! * `panic` — no `unwrap()` / `expect("…")` / `panic!` / `todo!` /
//!   `unimplemented!` in library code (the PR 1 panic→`Result` sweep,
//!   kept swept). `#[cfg(test)]` code is exempt. `expect` only fires
//!   when called with a string-literal message — `self.expect(b'{')`
//!   in the JSON parser is a fallible method, not `Option::expect`.
//!   `panic_any(...)` and `catch_unwind(...)` also fire: panic
//!   boundaries exist only at the fault injector (the `crash` action)
//!   and the cluster supervisor, and each use carries a reasoned
//!   pragma naming its boundary.
//! * `float-sort` — `sort_by` / `sort_unstable_by` / `max_by` / `min_by`
//!   must order through `total_cmp`, `Ord::cmp`, or the shared
//!   `util::stats::nan_last_*` keys (the wanda NaN-panic audit,
//!   automated). `partial_cmp` in a sort closure always fires.
//! * `safety-comment` — every `unsafe` block needs a `// SAFETY:`
//!   comment ending no more than 3 lines above it.
//! * `env-var` — `env::var` only inside `util::config`, so `CURING_*`
//!   escape hatches stay centralized and documented.
//! * `kernel-purity` — no `Instant` and no allocating calls
//!   (`vec!`, `Vec::new`, `to_vec()`, `collect()`, …) in the kernel
//!   modules listed in [`KERNEL_MODULES`]; deliberate allocations
//!   (output buffers of convenience wrappers) carry a pragma.
//!
//! Any violation is suppressible in place with
//! `// curlint: allow(<rule>) -- <reason>` on the same line or the line
//! above; a pragma with an unknown rule name or a missing reason is
//! itself reported (`pragma`).

use crate::lexer::{lex, Comment, Tok, TokKind};

/// Kernel modules (path suffixes, `/`-separated) held to `kernel-purity`.
pub const KERNEL_MODULES: &[&str] = &["rust/src/backend/native/math.rs"];

/// The one module allowed to read `env::var` (path suffix).
pub const CONFIG_MODULE: &str = "rust/src/util/config.rs";

/// All rule names, the vocabulary `allow(...)` pragmas draw from.
pub const RULE_NAMES: &[&str] =
    &["panic", "float-sort", "safety-comment", "env-var", "kernel-purity", "pragma"];

const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented"];
const FLOAT_SORTS: &[&str] = &["sort_by", "sort_unstable_by", "max_by", "min_by"];
const SAFE_CMPS: &[&str] = &["total_cmp", "nan_last_desc", "nan_last_asc", "cmp"];
const KERNEL_BANNED_MACROS: &[&str] = &["vec", "format"];
const KERNEL_BANNED_CALLS: &[&str] = &["to_vec", "collect", "to_string"];
const KERNEL_BANNED_CTORS: &[&str] = &["Vec", "String", "Box"];
const KERNEL_CTOR_FNS: &[&str] = &["new", "with_capacity", "from"];

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

/// Token index spans covered by `#[cfg(test)]` / `#[test]` items.
fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let n = toks.len();
    let mut i = 0;
    while i < n {
        if toks[i].text == "#" && i + 1 < n && toks[i + 1].text == "[" {
            // Scan the attribute to its matching `]`, collecting idents.
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut names: Vec<&str> = Vec::new();
            while j < n {
                let t = &toks[j];
                if t.text == "[" {
                    depth += 1;
                } else if t.text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.kind == TokKind::Ident {
                    names.push(&t.text);
                }
                j += 1;
            }
            let is_test = (names.contains(&"cfg") && names.contains(&"test"))
                || names.first() == Some(&"test");
            i = j + 1;
            if !is_test {
                continue;
            }
            // Skip further attributes stacked on the same item.
            while i + 1 < n && toks[i].text == "#" && toks[i + 1].text == "[" {
                let mut depth = 0usize;
                while i < n {
                    if toks[i].text == "[" {
                        depth += 1;
                    } else if toks[i].text == "]" {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    i += 1;
                }
            }
            // The item body: to `;` at depth 0, or the matched brace block.
            let start = i;
            let mut depth = 0usize;
            while i < n {
                let t = &toks[i];
                if t.text == "{" {
                    depth += 1;
                } else if t.text == "}" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if t.text == ";" && depth == 0 {
                    break;
                }
                i += 1;
            }
            regions.push((start, i.min(n.saturating_sub(1))));
        }
        i += 1;
    }
    regions
}

fn suffix_match(path: &str, suffix: &str) -> bool {
    let p = path.replace('\\', "/");
    p == suffix || p.ends_with(&format!("/{suffix}"))
}

/// Lint one source file. `path` is repo-root-relative with `/` separators
/// (used for the kernel-module and config-module scoping).
pub fn check_source(path: &str, src: &str) -> Vec<Violation> {
    let (toks, comments) = lex(src);
    let regions = test_regions(&toks);
    let is_kernel = KERNEL_MODULES.iter().any(|k| suffix_match(path, k));
    let is_config = suffix_match(path, CONFIG_MODULE);
    let n = toks.len();
    let mut out: Vec<Violation> = Vec::new();
    let mut push = |rule: &'static str, line: usize, col: usize, msg: String| {
        out.push(Violation { rule, line, col, msg });
    };

    for i in 0..n {
        if regions.iter().any(|&(a, b)| a <= i && i <= b) {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let nxt = toks.get(i + 1);
        let nxt2 = toks.get(i + 2);
        let text = |o: Option<&Tok>| o.map(|t| t.text.as_str()).unwrap_or("");
        let kind = |o: Option<&Tok>| o.map(|t| t.kind);

        // ---- panic
        if t.text == "unwrap" && text(nxt) == "(" && text(nxt2) == ")" {
            push("panic", t.line, t.col, "`unwrap()` can panic".into());
        }
        if t.text == "expect" && text(nxt) == "(" && kind(nxt2) == Some(TokKind::Str) {
            push("panic", t.line, t.col, "`expect(\"…\")` can panic".into());
        }
        if PANIC_MACROS.contains(&t.text.as_str()) && text(nxt) == "!" {
            push("panic", t.line, t.col, format!("`{}!` in library code", t.text));
        }
        // Panic boundaries: raising one (`panic_any`, the injected
        // `crash` fault) or catching one (`catch_unwind`, the cluster
        // supervisor) is infrastructure territory — each use carries a
        // reasoned pragma saying whose boundary it is.
        if (t.text == "panic_any" || t.text == "catch_unwind") && text(nxt) == "(" {
            push(
                "panic",
                t.line,
                t.col,
                format!("`{}` is a panic boundary; justify it with a pragma", t.text),
            );
        }

        // ---- float-sort
        if FLOAT_SORTS.contains(&t.text.as_str()) && text(nxt) == "(" {
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut has_safe = false;
            let mut has_partial = false;
            while j < n {
                let u = &toks[j];
                if u.text == "(" {
                    depth += 1;
                } else if u.text == ")" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if u.kind == TokKind::Ident {
                    if SAFE_CMPS.contains(&u.text.as_str()) {
                        has_safe = true;
                    }
                    if u.text == "partial_cmp" {
                        has_partial = true;
                    }
                }
                j += 1;
            }
            if has_partial || !has_safe {
                push(
                    "float-sort",
                    t.line,
                    t.col,
                    format!(
                        "`{}` without a total order — use `total_cmp` or the \
                         `util::stats::nan_last_*` keys",
                        t.text
                    ),
                );
            }
        }

        // ---- safety-comment
        if t.text == "unsafe" && text(nxt) == "{" {
            let covered = comments.iter().any(|c| {
                c.text.contains("SAFETY:")
                    && c.end_line + 3 >= t.line
                    && c.end_line <= t.line
            });
            if !covered {
                push(
                    "safety-comment",
                    t.line,
                    t.col,
                    "`unsafe` block without a preceding `// SAFETY:` comment".into(),
                );
            }
        }

        // ---- env-var
        if !is_config
            && t.text == "env"
            && text(nxt) == ":"
            && text(nxt2) == ":"
            && matches!(text(toks.get(i + 3)), "var" | "var_os")
        {
            let v = &toks[i + 3];
            push(
                "env-var",
                v.line,
                v.col,
                "`env::var` outside `util::config` — add an accessor there".into(),
            );
        }

        // ---- kernel-purity
        if is_kernel {
            let bad = if t.text == "Instant" {
                Some("`Instant` in a kernel module".to_string())
            } else if KERNEL_BANNED_MACROS.contains(&t.text.as_str()) && text(nxt) == "!" {
                Some(format!("`{}!` allocates in a kernel module", t.text))
            } else if KERNEL_BANNED_CALLS.contains(&t.text.as_str()) && text(nxt) == "(" {
                Some(format!("`{}()` allocates in a kernel module", t.text))
            } else if KERNEL_BANNED_CTORS.contains(&t.text.as_str())
                && text(nxt) == ":"
                && text(nxt2) == ":"
                && KERNEL_CTOR_FNS.contains(&text(toks.get(i + 3)))
            {
                Some(format!(
                    "`{}::{}` allocates in a kernel module",
                    t.text,
                    text(toks.get(i + 3))
                ))
            } else {
                None
            };
            if let Some(msg) = bad {
                push("kernel-purity", t.line, t.col, msg);
            }
        }
    }

    apply_pragmas(out, &comments)
}

/// Parse `// curlint: allow(rule[, rule]) -- reason` pragmas and drop
/// suppressed violations; malformed pragmas become violations themselves.
fn apply_pragmas(found: Vec<Violation>, comments: &[Comment]) -> Vec<Violation> {
    // (rule, first suppressed line, last suppressed line)
    let mut allows: Vec<(String, usize, usize)> = Vec::new();
    let mut out: Vec<Violation> = Vec::new();
    for c in comments {
        let Some(k) = c.text.find("curlint: allow(") else { continue };
        let rest = &c.text[k + "curlint: allow(".len()..];
        let Some(close) = rest.find(')') else {
            out.push(Violation {
                rule: "pragma",
                line: c.line,
                col: 1,
                msg: "malformed curlint pragma (unclosed `allow(`)".into(),
            });
            continue;
        };
        let names: Vec<String> =
            rest[..close].split(',').map(|r| r.trim().to_string()).collect();
        let tail = &rest[close + 1..];
        let reason = match tail.find("--") {
            Some(sep) => tail[sep + 2..].trim(),
            None => "",
        };
        if reason.is_empty() || names.iter().any(|r| !RULE_NAMES.contains(&r.as_str())) {
            out.push(Violation {
                rule: "pragma",
                line: c.line,
                col: 1,
                msg: "malformed curlint pragma (need a known rule and `-- <reason>`)"
                    .into(),
            });
            continue;
        }
        for r in names {
            allows.push((r, c.line, c.end_line + 1));
        }
    }
    for v in found {
        let suppressed = allows
            .iter()
            .any(|(r, lo, hi)| r == v.rule && *lo <= v.line && v.line <= *hi);
        if !suppressed {
            out.push(v);
        }
    }
    out.sort_by(|a, b| (a.line, a.col).cmp(&(b.line, b.col)));
    out
}

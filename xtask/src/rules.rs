//! The curlint rule set. Each rule encodes an invariant this repo has
//! already been burned by (see `rust/README.md` § curlint, or
//! `cargo xtask lint --explain <rule>`, for the incident behind each):
//!
//! Token rules (per file, in [`check_source`]):
//!
//! * `panic` — no `unwrap()` / `expect("…")` / `panic!` / `todo!` /
//!   `unimplemented!` in library code (the PR 1 panic→`Result` sweep,
//!   kept swept). `#[cfg(test)]` code is exempt. `expect` only fires
//!   when called with a string-literal message — `self.expect(b'{')`
//!   in the JSON parser is a fallible method, not `Option::expect`.
//!   `panic_any(...)` and `catch_unwind(...)` also fire: panic
//!   boundaries exist only at the fault injector (the `crash` action)
//!   and the cluster supervisor, and each use carries a reasoned
//!   pragma naming its boundary.
//! * `float-sort` — `sort_by` / `sort_unstable_by` / `max_by` / `min_by`
//!   must order through `total_cmp`, `Ord::cmp`, or the shared
//!   `util::stats::nan_last_*` keys (the wanda NaN-panic audit,
//!   automated). `partial_cmp` in a sort closure always fires.
//! * `safety-comment` — every `unsafe` block needs a `// SAFETY:`
//!   comment ending no more than 3 lines above it.
//! * `env-var` — `env::var` only inside `util::config`, so `CURING_*`
//!   escape hatches stay centralized and documented.
//! * `blocking-recv` — in `serve/` (the supervisor/cluster event
//!   loops), no bare blocking `recv()` and no blocking iteration of a
//!   channel receiver (`rx.iter()`, `for r in rx`): a hung worker must
//!   never hang its supervisor. Use `recv_timeout` / `try_recv` /
//!   `try_iter`.
//!
//! Cross-file rules (whole-repo, in [`check_repo`], built on the item
//! graph + call graph):
//!
//! * `hot-path-purity` — every fn transitively callable from a
//!   hot-entry fn (marked `curlint: hot-entry`, plus every fn in
//!   [`KERNEL_MODULES`] — the retired v1 `kernel-purity` allowlist,
//!   kept as the always-checked floor) must be free of allocation,
//!   `Instant`, locking and I/O. The v1 rule name remains valid in
//!   pragmas as an alias.
//! * `typed-error` — pub `Result` fns in `serve/` and `backend/` must
//!   not construct bare-message `anyhow!`/`bail!` errors.
//! * `dead-pub` — plain-`pub` items never referenced outside their
//!   defining file are flagged for a visibility ratchet.
//!
//! Any violation is suppressible in place with a pragma comment that
//! *starts* (after `//`): `curlint: allow(<rule>) -- <reason>`, on the
//! same line or the line above; a pragma with an unknown rule name or a
//! missing reason is itself reported (`pragma`), as is any other
//! unrecognized `curlint:` directive.

use std::collections::BTreeMap;

use crate::callgraph::CallGraph;
use crate::itemgraph::{control_text, test_regions, ItemGraph};
use crate::lexer::{lex, Comment, Tok, TokKind};

/// Kernel modules (path suffixes, `/`-separated): the v1 `kernel-purity`
/// allowlist, kept as `hot-path-purity`'s always-checked floor so the
/// new rule is a strict superset of the old one.
pub const KERNEL_MODULES: &[&str] = &["rust/src/backend/native/math.rs"];

/// The one module allowed to read `env::var` (path suffix).
pub const CONFIG_MODULE: &str = "rust/src/util/config.rs";

/// All rule names, the vocabulary `allow(...)` pragmas draw from.
/// `kernel-purity` is retired as a rule but stays valid in pragmas as
/// an alias for `hot-path-purity`.
pub const RULE_NAMES: &[&str] = &[
    "panic",
    "float-sort",
    "safety-comment",
    "env-var",
    "kernel-purity",
    "hot-path-purity",
    "typed-error",
    "blocking-recv",
    "dead-pub",
    "pragma",
];

const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented"];
const FLOAT_SORTS: &[&str] = &["sort_by", "sort_unstable_by", "max_by", "min_by"];
const SAFE_CMPS: &[&str] = &["total_cmp", "nan_last_desc", "nan_last_asc", "cmp"];
const HOT_BANNED_MACROS: &[&str] = &["vec", "format", "println", "eprintln", "print", "eprint"];
const HOT_BANNED_CALLS: &[&str] = &["to_vec", "collect", "to_string", "lock"];
const HOT_BANNED_CTORS: &[&str] = &["Vec", "String", "Box"];
const HOT_CTOR_FNS: &[&str] = &["new", "with_capacity", "from"];

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub line: usize,
    pub col: usize,
    pub msg: String,
}

pub(crate) fn suffix_match(path: &str, suffix: &str) -> bool {
    let p = path.replace('\\', "/");
    p == suffix || p.ends_with(&format!("/{suffix}"))
}

/// Scan `span` (token indexes, end-exclusive) for allocation, `Instant`,
/// locking and I/O — the `hot-path-purity` banned set, a strict
/// superset of v1 `kernel-purity`'s. `skip` spans (test regions) are
/// exempt. Shared by the kernel-module whole-file scan and the
/// call-graph reachability pass.
pub(crate) fn purity_scan(
    toks: &[Tok],
    span: (usize, usize),
    skip: &[(usize, usize)],
) -> Vec<Violation> {
    let n = toks.len();
    let mut out = Vec::new();
    let text = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
    for i in span.0..span.1.min(n) {
        if skip.iter().any(|&(a, b)| a <= i && i <= b) {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let msg = if t.text == "Instant" {
            Some("`Instant` on a hot path".to_string())
        } else if HOT_BANNED_MACROS.contains(&t.text.as_str()) && text(i + 1) == "!" {
            Some(format!("`{}!` allocates/does I/O on a hot path", t.text))
        } else if HOT_BANNED_CALLS.contains(&t.text.as_str()) && text(i + 1) == "(" {
            Some(format!("`{}()` allocates/blocks on a hot path", t.text))
        } else if HOT_BANNED_CTORS.contains(&t.text.as_str())
            && text(i + 1) == ":"
            && text(i + 2) == ":"
            && HOT_CTOR_FNS.contains(&text(i + 3))
        {
            Some(format!("`{}::{}` allocates on a hot path", t.text, text(i + 3)))
        } else {
            None
        };
        if let Some(msg) = msg {
            out.push(Violation { rule: "hot-path-purity", line: t.line, col: t.col, msg });
        }
    }
    out
}

/// The per-file token rules, pre-pragma. `path` is repo-root-relative
/// with `/` separators (used for the kernel/config/serve scoping).
fn token_rules(
    path: &str,
    toks: &[Tok],
    comments: &[Comment],
    regions: &[(usize, usize)],
) -> Vec<Violation> {
    let is_kernel = KERNEL_MODULES.iter().any(|k| suffix_match(path, k));
    let is_config = suffix_match(path, CONFIG_MODULE);
    let is_serve = path.replace('\\', "/").contains("rust/src/serve/");
    let n = toks.len();
    let mut out: Vec<Violation> = Vec::new();
    let mut push = |rule: &'static str, line: usize, col: usize, msg: String| {
        out.push(Violation { rule, line, col, msg });
    };

    for i in 0..n {
        if regions.iter().any(|&(a, b)| a <= i && i <= b) {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let nxt = toks.get(i + 1);
        let nxt2 = toks.get(i + 2);
        let text = |o: Option<&Tok>| o.map(|t| t.text.as_str()).unwrap_or("");
        let kind = |o: Option<&Tok>| o.map(|t| t.kind);

        // ---- panic
        if t.text == "unwrap" && text(nxt) == "(" && text(nxt2) == ")" {
            push("panic", t.line, t.col, "`unwrap()` can panic".into());
        }
        if t.text == "expect" && text(nxt) == "(" && kind(nxt2) == Some(TokKind::Str) {
            push("panic", t.line, t.col, "`expect(\"…\")` can panic".into());
        }
        if PANIC_MACROS.contains(&t.text.as_str()) && text(nxt) == "!" {
            push("panic", t.line, t.col, format!("`{}!` in library code", t.text));
        }
        // Panic boundaries: raising one (`panic_any`, the injected
        // `crash` fault) or catching one (`catch_unwind`, the cluster
        // supervisor) is infrastructure territory — each use carries a
        // reasoned pragma saying whose boundary it is.
        if (t.text == "panic_any" || t.text == "catch_unwind") && text(nxt) == "(" {
            push(
                "panic",
                t.line,
                t.col,
                format!("`{}` is a panic boundary; justify it with a pragma", t.text),
            );
        }

        // ---- float-sort
        if FLOAT_SORTS.contains(&t.text.as_str()) && text(nxt) == "(" {
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut has_safe = false;
            let mut has_partial = false;
            while j < n {
                let u = &toks[j];
                if u.text == "(" {
                    depth += 1;
                } else if u.text == ")" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if u.kind == TokKind::Ident {
                    if SAFE_CMPS.contains(&u.text.as_str()) {
                        has_safe = true;
                    }
                    if u.text == "partial_cmp" {
                        has_partial = true;
                    }
                }
                j += 1;
            }
            if has_partial || !has_safe {
                push(
                    "float-sort",
                    t.line,
                    t.col,
                    format!(
                        "`{}` without a total order — use `total_cmp` or the \
                         `util::stats::nan_last_*` keys",
                        t.text
                    ),
                );
            }
        }

        // ---- safety-comment
        if t.text == "unsafe" && text(nxt) == "{" {
            let covered = comments.iter().any(|c| {
                c.text.contains("SAFETY:") && c.end_line + 3 >= t.line && c.end_line <= t.line
            });
            if !covered {
                push(
                    "safety-comment",
                    t.line,
                    t.col,
                    "`unsafe` block without a preceding `// SAFETY:` comment".into(),
                );
            }
        }

        // ---- env-var
        if !is_config
            && t.text == "env"
            && text(nxt) == ":"
            && text(nxt2) == ":"
            && matches!(text(toks.get(i + 3)), "var" | "var_os")
        {
            let v = &toks[i + 3];
            push(
                "env-var",
                v.line,
                v.col,
                "`env::var` outside `util::config` — add an accessor there".into(),
            );
        }

        // ---- blocking-recv (serve/ event loops only)
        if is_serve {
            if t.text == "recv" && text(nxt) == "(" && text(nxt2) == ")" {
                push(
                    "blocking-recv",
                    t.line,
                    t.col,
                    "bare blocking `recv()` in serve/ — a hung peer hangs this loop; \
                     use `recv_timeout` or `try_recv`"
                        .into(),
                );
            }
            // Blocking receiver iteration, by the repo's rx naming
            // convention: `rx.iter()` / `rx.into_iter()` / `for r in rx`
            // where the receiver ident is `rx` or `*_rx` (plural `rxs`
            // is a container of receivers — slice iteration is fine).
            let rx_like = |s: &str| s == "rx" || s.ends_with("_rx");
            if rx_like(&t.text)
                && text(nxt) == "."
                && matches!(text(nxt2), "iter" | "into_iter")
                && text(toks.get(i + 3)) == "("
            {
                push(
                    "blocking-recv",
                    t.line,
                    t.col,
                    format!(
                        "`{}.{}()` blocks until the channel closes — use `try_iter()` \
                         or a `recv_timeout` loop",
                        t.text,
                        text(nxt2)
                    ),
                );
            }
            if t.text == "in" && text(nxt2) == "{" {
                if let Some(v) = nxt.filter(|v| rx_like(&v.text)) {
                    push(
                        "blocking-recv",
                        v.line,
                        v.col,
                        format!(
                            "`for … in {}` blocks until the channel closes — use \
                             `try_iter()` or a `recv_timeout` loop",
                            v.text
                        ),
                    );
                }
            }
        }
    }

    // ---- hot-path-purity floor: kernel modules are scanned wholesale.
    if is_kernel {
        out.extend(purity_scan(toks, (0, n), regions));
    }
    out
}

/// Lint one source file with the token rules. `path` is
/// repo-root-relative with `/` separators. Cross-file rules need the
/// whole repo — see [`check_repo`].
pub fn check_source(path: &str, src: &str) -> Vec<Violation> {
    let (toks, comments) = lex(src);
    let regions = test_regions(&toks);
    apply_pragmas(token_rules(path, &toks, &comments, &regions), &comments)
}

/// Lint the whole repo: token rules per file plus the cross-file rules
/// (`hot-path-purity`, `typed-error`, `dead-pub`) over the item/call
/// graph. `refs_only` holds `(path, source)` files scanned for
/// `dead-pub` references without being linted (tests, benches,
/// examples). Returns violations keyed by file path.
pub fn check_repo(
    g: &ItemGraph,
    refs_only: &[(String, String)],
) -> BTreeMap<String, Vec<Violation>> {
    let mut per_file: Vec<Vec<Violation>> = g
        .files
        .iter()
        .map(|f| token_rules(&f.path, &f.toks, &f.comments, &f.test_regions))
        .collect();
    let cg = CallGraph::build(g);
    for (fi, v) in cg.hot_path_purity() {
        per_file[fi].push(v);
    }
    for (fi, v) in cg.typed_error() {
        per_file[fi].push(v);
    }
    for (fi, v) in cg.dead_pub(refs_only) {
        per_file[fi].push(v);
    }
    let mut out = BTreeMap::new();
    for (fi, f) in g.files.iter().enumerate() {
        let mut vs = apply_pragmas(std::mem::take(&mut per_file[fi]), &f.comments);
        vs.dedup_by(|a, b| a.rule == b.rule && a.line == b.line && a.col == b.col);
        if !vs.is_empty() {
            out.insert(f.path.clone(), vs);
        }
    }
    out
}

/// Whether pragma rule name `allow` suppresses violations of `rule`
/// (exact match, plus the retired-v1 `kernel-purity` alias).
fn pragma_matches(allow: &str, rule: &str) -> bool {
    allow == rule || (allow == "kernel-purity" && rule == "hot-path-purity")
}

/// Parse `curlint:` control comments, drop suppressed violations, and
/// report malformed directives. A pragma must *start* the comment text
/// (after `//`/`/*` sigils): prose that merely mentions the syntax is
/// not a directive.
fn apply_pragmas(found: Vec<Violation>, comments: &[Comment]) -> Vec<Violation> {
    // (rule, first suppressed line, last suppressed line)
    let mut allows: Vec<(String, usize, usize)> = Vec::new();
    let mut out: Vec<Violation> = Vec::new();
    for c in comments {
        let Some(directive) = control_text(c).strip_prefix("curlint:") else { continue };
        let directive = directive.trim_start();
        if directive.starts_with("hot-entry") {
            continue; // consumed by the item graph
        }
        let Some(rest) = directive.strip_prefix("allow(") else {
            out.push(Violation {
                rule: "pragma",
                line: c.line,
                col: 1,
                msg: "unknown curlint directive (expected `allow(…) -- reason` or \
                      `hot-entry`)"
                    .into(),
            });
            continue;
        };
        let Some(close) = rest.find(')') else {
            out.push(Violation {
                rule: "pragma",
                line: c.line,
                col: 1,
                msg: "malformed curlint pragma (unclosed `allow(`)".into(),
            });
            continue;
        };
        let names: Vec<String> =
            rest[..close].split(',').map(|r| r.trim().to_string()).collect();
        let tail = &rest[close + 1..];
        let reason = match tail.find("--") {
            Some(sep) => tail[sep + 2..].trim(),
            None => "",
        };
        if reason.is_empty() || names.iter().any(|r| !RULE_NAMES.contains(&r.as_str())) {
            out.push(Violation {
                rule: "pragma",
                line: c.line,
                col: 1,
                msg: "malformed curlint pragma (need a known rule and `-- <reason>`)".into(),
            });
            continue;
        }
        for r in names {
            allows.push((r, c.line, c.end_line + 1));
        }
    }
    for v in found {
        let suppressed = allows
            .iter()
            .any(|(r, lo, hi)| pragma_matches(r, v.rule) && *lo <= v.line && v.line <= *hi);
        if !suppressed {
            out.push(v);
        }
    }
    out.sort_by(|a, b| (a.line, a.col).cmp(&(b.line, b.col)));
    out
}

/// The incident + invariant text behind a rule, for `--explain`.
pub fn explain(rule: &str) -> Option<&'static str> {
    let text = match rule {
        "panic" => {
            "panic — no unwrap()/expect(\"…\")/panic!/todo!/unimplemented! in library code.\n\
             Incident: the seed crate panicked on malformed artifacts and empty calib sets;\n\
             PR 1 swept every panic into Result and this rule keeps it swept. Test code is\n\
             exempt; panic boundaries (panic_any/catch_unwind at the fault injector and the\n\
             cluster supervisor) each carry a reasoned pragma naming the boundary.\n\
             Invariant: a malformed input or a poisoned invariant surfaces as Err, never as\n\
             a worker-killing unwind outside the supervisor's catch."
        }
        "float-sort" => {
            "float-sort — sort_by/sort_unstable_by/max_by/min_by must order through\n\
             total_cmp, Ord::cmp, or the util::stats::nan_last_* keys; partial_cmp in a\n\
             comparator always fires.\n\
             Incident: the wanda importance sort hit a NaN under an all-zero calibration\n\
             batch and panicked deep in leverage scoring.\n\
             Invariant: float orderings are total, NaNs land deterministically last."
        }
        "safety-comment" => {
            "safety-comment — every unsafe block needs a `// SAFETY:` comment ending\n\
             within 3 lines above it.\n\
             Incident: the pod_bytes byte-cast in backend/pjrt.rs is the repo's only\n\
             unsafe surface; its aliasing/alignment argument must travel with the code.\n\
             Invariant: unsafe never outlives the argument for why it is sound."
        }
        "env-var" => {
            "env-var — env::var only inside util::config.\n\
             Incident: CURING_* escape hatches had started sprouting at call sites, each\n\
             with its own default and parsing; one bench read a stale name.\n\
             Invariant: every env knob is declared, parsed and documented in one module."
        }
        "kernel-purity" | "hot-path-purity" => {
            "hot-path-purity (v1 name: kernel-purity, still valid in pragmas) — every fn\n\
             transitively callable from a `// curlint: hot-entry` fn (layer_decode_batch,\n\
             layer_prefill, layer_forward_infer, the matmul_* family), plus everything in\n\
             backend/native/math.rs (the retired v1 allowlist, kept as the always-checked\n\
             floor), must be free of allocation (vec!/format!/to_vec/collect/to_string/\n\
             Vec::new/String::from/Box::new), Instant, lock(), and print I/O.\n\
             Incident: a per-token Vec allocation snuck into a fn *called from* the decode\n\
             loop — the v1 module allowlist was blind to it; tokens/s dropped double-digit\n\
             percent before the bench caught it.\n\
             Invariant: the decode/prefill hot paths run allocation-free at steady state;\n\
             deliberate setup allocations carry a per-site pragma with a reason."
        }
        "typed-error" => {
            "typed-error — pub fns in serve/ and backend/ that return Result must not\n\
             construct bare anyhow!(\"…\")/bail!(\"…\") errors; wrap a typed payload\n\
             (ServeError, BackendError, InjectedFault, StoreCorruption) so callers can\n\
             downcast. bail!(ServeError::Overloaded) passes; bail!(\"overloaded\") fails.\n\
             Incident: the cluster router once matched on error *strings* to tell\n\
             retryable Overloaded from fatal Failed; a reworded message broke retry.\n\
             Invariant: API-boundary errors are downcastable types, not prose."
        }
        "blocking-recv" => {
            "blocking-recv — in serve/, no bare blocking recv() and no blocking receiver\n\
             iteration (rx.iter(), for r in rx); use recv_timeout/try_recv/try_iter.\n\
             Incident: the hung-worker bug class the supervisor's heartbeat machinery\n\
             exists to catch at runtime — a worker that stops responding must never also\n\
             hang the loop that is supposed to detect it.\n\
             Invariant: every serve/ event loop bounds its waits and keeps polling health."
        }
        "dead-pub" => {
            "dead-pub — plain-`pub` non-method items never referenced outside their\n\
             defining file (crate sources, tests, benches and examples all count as\n\
             references) are flagged to ratchet visibility down.\n\
             Incident: the serve/ rework left behind pub types whose only callers had\n\
             been deleted; the stale surface kept compiling and kept misleading readers.\n\
             Invariant: `pub` tracks the real API surface. Name-collision matching means\n\
             the rule under-reports, never over-reports; justified keeps take a pragma."
        }
        "pragma" => {
            "pragma — a `curlint:` comment must be a well-formed directive:\n\
             `curlint: allow(<rule>[, <rule>]) -- <reason>` (suppresses matching\n\
             violations on its own and the next line) or `curlint: hot-entry` (marks the\n\
             next fn as a hot-path root). Unknown rules, missing reasons, or unrecognized\n\
             directives are violations themselves, so a typo'd suppression cannot\n\
             silently do nothing."
        }
        _ => return None,
    };
    Some(text)
}

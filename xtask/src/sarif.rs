//! SARIF 2.1.0 output for `cargo xtask lint --emit sarif`, consumed by
//! GitHub code-scanning upload. Dependency-free like the rest of the
//! crate: the document is built by hand and then re-parsed with
//! [`crate::json`] before being returned, so a malformed emit fails the
//! lint run instead of failing silently at upload time.

use crate::rules::{explain, RULE_NAMES};

/// One result row: a violation with its ratchet status.
pub struct Row {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub col: usize,
    pub msg: String,
    /// Grandfathered (at/under baseline) rows become `warning`; new
    /// violations become `error`.
    pub new: bool,
}

/// Render the SARIF document, or an error if the emitted text does not
/// re-parse as JSON (an emitter bug, never a caller error).
pub fn emit(rows: &[Row]) -> Result<String, String> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\",\n");
    s.push_str("  \"version\": \"2.1.0\",\n");
    s.push_str("  \"runs\": [\n    {\n");
    s.push_str("      \"tool\": {\n        \"driver\": {\n");
    s.push_str("          \"name\": \"curlint\",\n");
    s.push_str("          \"rules\": [\n");
    let rules: Vec<&str> = RULE_NAMES.iter().copied().filter(|r| *r != "kernel-purity").collect();
    for (i, rule) in rules.iter().enumerate() {
        let help = explain(rule).unwrap_or("");
        let short = help.lines().next().unwrap_or(rule);
        s.push_str("            {\n");
        s.push_str(&format!("              \"id\": {},\n", quote(rule)));
        s.push_str(&format!(
            "              \"shortDescription\": {{ \"text\": {} }},\n",
            quote(short)
        ));
        s.push_str(&format!(
            "              \"fullDescription\": {{ \"text\": {} }}\n",
            quote(help)
        ));
        s.push_str(if i + 1 == rules.len() { "            }\n" } else { "            },\n" });
    }
    s.push_str("          ]\n        }\n      },\n");
    s.push_str("      \"results\": [\n");
    for (i, row) in rows.iter().enumerate() {
        s.push_str("        {\n");
        s.push_str(&format!("          \"ruleId\": {},\n", quote(&row.rule)));
        let idx = rules.iter().position(|r| *r == row.rule);
        if let Some(idx) = idx {
            s.push_str(&format!("          \"ruleIndex\": {idx},\n"));
        }
        s.push_str(&format!(
            "          \"level\": {},\n",
            quote(if row.new { "error" } else { "warning" })
        ));
        s.push_str(&format!("          \"message\": {{ \"text\": {} }},\n", quote(&row.msg)));
        s.push_str("          \"locations\": [\n            {\n");
        s.push_str("              \"physicalLocation\": {\n");
        s.push_str(&format!(
            "                \"artifactLocation\": {{ \"uri\": {} }},\n",
            quote(&row.path)
        ));
        s.push_str(&format!(
            "                \"region\": {{ \"startLine\": {}, \"startColumn\": {} }}\n",
            row.line.max(1),
            row.col.max(1)
        ));
        s.push_str("              }\n            }\n          ]\n");
        s.push_str(if i + 1 == rows.len() { "        }\n" } else { "        },\n" });
    }
    s.push_str("      ]\n    }\n  ]\n}\n");
    crate::json::parse(&s).map_err(|e| format!("sarif emitter produced invalid JSON: {e}"))?;
    Ok(s)
}

/// JSON string literal with escaping.
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{parse, Value};

    fn rows() -> Vec<Row> {
        vec![
            Row {
                rule: "panic".into(),
                path: "rust/src/serve/mod.rs".into(),
                line: 12,
                col: 7,
                msg: "`unwrap()` can panic".into(),
                new: true,
            },
            Row {
                rule: "dead-pub".into(),
                path: "rust/src/util/record.rs".into(),
                line: 3,
                col: 1,
                msg: "pub fn `old_api` is never referenced — \"quote\" test".into(),
                new: false,
            },
        ]
    }

    #[test]
    fn emits_valid_sarif_with_levels_and_positions() {
        let text = emit(&rows()).unwrap();
        let doc = parse(&text).unwrap();
        assert_eq!(doc.get("version").and_then(Value::as_str), Some("2.1.0"));
        let runs = doc.get("runs").and_then(Value::as_arr).unwrap();
        let results = runs[0].get("results").and_then(Value::as_arr).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("level").and_then(Value::as_str), Some("error"));
        assert_eq!(results[1].get("level").and_then(Value::as_str), Some("warning"));
        let loc = results[0].get("locations").and_then(Value::as_arr).unwrap()[0]
            .get("physicalLocation")
            .unwrap();
        assert_eq!(
            loc.get("artifactLocation").and_then(|a| a.get("uri")).and_then(Value::as_str),
            Some("rust/src/serve/mod.rs")
        );
        assert_eq!(
            loc.get("region").and_then(|r| r.get("startLine")).and_then(Value::as_f64),
            Some(12.0)
        );
    }

    #[test]
    fn every_active_rule_has_driver_metadata() {
        let text = emit(&[]).unwrap();
        let doc = parse(&text).unwrap();
        let runs = doc.get("runs").and_then(Value::as_arr).unwrap();
        let rules = runs[0]
            .get("tool")
            .and_then(|t| t.get("driver"))
            .and_then(|d| d.get("rules"))
            .and_then(Value::as_arr)
            .unwrap();
        // kernel-purity is a pragma alias, not an active rule.
        assert_eq!(rules.len(), RULE_NAMES.len() - 1);
        for r in rules {
            assert!(r.get("id").and_then(Value::as_str).is_some());
            let full = r
                .get("fullDescription")
                .and_then(|f| f.get("text"))
                .and_then(Value::as_str)
                .unwrap();
            assert!(full.contains("Invariant") || full.contains("directive"), "{full}");
        }
    }
}

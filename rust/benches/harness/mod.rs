//! The perf-barometer harness behind `cargo bench` (rebar-style).
//!
//! Structure (see rust/README.md § Benchmarks):
//!   - **Named workload models** ([`workloads`]): each declares what it
//!     measures and in which units, runs at an explicit parameter
//!     point, and returns a [`WorkloadRecord`] that lands in the v2
//!     recorded-run file `BENCH_native.json`.
//!   - **Sensitivity grids** ([`grid`]): first-class axis meshes
//!     (kv-keep × slots × prompt-len, …) rather than hardcoded triples.
//!   - **Tables** ([`tables`]): the paper's table/figure reproductions;
//!     print-only, not recorded.
//!
//! `bench_main.rs` is a thin driver over this module; the determinism
//! suite (`tests/bench_determinism.rs`) includes it via `#[path]` and
//! runs every workload twice, asserting the non-timing fingerprints
//! match bit-for-bit.

pub mod grid;
pub mod tables;
pub mod workloads;

use anyhow::Result;
use curing::calib::Calibration;
use curing::coordinator::Ctx;
use curing::pipeline::Pipeline;
use curing::tensor::TensorStore;
use curing::util::bench::{BenchResult, Bencher};
use curing::util::record::{Measurement, RecordedRun, Unit, WorkloadRecord};

/// Shared state every workload runs against: the experiment context,
/// quick-vs-full mode, and the cached tiny teacher + calibration that
/// the compression/PEFT workloads start from.
pub struct BenchCtx<'a> {
    pub ctx: &'a Ctx,
    pub quick: bool,
    pub tiny: Pipeline<'a>,
    pub dense: TensorStore,
    pub calib: Calibration,
}

impl<'a> BenchCtx<'a> {
    pub fn new(ctx: &'a Ctx, quick: bool, dense: TensorStore, calib: Calibration) -> Result<Self> {
        let tiny = ctx.pipeline("tiny")?;
        Ok(BenchCtx { ctx, quick, tiny, dense, calib })
    }

    /// The iteration policy for timed closures in this mode (warmup +
    /// min-iters floor + CV-based stop; see `util::bench::IterPolicy`).
    pub fn bencher(&self) -> Bencher {
        if self.quick {
            Bencher::quick()
        } else {
            Bencher::default()
        }
    }
}

/// One named workload model.
pub struct WorkloadSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub run: fn(&BenchCtx) -> Result<WorkloadRecord>,
}

/// The registry of recorded workload models, in report order.
pub fn workload_specs() -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec {
            name: "compress_time",
            about: "wall-clock CUR compression (the paper's headline metric) over k × r_max",
            run: workloads::compress_time,
        },
        WorkloadSpec {
            name: "prefill_heavy",
            about: "prompt-ingestion latency/throughput over a prompt-length sweep",
            run: workloads::prefill_heavy,
        },
        WorkloadSpec {
            name: "decode_heavy",
            about: "KV-cached greedy decode vs the cache-free replay reference",
            run: workloads::decode_heavy,
        },
        WorkloadSpec {
            name: "serve_mixed",
            about: "continuous-batching server under mixed traffic, slots + worker scaling",
            run: workloads::serve_mixed,
        },
        WorkloadSpec {
            name: "long_context",
            about: "streaming decode far past the window; quality + throughput vs length",
            run: workloads::long_context,
        },
        WorkloadSpec {
            name: "kv_cur",
            about: "CUR-compressed KV cache: keep × slots × prompt-len sensitivity mesh",
            run: workloads::kv_cur,
        },
        WorkloadSpec {
            name: "micro",
            about: "hot-path kernel micro-benchmarks (decomposition, matmuls, layer calls)",
            run: workloads::micro,
        },
        WorkloadSpec {
            name: "peft_heal",
            about: "Fig 5: full-model healing, ΔU vs LoRA vs MoRA (KD loss series)",
            run: workloads::peft_heal,
        },
        WorkloadSpec {
            name: "peft_task",
            about: "Fig 6: MRPC fine-tune vs wiki forgetting, four adapters",
            run: workloads::peft_task,
        },
        WorkloadSpec {
            name: "peft_uuid",
            about: "Fig 7: UUID memorization char accuracy per adapter",
            run: workloads::peft_uuid,
        },
    ]
}

/// Record a timed `BenchResult` as an `ms/iter` measurement (samples +
/// CV travel with it) and echo the human row.
pub fn put_timed(rec: &mut WorkloadRecord, r: &BenchResult) {
    println!("{}", r.row());
    rec.put(&r.name, Measurement::from_samples(r.samples.clone(), Unit::MsPerIter));
}

/// Derive a throughput measurement from a timed result: `units_per_iter`
/// work items per iteration over the measured mean wall time.
pub fn rate_of(r: &BenchResult, units_per_iter: f64, unit: Unit) -> Measurement {
    let value = if r.mean_ms > 0.0 { units_per_iter / (r.mean_ms / 1e3) } else { 0.0 };
    Measurement { value, unit, iters: r.iters, cv: r.cv, deterministic: false, samples: Vec::new() }
}

/// FNV-1a-64 over a set of token streams, truncated to 48 bits so the
/// value is exactly representable as an f64 measurement. Streams are
/// hashed in sorted order: multi-client workloads collect them in
/// completion order, and the determinism suite pins stream *content*,
/// not scheduling.
pub fn tokens_fnv(streams: &[Vec<i32>]) -> f64 {
    let mut ordered: Vec<&Vec<i32>> = streams.iter().collect();
    ordered.sort();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for s in ordered {
        for &t in s {
            for b in t.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        // Stream separator so [1,2]+[3] != [1]+[2,3].
        h ^= 0xff;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h & 0xffff_ffff_ffff) as f64
}

/// Pretty-print a workload's recorded measurements.
pub fn print_record(rec: &WorkloadRecord) {
    for (k, m) in &rec.measurements {
        if m.unit == Unit::MsPerIter && m.iters > 1 {
            continue; // already echoed as a bench row by put_timed
        }
        let noise = if m.iters > 1 {
            format!("  (cv {:>4.1}%, {} it)", 100.0 * m.cv, m.iters)
        } else {
            String::new()
        };
        println!("  {:<52} {:>14.4} {}{}", k, m.value, m.unit.as_str(), noise);
    }
    for (k, vs) in &rec.series {
        let first = vs.first().copied().unwrap_or(f64::NAN);
        let last = vs.last().copied().unwrap_or(f64::NAN);
        println!("  {:<52} series of {} ({first:.4} -> {last:.4})", k, vs.len());
    }
}

/// Run the named workloads and assemble a recorded run.
pub fn run_workloads(b: &BenchCtx, names: &[&str]) -> Result<RecordedRun> {
    let mut run = RecordedRun::new(b.ctx.rt.backend_name(), b.quick);
    for spec in workload_specs() {
        if !names.contains(&spec.name) {
            continue;
        }
        println!("\n════════ workload {} ════════", spec.name);
        println!("{}", spec.about);
        let t0 = std::time::Instant::now();
        let rec = (spec.run)(b)?;
        print_record(&rec);
        println!("──── {} done in {:.1}s", spec.name, t0.elapsed().as_secs_f64());
        run.put_workload(rec);
    }
    Ok(run)
}

//! First-class sensitivity grids (rebar / dag-crr style): a named set
//! of axes whose cartesian product defines the parameter points a
//! workload sweeps. Axes are recorded into the workload's params (so
//! the recorded run carries the mesh, not just the points) and every
//! point's measurements are keyed `metric[axis=v,axis=v]` so
//! `cargo xtask bench-diff` can match points across runs.

use curing::util::record::WorkloadRecord;
use curing::util::Json;

pub struct Axis {
    pub name: &'static str,
    pub values: Vec<f64>,
}

impl Axis {
    pub fn new(name: &'static str, values: &[f64]) -> Axis {
        Axis { name, values: values.to_vec() }
    }
}

pub struct Grid {
    pub axes: Vec<Axis>,
}

impl Grid {
    pub fn new(axes: Vec<Axis>) -> Grid {
        Grid { axes }
    }

    /// Cartesian product in row-major order (first axis slowest), each
    /// point a `(axis-name, value)` list in axis order.
    pub fn points(&self) -> Vec<Vec<(&'static str, f64)>> {
        let mut out: Vec<Vec<(&'static str, f64)>> = vec![Vec::new()];
        for axis in &self.axes {
            let mut next = Vec::with_capacity(out.len() * axis.values.len());
            for prefix in &out {
                for &v in &axis.values {
                    let mut p = prefix.clone();
                    p.push((axis.name, v));
                    next.push(p);
                }
            }
            out = next;
        }
        out
    }

    /// Record the mesh into the workload params as `grid_<axis>` arrays.
    pub fn record_axes(&self, rec: &mut WorkloadRecord) {
        for axis in &self.axes {
            rec.param_json(
                &format!("grid_{}", axis.name),
                Json::Arr(axis.values.iter().map(|&v| Json::Num(v)).collect()),
            );
        }
    }
}

/// Canonical measurement key for one metric at one grid point:
/// `tokens_per_s[keep=0.5,slots=4]`.
pub fn point_key(metric: &str, point: &[(&'static str, f64)]) -> String {
    let coords: Vec<String> = point.iter().map(|(k, v)| format!("{k}={}", fmt_val(*v))).collect();
    format!("{metric}[{}]", coords.join(","))
}

/// Axis-value formatting: integers without a trailing `.0`.
pub fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cartesian_order_and_keys() {
        let g = Grid::new(vec![Axis::new("keep", &[1.0, 0.5]), Axis::new("slots", &[2.0, 4.0])]);
        let pts = g.points();
        assert_eq!(pts.len(), 4);
        assert_eq!(point_key("tps", &pts[0]), "tps[keep=1,slots=2]");
        assert_eq!(point_key("tps", &pts[1]), "tps[keep=1,slots=4]");
        assert_eq!(point_key("tps", &pts[3]), "tps[keep=0.5,slots=4]");
    }
}

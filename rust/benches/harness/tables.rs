//! The paper's table/figure reproductions (print-only — the recorded
//! workload models live in [`super::workloads`]). Shapes (who wins,
//! scaling direction, crossovers) are the reproduction target; absolute
//! numbers differ from the paper's H100/8B setup by design
//! (see DESIGN.md §2).

use super::BenchCtx;
use anyhow::Result;
use curing::calib::Calibration;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{Ctx, EvalSizes};
use curing::data::{self, Corpus, CorpusKind};
use curing::eval;
use curing::heal::{heal_layers, HealOptions};
use curing::model::ModelConfig;
use curing::pipeline::{LayerPlan, Pipeline};
use curing::tensor::{Tensor, TensorStore};
use curing::util::stats::mib;
use curing::util::Rng;
use curing::wanda::Selector;

/// One print-only table/figure reproduction.
pub struct TableSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub run: fn(&BenchCtx) -> Result<()>,
}

/// The registry of tables, in paper order.
pub fn table_specs() -> Vec<TableSpec> {
    vec![
        TableSpec { name: "t1", about: "Table 1: compression time + size vs k", run: t1 },
        TableSpec { name: "t2", about: "Table 2 / Fig 8: weight-combination ablation", run: t2 },
        TableSpec { name: "t3", about: "Table 3 / Fig 9: r_max ablation", run: t3 },
        TableSpec { name: "f4", about: "Fig 4: metrics vs k, + healing at one point", run: f4 },
        TableSpec { name: "f10", about: "Fig 10: calibration-set size ablation", run: f10 },
        TableSpec { name: "t4", about: "Table 4 / Fig 11: angular distances + selection", run: t4 },
        TableSpec { name: "t5", about: "Table 5 / Fig 12: row/column selector ablation", run: t5 },
        TableSpec { name: "t6", about: "Table 6: activation norms, cured vs healed", run: t6 },
    ]
}

fn eval_sizes(b: &BenchCtx) -> EvalSizes {
    if b.quick {
        EvalSizes { ppl_batches: 1, boolq_items: 8, mmlu_items: 8 }
    } else {
        EvalSizes::default()
    }
}

// ------------------------------------------------------------------- t1

/// Table 1: compression time (s) and size reduction vs #compressed layers.
fn t1(b: &BenchCtx) -> Result<()> {
    let (pipe, dense, calib) = (&b.tiny, &b.dense, &b.calib);
    let cfg = &pipe.cfg;
    let max_k = cfg.middle_layers().len();
    let ks: Vec<usize> = (1..=max_k).collect();
    println!("Table 1 analog — tiny model, r_max=16, combo=all (paper: linear scaling)");
    println!("{:<4} {:>10} {:>12} {:>10}", "k", "time (s)", "saved (MiB)", "saved (%)");
    let mut rng = Rng::new(0, 0);
    for &k in &ks {
        let layers =
            curing::compress::select_layers(cfg, calib, k, LayerStrategy::Angular, &mut rng)?;
        let mut student = dense.clone();
        let rep = curing::compress::cure_layers(
            &mut student,
            cfg,
            calib,
            &layers,
            &CompressOptions::default(),
        )?;
        println!(
            "{:<4} {:>10.3} {:>12.2} {:>10.2}",
            k,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            100.0 * rep.bytes_saved() as f64 / dense.total_bytes() as f64
        );
    }
    // Analytic size accounting for the base (~90M) config at its ranks
    // (paper reports GiB; shape = linear in k, ~2x params at 2x rank).
    if let Ok(base) = ModelConfig::from_manifest(pipe.rt.manifest(), "base") {
        println!(
            "\nbase (~{}M params) analytic saved-bytes per layer:",
            base.total_params / 1_000_000
        );
        for r in &base.ranks {
            println!(
                "  r_max={:<4} {:>10.2} MiB/layer",
                r,
                mib(base.bytes_saved_per_layer("all", *r)? as f64)
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- t2

/// Table 2 + Figure 8: weight-combination ablation.
fn t2(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib) = (b.ctx, &b.tiny, &b.dense, &b.calib);
    let k = 3;
    let sizes = eval_sizes(b);
    println!("Table 2 / Fig 8 analog — combos at k={k}, r_max=16");
    println!(
        "{:<6} {:>10} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "combo", "time (s)", "saved (MiB)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for combo in ["all", "gate", "qk", "qg", "kg"] {
        let opts = CompressOptions { combo: combo.into(), ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<6} {:>10.3} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            combo,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: 'all' saves most; 'qk' smallest saving, best metrics");
    Ok(())
}

// ------------------------------------------------------------------- t3

/// Table 3 + Figure 9: r_max ablation (paper {128,256,512} ↔ ours {8,16,32}).
fn t3(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib) = (b.ctx, &b.tiny, &b.dense, &b.calib);
    let k = 3;
    let sizes = eval_sizes(b);
    println!("Table 3 / Fig 9 analog — rank sweep at k={k}");
    println!(
        "{:<6} {:>10} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "r_max", "time (s)", "saved (MiB)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for r in pipe.cfg.ranks.clone() {
        let opts = CompressOptions { r_max: r, ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<6} {:>10.3} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            r,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: larger rank → slower + less saving + better metrics");
    Ok(())
}

// ------------------------------------------------------------------- f4

/// Figure 4: metrics vs #compressed layers, with healing at one point.
fn f4(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib) = (b.ctx, &b.tiny, &b.dense, &b.calib);
    let sizes = eval_sizes(b);
    let max_k = if b.quick { 2 } else { pipe.cfg.middle_layers().len() };
    let heal_k = 3.min(max_k);
    let heal_steps = if b.quick { 10 } else { 80 };
    println!("Fig 4 analog — metric degradation vs k, + healing at k={heal_k}");
    println!("{:<10} {:>9} {:>9} {:>7} {:>7}", "model", "c4_ppl", "wiki_ppl", "boolq", "mmlu");
    let base = ctx.eval_suite(pipe, dense, &LayerPlan::all_dense(&pipe.cfg), &sizes)?;
    println!(
        "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3} (random: boolq 0.5, mmlu 0.25)",
        "dense", base.c4_ppl, base.wiki_ppl, base.boolq_acc, base.mmlu_acc
    );
    for k in 1..=max_k {
        let (student, plan, _) = ctx.compress_k(
            pipe,
            dense,
            calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            format!("cured k={k}"),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    // Healing point.
    let (mut student, plan, _) = ctx.compress_k(
        pipe,
        dense,
        calib,
        heal_k,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;
    let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
    let mut opt = TensorStore::new();
    heal_layers(
        pipe,
        dense,
        &mut student,
        &mut opt,
        &ctx.vocab,
        &mut corpus,
        &HealOptions { steps: heal_steps, ..Default::default() },
        0,
    )?;
    let healed = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
    println!(
        "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3}  <- healing recovers",
        format!("healed k={heal_k}"),
        healed.c4_ppl,
        healed.wiki_ppl,
        healed.boolq_acc,
        healed.mmlu_acc
    );
    Ok(())
}

// ------------------------------------------------------------------ f10

/// Figure 10: calibration-set size ablation.
fn f10(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense): (&Ctx, &Pipeline, &TensorStore) = (b.ctx, &b.tiny, &b.dense);
    let sizes_cfg = eval_sizes(b);
    let calib_sizes: &[usize] = if b.quick { &[16, 32] } else { &[32, 128, 512] };
    println!("Fig 10 analog — calibration size ablation (paper: 128 ≈ 1024)");
    println!(
        "{:<8} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "examples", "calib (s)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for &n in calib_sizes {
        let t0 = std::time::Instant::now();
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_CALIB);
        let calib = curing::calib::calibrate(pipe, dense, &ctx.vocab, &mut corpus, n)?;
        let secs = t0.elapsed().as_secs_f64();
        let (student, plan, _) = ctx.compress_k(
            pipe,
            dense,
            &calib,
            3,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes_cfg)?;
        println!(
            "{:<8} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            n, secs, suite.c4_ppl, suite.wiki_ppl, suite.boolq_acc, suite.mmlu_acc
        );
    }
    println!("expected shape: metrics ~flat with size; calibration time linear");
    Ok(())
}

// ------------------------------------------------------------------- t4

/// Table 4 + Figure 11: angular distances + layer-selection strategies.
fn t4(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib) = (b.ctx, &b.tiny, &b.dense, &b.calib);
    let sizes = eval_sizes(b);
    println!("Table 4 analog — per-layer angular distances (ascending):");
    let mut order = pipe.cfg.middle_layers();
    order.sort_by(|&a, &b| calib.angular[a].total_cmp(&calib.angular[b]));
    for &l in &order {
        print!("  L{l}:{:.4}", calib.angular[l]);
    }
    println!("\n\nFig 11 analog — selection strategy vs metrics at k=3:");
    println!("{:<9} {:>9} {:>9} {:>7} {:>7}", "strategy", "c4_ppl", "wiki_ppl", "boolq", "mmlu");
    for strat in [LayerStrategy::Angular, LayerStrategy::LastN, LayerStrategy::Random] {
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, 3, strat, &CompressOptions::default())?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<9} {:>9.2} {:>9.2} {:>7.3} {:>7.3}   layers {:?}",
            strat.label(),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc,
            rep.layers
        );
    }
    println!("expected shape: angular ≥ last-n > random (paper App. D.1)");
    Ok(())
}

// ------------------------------------------------------------------- t5

/// Table 5 + Figure 12: row/column selector ablation.
fn t5(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib) = (b.ctx, &b.tiny, &b.dense, &b.calib);
    let sizes = eval_sizes(b);
    let k = 3;
    println!("Table 5 / Fig 12 analog — selector ablation at k={k}:");
    println!(
        "{:<8} {:>12} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "selector", "Σ‖CUR‖_F", "Σ‖W−CUR‖_F", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for sel in Selector::ALL {
        let opts = CompressOptions { selector: sel, ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let cur_fro: f64 = rep.weights.iter().map(|w| w.cur_fro).sum();
        let diff: f64 = rep.weights.iter().map(|w| w.diff_fro).sum();
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<8} {:>12.2} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            sel.label(),
            cur_fro,
            diff,
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: CURing smallest ‖W−CUR‖_F; Random worst metrics");
    Ok(())
}

// ------------------------------------------------------------------- t6

/// Table 6: per-weight activation norms, teacher vs student vs healed.
fn t6(b: &BenchCtx) -> Result<()> {
    let (ctx, pipe, dense, calib): (&Ctx, &Pipeline, &TensorStore, &Calibration) =
        (b.ctx, &b.tiny, &b.dense, &b.calib);
    let k = 3;
    let (mut student, _plan, _) = ctx.compress_k(
        pipe,
        dense,
        calib,
        k,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;
    // One calibration batch provides the projection inputs X.
    let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_EVAL);
    let (toks, _) = corpus.batch(&ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
    let tokens = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
    let fwd = pipe.forward_calib(dense, &tokens)?;
    let cured = curing::compress::cured_layers_of(&student);

    let table = |label: &str, student: &TensorStore| -> Result<()> {
        println!("  {label}:");
        println!(
            "    {:<6} {:>5} {:>12} {:>12} {:>12}",
            "layer", "proj", "‖XW‖ teach", "‖XCUR‖ stud", "‖W−CUR‖_F"
        );
        for &l in &cured {
            for row in eval::activation_rows(dense, student, l, &fwd.attn_in[l], &fwd.ffn_in[l])? {
                println!(
                    "    {:<6} {:>5} {:>12.2} {:>12.2} {:>12.2}",
                    row.layer, row.proj, row.teacher_norm, row.student_norm, row.weight_diff
                );
            }
        }
        Ok(())
    };
    println!("Table 6 analog — activation Frobenius norms (teacher vs student):");
    table("cured (no healing)", &student)?;
    // Heal and re-measure: differences must shrink (paper's claim).
    let heal_steps = if b.quick { 10 } else { 60 };
    let mut hcorpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
    let mut opt = TensorStore::new();
    heal_layers(
        pipe,
        dense,
        &mut student,
        &mut opt,
        &ctx.vocab,
        &mut hcorpus,
        &HealOptions { steps: heal_steps, ..Default::default() },
        0,
    )?;
    table(&format!("healed ({heal_steps} steps)"), &student)?;
    println!("expected shape: healed ‖W−CUR‖_F shrinks; student norms approach teacher");
    Ok(())
}

//! The named workload models of the perf barometer.
//!
//! Every function takes the shared [`BenchCtx`] and returns a
//! [`WorkloadRecord`]: an explicit parameter point, measurements with
//! units (+ samples/CV for timed rows), and deterministic outputs
//! (token-stream hashes, byte footprints, losses) that the determinism
//! suite pins across in-process runs.

use super::grid::{point_key, Axis, Grid};
use super::{put_timed, rate_of, tokens_fnv, BenchCtx};
use anyhow::Result;
use curing::backend::native::math;
use curing::backend::{KvCache, KvPolicy};
use curing::calib::Calibration;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::cur;
use curing::data::{self, Corpus, CorpusKind, TrainItem};
use curing::eval;
use curing::heal::{StepMode, SwitchedRunner};
use curing::linalg::{jacobi_svd, rand_svd, Mat};
use curing::peft::{init_adapters, trainable_params, Adapter};
use curing::pipeline::{LayerKind, LayerPlan, Pipeline};
use curing::serve::{
    drain_gen_responses, drain_score_responses, spawn_gen_clients, spawn_score_clients,
    ClusterServer, GenerationServer, Request,
};
use curing::tensor::{Tensor, TensorStore};
use curing::util::record::{Measurement, Unit, WorkloadRecord};
use curing::util::Rng;
use curing::wanda::Selector;
use std::sync::mpsc::channel;
use std::time::Duration;

/// A timing number derived from a timed row (e.g. a per-token cost
/// computed from two [`put_timed`] means): it inherits the row's
/// iteration evidence but has no raw samples of its own.
fn derived_timing(value: f64, unit: Unit, iters: usize, cv: f64) -> Measurement {
    Measurement { value, unit, iters, cv, deterministic: false, samples: Vec::new() }
}

// ---------------------------------------------------------- compress_time

/// The paper's headline metric: wall-clock CUR compression. Sweeps the
/// k × r_max mesh on the tiny config (paper Table 1: time scales
/// linearly in k) and records seconds, bytes saved and the saved
/// fraction per point.
pub fn compress_time(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("compress_time");
    let cfg = &b.tiny.cfg;
    let max_k = cfg.middle_layers().len();
    let grid = if b.quick {
        Grid::new(vec![Axis::new("k", &[1.0, 3.0]), Axis::new("r_max", &[16.0])])
    } else {
        Grid::new(vec![
            Axis::new("k", &[1.0, 3.0, max_k as f64]),
            Axis::new("r_max", &[8.0, 16.0, 32.0]),
        ])
    };
    rec.param_str("config", "tiny");
    rec.param_str("combo", "all");
    grid.record_axes(&mut rec);
    let iters = if b.quick { 2 } else { 3 };
    let total_bytes = b.dense.total_bytes() as f64;
    for point in grid.points() {
        let (k, r_max) = (point[0].1 as usize, point[1].1 as usize);
        let opts = CompressOptions { r_max, ..Default::default() };
        let mut samples = Vec::with_capacity(iters);
        let mut bytes_saved = 0.0;
        for _ in 0..iters {
            let (_student, _plan, rep) =
                b.ctx.compress_k(&b.tiny, &b.dense, &b.calib, k, LayerStrategy::Angular, &opts)?;
            samples.push(rep.seconds_total);
            bytes_saved = rep.bytes_saved() as f64;
        }
        let compress_s = Measurement::from_samples(samples, Unit::Seconds);
        rec.put(&point_key("compress_s", &point), compress_s);
        rec.put(&point_key("bytes_saved", &point), Measurement::point(bytes_saved, Unit::Bytes));
        rec.put(
            &point_key("saved_frac", &point),
            Measurement::point(bytes_saved / total_bytes, Unit::Ratio),
        );
    }
    if let Some(m) = rec.get("compress_s[k=3,r_max=16]") {
        println!(
            "headline: k=3 r_max=16 compresses in {:.3}s (paper: Llama3.1-8B in 129s)",
            m.value
        );
    }
    Ok(rec)
}

// ---------------------------------------------------------- prefill_heavy

/// Prompt ingestion: one-token generations whose cost is all prefill,
/// over a prompt-length sweep on the tiny config.
pub fn prefill_heavy(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("prefill_heavy");
    let cfg = b.tiny.cfg.clone();
    let plan = LayerPlan::all_dense(&cfg);
    let n_prompts = 4usize;
    let grid = if b.quick {
        Grid::new(vec![Axis::new("prompt", &[16.0, 64.0])])
    } else {
        Grid::new(vec![Axis::new("prompt", &[16.0, 32.0, 64.0])])
    };
    rec.param_str("config", "tiny");
    rec.param_num("batch", n_prompts as f64);
    grid.record_axes(&mut rec);
    let bench = b.bencher();
    let mut all_tokens: Vec<Vec<i32>> = Vec::new();
    for point in grid.points() {
        let p = (point[0].1 as usize).min(cfg.seq);
        let mut corpus = Corpus::new(CorpusKind::SynthC4, 4100 + p as u64);
        let prompts: Vec<Vec<i32>> =
            (0..n_prompts).map(|_| corpus.sequence(&b.ctx.vocab, p)).collect();
        let r = bench.run(&point_key("prefill_ms", &point), || {
            b.tiny.generate_greedy(&b.dense, &plan, &prompts, 1).map(|t| t.len())
        });
        put_timed(&mut rec, &r);
        rec.put(
            &point_key("prompt_tokens_per_s", &point),
            rate_of(&r, (n_prompts * p) as f64, Unit::TokensPerS),
        );
        all_tokens.extend(b.tiny.generate_greedy(&b.dense, &plan, &prompts, 1)?);
    }
    rec.put("tokens_fnv", Measurement::point(tokens_fnv(&all_tokens), Unit::Count));
    Ok(rec)
}

// ----------------------------------------------------------- decode_heavy

/// Decode-dominated generation: short prompt, long KV-cached decode,
/// against the cache-free replay reference (tiny config).
pub fn decode_heavy(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("decode_heavy");
    let cfg = b.tiny.cfg.clone();
    let plan = LayerPlan::all_dense(&cfg);
    let prompt: Vec<i32> = (1..9).collect();
    let n_dec = if b.quick { 4 } else { 16 };
    rec.param_str("config", "tiny");
    rec.param_num("prompt", prompt.len() as f64);
    rec.param_num("n_dec", n_dec as f64);
    let bench = b.bencher();
    let r_prefill = bench.run("prefill_1tok_ms", || {
        b.tiny.generate_greedy(&b.dense, &plan, &[prompt.clone()], 1).map(|t| t.len())
    });
    put_timed(&mut rec, &r_prefill);
    let r_kv = bench.run("decode_kv_ms", || {
        b.tiny.generate_greedy(&b.dense, &plan, &[prompt.clone()], n_dec).map(|t| t.len())
    });
    put_timed(&mut rec, &r_kv);
    let r_full = bench.run("decode_replay_ms", || {
        b.tiny.generate_greedy_uncached(&b.dense, &plan, &[prompt.clone()], n_dec).map(|t| t.len())
    });
    put_timed(&mut rec, &r_full);
    // Per-token decode latency: the KV path pays prefill once, then one
    // single-position pass per token; the reference replays the whole
    // history per token.
    let per_tok_kv = ((r_kv.mean_ms - r_prefill.mean_ms) / (n_dec as f64 - 1.0)).max(1e-6);
    let per_tok_full = r_full.mean_ms / n_dec as f64;
    rec.put(
        "per_token_kv_ms",
        derived_timing(per_tok_kv, Unit::MsPerIter, r_kv.iters, r_kv.cv),
    );
    rec.put(
        "per_token_replay_ms",
        derived_timing(per_tok_full, Unit::MsPerIter, r_full.iters, r_full.cv),
    );
    rec.put(
        "tokens_per_s_kv",
        derived_timing(1e3 / per_tok_kv, Unit::TokensPerS, r_kv.iters, r_kv.cv),
    );
    rec.put(
        "kv_speedup",
        Measurement::point(per_tok_full / per_tok_kv, Unit::Ratio).volatile(),
    );
    let toks = b.tiny.generate_greedy(&b.dense, &plan, &[prompt.clone()], n_dec)?;
    rec.put("tokens_fnv", Measurement::point(tokens_fnv(&toks), Unit::Count));
    println!(
        "decode per-token: kv {per_tok_kv:.4} ms vs replay {per_tok_full:.4} ms -> {:.1}x",
        per_tok_full / per_tok_kv
    );
    Ok(rec)
}

// ------------------------------------------------------------ serve_mixed

/// The continuous-batching server under load (mini config): generation
/// throughput over a slot sweep, a mixed score+generate round, faulted
/// traffic, and worker scaling behind the supervised cluster router
/// (clean and under an injected crash plan).
pub fn serve_mixed(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("serve_mixed");
    let pipe = b.ctx.pipeline("mini")?;
    let cfg = pipe.cfg.clone();
    let mut rng = Rng::new(77, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let n_req = 8usize;
    // Past the rotation boundary: prompt 8 + n_new > seq.
    let n_new = if b.quick { cfg.seq - 4 } else { cfg.seq + 8 };
    let slots_axis: &[f64] = if b.quick { &[1.0, 4.0] } else { &[1.0, 4.0, 8.0] };
    let workers_axis: &[f64] = if b.quick { &[1.0, 2.0] } else { &[1.0, 2.0, 4.0, 8.0] };
    let grid = Grid::new(vec![Axis::new("slots", slots_axis)]);
    rec.param_str("config", "mini");
    rec.param_num("requests", n_req as f64);
    rec.param_num("n_new", n_new as f64);
    grid.record_axes(&mut rec);
    rec.param_json(
        "grid_workers",
        curing::util::Json::Arr(workers_axis.iter().map(|&w| curing::util::Json::Num(w)).collect()),
    );
    let mut tps_first = 0.0;
    let mut tps_last = 0.0;
    for point in grid.points() {
        let slots = point[0].1 as usize;
        let (tx, rx) = channel::<Request>();
        let resps =
            spawn_gen_clients(&tx, &b.ctx.vocab, CorpusKind::SynthC4, 8, n_new, n_req, 1, 0);
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        let (out, _tally) = drain_gen_responses(&resps);
        let streams: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        println!(
            "  slots {slots}: {:>8.0} tok/s | occupancy {:>4.1} | prefills {} | p95 {:.3} ms",
            stats.tokens_per_s, stats.mean_active_slots, stats.prefills, stats.tok_p95_ms
        );
        rec.put(
            &point_key("tokens_per_s", &point),
            Measurement::point(stats.tokens_per_s, Unit::TokensPerS),
        );
        rec.put(
            &point_key("tok_p50_ms", &point),
            Measurement::point(stats.tok_p50_ms, Unit::MsPerIter),
        );
        rec.put(
            &point_key("tok_p95_ms", &point),
            Measurement::point(stats.tok_p95_ms, Unit::MsPerIter),
        );
        rec.put(
            &point_key("occupancy", &point),
            Measurement::point(stats.mean_active_slots, Unit::Ratio).volatile(),
        );
        rec.put(
            &point_key("prefills", &point),
            Measurement::point(stats.prefills as f64, Unit::Count),
        );
        rec.put(
            &point_key("tokens_fnv", &point),
            Measurement::point(tokens_fnv(&streams), Unit::Count),
        );
        if point[0].1 == slots_axis[0] {
            tps_first = stats.tokens_per_s;
        }
        if point[0].1 == slots_axis[slots_axis.len() - 1] {
            tps_last = stats.tokens_per_s;
        }
    }
    rec.put(
        "speedup_max_slots_vs_1",
        Measurement::point(tps_last / tps_first.max(1e-9), Unit::Ratio).volatile(),
    );

    // Mixed traffic: generation and scoring through the same intake
    // queue at 4 slots — the workload the server is named for.
    {
        let (tx, rx) = channel::<Request>();
        let gen_rx =
            spawn_gen_clients(&tx, &b.ctx.vocab, CorpusKind::SynthC4, 8, n_new, n_req / 2, 1, 0);
        let score_rx =
            spawn_score_clients(&tx, &b.ctx.vocab, CorpusKind::SynthWiki, cfg.seq, n_req / 2, 1, 0);
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots: 4,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        let (gen_out, _t1) = drain_gen_responses(&gen_rx);
        let (score_out, _t2) = drain_score_responses(&score_rx);
        let streams: Vec<Vec<i32>> = gen_out.into_iter().map(|r| r.tokens).collect();
        let mean_nll = score_out.iter().map(|r| r.mean_nll).sum::<f64>()
            / score_out.len().max(1) as f64;
        println!(
            "  mixed (4 slots, {} gen + {} score): {:>8.0} tok/s | score nll {mean_nll:.4}",
            n_req / 2,
            score_out.len(),
            stats.tokens_per_s
        );
        rec.put("tokens_per_s_mixed", Measurement::point(stats.tokens_per_s, Unit::TokensPerS));
        rec.put("score_mean_nll_mixed", Measurement::point(mean_nll, Unit::Nats));
        rec.put("scored_mixed", Measurement::point(score_out.len() as f64, Unit::Count));
        rec.put("tokens_fnv_mixed", Measurement::point(tokens_fnv(&streams), Unit::Count));
    }

    // Faulted traffic: ~1% decode faults at 4 slots — what rollback +
    // per-slot retry cost when the fleet is unhealthy.
    {
        let faults = curing::backend::fault::FaultPlan::parse("seed=7;decode=0.01")?;
        let frt = curing::runtime::Runtime::native().with_faults(faults);
        let fpipe = Pipeline { rt: &frt, cfg: cfg.clone() };
        let (tx, rx) = channel::<Request>();
        let _resps =
            spawn_gen_clients(&tx, &b.ctx.vocab, CorpusKind::SynthC4, 8, n_new, n_req, 1, 0);
        drop(tx);
        let server = GenerationServer {
            pipe: &fpipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots: 4,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        println!(
            "  faulted (decode p=0.01, 4 slots): {:>8.0} tok/s | p95 {:.3} ms | slot failures {}",
            stats.tokens_per_s, stats.tok_p95_ms, stats.slot_failures
        );
        rec.put("tokens_per_s_faulted", Measurement::point(stats.tokens_per_s, Unit::TokensPerS));
        rec.put("tok_p95_ms_faulted", Measurement::point(stats.tok_p95_ms, Unit::MsPerIter));
        rec.put(
            "slot_failures_faulted",
            Measurement::point(stats.slot_failures as f64, Unit::Count).volatile(),
        );
    }

    // Worker scaling behind the supervised cluster router, clean and
    // with an injected crash plan.
    let cstore = std::sync::Arc::new(store.clone());
    for crash in [false, true] {
        let suffix = if crash { "_crash" } else { "" };
        for &workers_f in workers_axis {
            let workers = workers_f as usize;
            let (tx, rx) = channel::<Request>();
            let resps =
                spawn_gen_clients(&tx, &b.ctx.vocab, CorpusKind::SynthC4, 8, n_new, n_req, 1, 0);
            drop(tx);
            let mut cluster =
                ClusterServer::new(cfg.clone(), cstore.clone(), plan.clone(), workers);
            cluster.max_wait = Duration::from_millis(5);
            cluster.retry_budget = 4;
            if crash {
                let plan = curing::backend::fault::FaultPlan::parse("seed=5;decode=0.002:crash")?;
                cluster = cluster.with_fault_plan(plan);
            }
            let stats = cluster.run(rx)?;
            println!(
                "  workers {workers}{}: {:>8.0} tok/s | p95 {:.3} ms | crashes {} | retried {}",
                if crash { " (crash p=0.002)" } else { "" },
                stats.tokens_per_s,
                stats.tok_p95_ms,
                stats.worker_crashes,
                stats.retried_requests
            );
            let wk = format!("workers={workers}");
            rec.put(
                &format!("tokens_per_s{suffix}[{wk}]"),
                Measurement::point(stats.tokens_per_s, Unit::TokensPerS),
            );
            rec.put(
                &format!("tok_p95_ms{suffix}[{wk}]"),
                Measurement::point(stats.tok_p95_ms, Unit::MsPerIter),
            );
            if crash {
                rec.put(
                    &format!("worker_crashes{suffix}[{wk}]"),
                    Measurement::point(stats.worker_crashes as f64, Unit::Count).volatile(),
                );
                rec.put(
                    &format!("retried_requests{suffix}[{wk}]"),
                    Measurement::point(stats.retried_requests as f64, Unit::Count).volatile(),
                );
            } else {
                // Crash-free replication must keep streams bit-identical.
                let (out, _tally) = drain_gen_responses(&resps);
                let streams: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
                rec.put(
                    &format!("tokens_fnv[{wk}]"),
                    Measurement::point(tokens_fnv(&streams), Unit::Count),
                );
            }
        }
    }
    Ok(rec)
}

// ----------------------------------------------------------- long_context

/// Streaming decode far past the window (mini config): throughput and
/// teacher-forced decode perplexity as the generation length grows to
/// `mult × window`, exact ring vs the CUR-compressed cache.
pub fn long_context(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("long_context");
    let pipe = b.ctx.pipeline("mini")?;
    let cfg = pipe.cfg.clone();
    let mut rng = Rng::new(81, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let grid = if b.quick {
        Grid::new(vec![Axis::new("mult", &[2.0])])
    } else {
        Grid::new(vec![Axis::new("mult", &[2.0, 4.0])])
    };
    rec.param_str("config", "mini");
    rec.param_num("window", cfg.seq as f64);
    grid.record_axes(&mut rec);
    let cur_policy = KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 };
    for point in grid.points() {
        let mult = point[0].1 as usize;
        let n_new = mult * cfg.seq;
        let mut corpus = Corpus::new(CorpusKind::SynthC4, 4300 + mult as u64);
        let prompts: Vec<Vec<i32>> = (0..2).map(|_| corpus.sequence(&b.ctx.vocab, 8)).collect();
        let t0 = std::time::Instant::now();
        let toks = pipe.generate_greedy(&store, &plan, &prompts, n_new)?;
        let secs = t0.elapsed().as_secs_f64();
        rec.put(
            &point_key("tokens_per_s", &point),
            Measurement::point((prompts.len() * n_new) as f64 / secs.max(1e-9), Unit::TokensPerS),
        );
        rec.put(
            &point_key("tokens_fnv", &point),
            Measurement::point(tokens_fnv(&toks), Unit::Count),
        );
        let seqs: Vec<Vec<i32>> =
            (0..2).map(|_| corpus.sequence(&b.ctx.vocab, mult * cfg.seq)).collect();
        let ppl_exact = eval::decode_perplexity(&pipe, &store, &plan, KvPolicy::Exact, &seqs)?;
        let ppl_cur = eval::decode_perplexity(&pipe, &store, &plan, cur_policy, &seqs)?;
        println!(
            "  mult {mult}: decode ppl exact {ppl_exact:.2} vs cur(keep=0.5) {ppl_cur:.2}"
        );
        rec.put(&point_key("decode_ppl_exact", &point), Measurement::point(ppl_exact, Unit::Ppl));
        rec.put(&point_key("decode_ppl_cur50", &point), Measurement::point(ppl_cur, Unit::Ppl));
    }
    Ok(rec)
}

// ----------------------------------------------------------------- kv_cur

/// CUR-compressed KV cache sensitivity mesh (mini config): keep-ratio ×
/// slots × prompt-len, decoding past the compaction high-water mark.
/// Records tokens/s, per-slot live cache bytes against the exact-ring
/// bound, compaction counts and stream hashes per point, plus the
/// quality harness at keep 0.5.
pub fn kv_cur(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("kv_cur");
    let pipe = b.ctx.pipeline("mini")?;
    let cfg = pipe.cfg.clone();
    let mut rng = Rng::new(79, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let n_req = 8usize;
    let n_new = if b.quick { cfg.seq + 8 } else { 2 * cfg.seq };
    let grid = if b.quick {
        Grid::new(vec![
            Axis::new("keep", &[1.0, 0.5, 0.25]),
            Axis::new("slots", &[2.0, 4.0]),
            Axis::new("prompt", &[8.0]),
        ])
    } else {
        Grid::new(vec![
            Axis::new("keep", &[1.0, 0.5, 0.25]),
            Axis::new("slots", &[2.0, 4.0]),
            Axis::new("prompt", &[8.0, 16.0]),
        ])
    };
    let exact_slot_bytes = KvCache::exact_slot_bound(cfg.n_layers, cfg.seq, cfg.d_model);
    rec.param_str("config", "mini");
    rec.param_num("requests", n_req as f64);
    rec.param_num("n_new", n_new as f64);
    grid.record_axes(&mut rec);
    rec.put("exact_slot_bytes", Measurement::point(exact_slot_bytes as f64, Unit::Bytes));
    for point in grid.points() {
        let (keep, slots, prompt_len) =
            (point[0].1 as f32, point[1].1 as usize, point[2].1 as usize);
        let policy = KvPolicy::Cur { keep, sinks: 4, recent: 8 };
        let (tx, rx) = channel::<Request>();
        let resps = spawn_gen_clients(
            &tx,
            &b.ctx.vocab,
            CorpusKind::SynthC4,
            prompt_len,
            n_new,
            n_req,
            1,
            0,
        );
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots,
            kv_policy: policy,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        let (out, _tally) = drain_gen_responses(&resps);
        let streams: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        let live_per_slot = stats.kv_live_bytes_mean / slots as f64;
        println!(
            "  keep {keep:<4} slots {slots} prompt {prompt_len:>2}: {:>8.0} tok/s | \
             compactions {:>4} | live {:>7.0} B/slot ({:.0}% of exact)",
            stats.tokens_per_s,
            stats.kv_compactions,
            live_per_slot,
            100.0 * live_per_slot / exact_slot_bytes as f64
        );
        rec.put(
            &point_key("tokens_per_s", &point),
            Measurement::point(stats.tokens_per_s, Unit::TokensPerS),
        );
        // Live bytes are a per-step mean over whichever requests were
        // resident — admission order is scheduling-dependent, so the
        // value is volatile even though each lane's footprint is not.
        rec.put(
            &point_key("live_bytes", &point),
            Measurement::point(live_per_slot, Unit::Bytes).volatile(),
        );
        rec.put(
            &point_key("compactions", &point),
            Measurement::point(stats.kv_compactions as f64, Unit::Count),
        );
        rec.put(
            &point_key("tokens_fnv", &point),
            Measurement::point(tokens_fnv(&streams), Unit::Count),
        );
    }
    // Quality harness at keep 0.5: greedy agreement + decode-ppl delta
    // vs the exact cache, decoding past the window.
    let mut corpus = Corpus::new(CorpusKind::SynthC4, 4242);
    let prompts: Vec<Vec<i32>> = (0..4).map(|_| corpus.sequence(&b.ctx.vocab, 8)).collect();
    let exact = pipe.generate_greedy(&store, &plan, &prompts, n_new)?;
    let cur_toks = pipe.generate_greedy_with_policy(
        &store,
        &plan,
        &prompts,
        n_new,
        KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 },
    )?;
    let total = (exact.len() * n_new) as f64;
    let matches: usize = exact
        .iter()
        .zip(&cur_toks)
        .map(|(a, c)| a.iter().zip(c).filter(|(x, y)| x == y).count())
        .sum();
    let seqs: Vec<Vec<i32>> = (0..2).map(|_| corpus.sequence(&b.ctx.vocab, 2 * cfg.seq)).collect();
    let ppl_exact = eval::decode_perplexity(&pipe, &store, &plan, KvPolicy::Exact, &seqs)?;
    let ppl_cur = eval::decode_perplexity(
        &pipe,
        &store,
        &plan,
        KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 },
        &seqs,
    )?;
    println!(
        "  quality keep50: greedy agreement {:.3} | decode ppl exact {ppl_exact:.2} \
         vs cur {ppl_cur:.2}",
        matches as f64 / total
    );
    rec.put("token_agreement_keep50", Measurement::point(matches as f64 / total, Unit::Ratio));
    rec.put("ppl_exact", Measurement::point(ppl_exact, Unit::Ppl));
    rec.put("ppl_keep50", Measurement::point(ppl_cur, Unit::Ppl));
    Ok(rec)
}

// ------------------------------------------------------------------ micro

/// Hot-path micro-benchmarks: decomposition math, tiled-vs-scalar and
/// packed-vs-unpacked kernels, dense/cured layer calls.
pub fn micro(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("micro");
    rec.param_str("config", "tiny");
    let mut rng = Rng::new(1, 0);
    let bench = b.bencher();
    let w_attn = Mat::random_normal(256, 256, &mut rng);
    let w_gate = Mat::random_normal(256, 704, &mut rng);
    let xnorm: Vec<f64> = (0..256).map(|_| rng.f64() + 0.1).collect();

    put_timed(&mut rec, &bench.run("jacobi_svd 256x256 (exact)", || jacobi_svd(&w_attn)));
    let mut r2 = Rng::new(2, 0);
    put_timed(
        &mut rec,
        &bench.run("rand_svd 256x704 k=16 (selection path)", || {
            rand_svd(&w_gate, 16, 8, 2, &mut r2)
        }),
    );
    let mut r3 = Rng::new(3, 0);
    put_timed(
        &mut rec,
        &bench.run("cur_decompose 256x704 r=16 (full)", || {
            cur::cur_decompose(&w_gate, &w_gate, 16, &mut r3).map(|c| c.row_idx.len())
        }),
    );
    let mut r4 = Rng::new(4, 0);
    put_timed(
        &mut rec,
        &bench.run("wanda+deim select 256x256 r=16", || {
            curing::wanda::select_indices(Selector::Curing, &w_attn, &xnorm, 16, &mut r4)
                .map(|(rows, cols)| rows.len() + cols.len())
        }),
    );

    // Tiled microkernels vs the scalar seed kernels (same threading).
    let mut r5 = Rng::new(5, 0);
    let (mk, kk, nk) = (256usize, 256usize, 256usize);
    let af = r5.normal_vec(mk * kk, 1.0);
    let bf = r5.normal_vec(kk * nk, 1.0);
    put_timed(
        &mut rec,
        &bench.run("matmul_nn tiled 256x256x256", || math::matmul_nn(&af, &bf, mk, kk, nk)),
    );
    put_timed(
        &mut rec,
        &bench.run("matmul_nn scalar 256x256x256", || {
            math::matmul_nn_scalar(&af, &bf, mk, kk, nk)
        }),
    );
    put_timed(
        &mut rec,
        &bench.run("matmul_nt tiled 256x256x256", || math::matmul_nt(&af, &bf, mk, kk, nk)),
    );
    put_timed(
        &mut rec,
        &bench.run("matmul_nt scalar 256x256x256", || {
            math::matmul_nt_scalar(&af, &bf, mk, kk, nk)
        }),
    );

    // Packed vs unpacked NT at the fused-decode head shape (8 active
    // rows, large-k B reused across steps — pack cost paid once).
    let mut r6 = Rng::new(78, 0);
    let (m, k, n) = (8usize, 256usize, 512usize);
    let a = r6.normal_vec(m * k, 1.0);
    let bt = r6.normal_vec(n * k, 1.0);
    let packed = math::pack_nt(&bt, n, k);
    put_timed(
        &mut rec,
        &bench.run("matmul_nt packed 8x256x512", || math::matmul_nt_packed(&a, &packed, m)),
    );
    put_timed(
        &mut rec,
        &bench.run("matmul_nt unpacked 8x256x512", || math::matmul_nt(&a, &bt, m, k, n)),
    );

    // Runtime latency: one dense vs one cured layer call (cached
    // train-path forward vs the cache-free inference forward).
    let cfg = &b.tiny.cfg;
    let mut rng6 = Rng::new(6, 0);
    let x = Tensor::from_f32(
        &[cfg.batch, cfg.seq, cfg.d_model],
        rng6.normal_vec(cfg.batch * cfg.seq * cfg.d_model, 1.0),
    );
    let backend = b.ctx.rt.backend_name();
    put_timed(
        &mut rec,
        &bench.run(&format!("{backend} layer_fwd_dense cached (b8 s64 d256)"), || {
            b.tiny.layer_forward(&b.dense, 1, &LayerKind::Dense, &x).map(|t| t.len())
        }),
    );
    put_timed(
        &mut rec,
        &bench.run(&format!("{backend} layer_fwd_dense infer (b8 s64 d256)"), || {
            b.tiny.layer_forward_infer(&b.dense, 1, &LayerKind::Dense, &x).map(|t| t.len())
        }),
    );
    // A cured store for layer 1.
    let calib = Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    };
    let mut student = b.dense.clone();
    curing::compress::cure_layers(&mut student, cfg, &calib, &[1], &CompressOptions::default())?;
    let kind = LayerKind::Cured { rank: 16, combo: "all".into() };
    put_timed(
        &mut rec,
        &bench.run(&format!("{backend} layer_fwd_cured r16 infer (b8 s64 d256)"), || {
            b.tiny.layer_forward_infer(&student, 1, &kind, &x).map(|t| t.len())
        }),
    );
    Ok(rec)
}

// -------------------------------------------------------------- peft_heal

/// Figure 5: healing curves — ΔU vs LoRA vs MoRA at equal budgets,
/// 0.9·KD(T=10) + 0.1·CE against the dense teacher. Records the full
/// Du KD-loss series (CI asserts it trends down on real runs).
pub fn peft_heal(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("peft_heal");
    // Du always runs >= 20 steps: the acceptance gate is a
    // monotonically-trending-down KD loss series over >= 20 steps.
    let du_steps = if b.quick { 20 } else { 30 };
    let other_steps = if b.quick { 6 } else { 30 };
    let k = 3;
    let pipe = &b.tiny;
    rec.param_str("config", "tiny");
    rec.param_num("k", k as f64);
    rec.param_num("du_steps", du_steps as f64);
    rec.param_num("other_steps", other_steps as f64);
    for adapter in [Adapter::Du, Adapter::Lora, Adapter::Mora] {
        let steps = if adapter == Adapter::Du { du_steps } else { other_steps };
        let (mut student, _plan, _) = b.ctx.compress_k(
            pipe,
            &b.dense,
            &b.calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut rng = Rng::new(11, 0);
        let mut adapters = init_adapters(adapter, &pipe.cfg, &b.dense, &b.calib, &mut rng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Heal);
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
        println!(
            "  {} (trainable ≈ {} params, {steps} steps):",
            adapter.label(),
            trainable_params(adapter, &pipe.cfg)?
        );
        let mut series = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            // Paper App. B uses 3e-4 at r=256; the tiny config's ΔU is
            // orders of magnitude smaller and needs a proportionally
            // hotter lr to move in few steps (same reasoning as
            // HealOptions::default — see EXPERIMENTS.md).
            let lr = curing::heal::cosine_lr(step, steps, 1e-2, steps / 5);
            let (toks, tgts) = corpus.batch(&b.ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
            let tokens = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
            let targets = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], tgts);
            let loss = runner.step(
                pipe,
                &b.dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                None,
                lr,
                step + 1,
            )?;
            series.push(loss);
        }
        let secs = t0.elapsed().as_secs_f64();
        let tag = adapter.tag();
        let final_loss = series.last().copied().unwrap_or(f64::NAN);
        println!("    final loss {final_loss:.4} after {steps} steps ({secs:.1}s)");
        rec.put(&format!("final_loss_{tag}"), Measurement::point(final_loss, Unit::Nats));
        rec.put(
            &format!("steps_per_s_{tag}"),
            Measurement::point(steps as f64 / secs.max(1e-9), Unit::StepsPerS),
        );
        if adapter == Adapter::Du {
            rec.put_series("du_loss", series);
        }
    }
    println!("expected shape: all recover; ΔU between LoRA and MoRA on wiki ppl (paper §5.2)");
    Ok(rec)
}

// -------------------------------------------------------------- peft_task

/// Figure 6: MRPC fine-tuning vs WikiText forgetting (4 methods).
pub fn peft_task(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("peft_task");
    let steps = if b.quick { 6 } else { 30 };
    let k = 3;
    let pipe = &b.tiny;
    let cfg = &pipe.cfg;
    rec.param_str("config", "tiny");
    rec.param_num("k", k as f64);
    rec.param_num("steps", steps as f64);
    // Fixed MRPC train/eval splits.
    let mut rng = Rng::new(77, 0);
    let train: Vec<TrainItem> =
        (0..64).map(|_| data::mrpc_item(&b.ctx.vocab, &mut rng, cfg.seq).1).collect();
    let eval_items: Vec<_> =
        (0..32).map(|_| data::mrpc_item(&b.ctx.vocab, &mut rng, cfg.seq).0).collect();
    for adapter in Adapter::ALL {
        let (mut student, _plan, _) = b.ctx.compress_k(
            pipe,
            &b.dense,
            &b.calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut arng = Rng::new(12, 0);
        let mut adapters = init_adapters(adapter, cfg, &b.dense, &b.calib, &mut arng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Task);
        let mut last_loss = f64::NAN;
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let lr = curing::heal::cosine_lr(step, steps, 3e-4, steps / 5);
            let (tokens, targets, mask) =
                eval::pack_train(&train, step * cfg.batch, cfg.batch, cfg.seq);
            last_loss = runner.step(
                pipe,
                &b.dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                Some(&mask),
                lr,
                step + 1,
            )?;
        }
        let secs = t0.elapsed().as_secs_f64();
        let acc = eval::choice_accuracy_switched(
            pipe,
            &b.dense,
            &student,
            &adapters,
            adapter,
            &eval_items,
        )?;
        let tag = adapter.tag();
        println!("  {}: task-loss {last_loss:.4}  mrpc-acc {acc:.3}", adapter.label());
        rec.put(&format!("final_loss_{tag}"), Measurement::point(last_loss, Unit::Nats));
        rec.put(
            &format!("steps_per_s_{tag}"),
            Measurement::point(steps as f64 / secs.max(1e-9), Unit::StepsPerS),
        );
        rec.put(&format!("mrpc_acc_{tag}"), Measurement::point(acc, Unit::Ratio));
    }
    println!("expected shape: lora/mora adapt fastest but drift most on wiki;");
    println!("curlora barely learns but barely forgets; ΔU sits between (paper Fig 6)");
    Ok(rec)
}

// -------------------------------------------------------------- peft_uuid

/// Figure 7: UUID→UUID memorization (loss + char accuracy).
pub fn peft_uuid(b: &BenchCtx) -> Result<WorkloadRecord> {
    let mut rec = WorkloadRecord::new("peft_uuid");
    let steps = if b.quick { 6 } else { 30 };
    let pipe = &b.tiny;
    let cfg = &pipe.cfg;
    let n_pairs = if b.quick { 32 } else { 128 };
    rec.param_str("config", "tiny");
    rec.param_num("steps", steps as f64);
    rec.param_num("pairs", n_pairs as f64);
    let pairs = data::uuid_pairs(n_pairs, 2024);
    let items: Vec<TrainItem> =
        pairs.iter().map(|(a, c)| data::uuid_item(&b.ctx.vocab, a, c, cfg.seq)).collect();
    for adapter in [Adapter::Du, Adapter::Lora, Adapter::Mora] {
        let (mut student, _plan, _) = b.ctx.compress_k(
            pipe,
            &b.dense,
            &b.calib,
            3,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut arng = Rng::new(13, 0);
        let mut adapters = init_adapters(adapter, cfg, &b.dense, &b.calib, &mut arng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Task);
        let mut last_loss = f64::NAN;
        for step in 0..steps {
            let lr = curing::heal::cosine_lr(step, steps, 1e-3, steps / 5);
            let (tokens, targets, mask) =
                eval::pack_train(&items, step * cfg.batch, cfg.batch, cfg.seq);
            last_loss = runner.step(
                pipe,
                &b.dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                Some(&mask),
                lr,
                step + 1,
            )?;
        }
        // Char accuracy on a fixed batch of training pairs
        // (memorization task: train accuracy is the metric).
        let (tokens_e, targets_e, mask_e) = eval::pack_train(&items, 0, cfg.batch, cfg.seq);
        let logits =
            eval::switched_logits(pipe, &b.dense, &student, &adapters, adapter, &tokens_e)?;
        let acc = eval::char_accuracy_host(&logits, targets_e.i32s()?, mask_e.f32s()?)?;
        let tag = adapter.tag();
        println!("  {}: loss {last_loss:.4}  char-acc {acc:.3}", adapter.label());
        rec.put(&format!("final_loss_{tag}"), Measurement::point(last_loss, Unit::Nats));
        rec.put(&format!("uuid_char_acc_{tag}"), Measurement::point(acc, Unit::Ratio));
    }
    println!("expected shape: MoRA > LoRA ≥ ΔU in convergence speed (paper Fig 7)");
    Ok(rec)
}

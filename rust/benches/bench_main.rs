//! Thin driver over the perf-barometer harness (`harness/`): named
//! workload models with units land in the v2 recorded-run file
//! `BENCH_native.json`; the paper's table/figure reproductions print.
//!
//! Usage:
//!   cargo bench                      # everything (moderate sizes)
//!   cargo bench -- workloads         # every recorded workload model
//!   cargo bench -- kv_cur t1         # subset (workloads and/or tables)
//!   CURING_BENCH_FAST=1 cargo bench  # quick mode (smoke sizes)
//!
//! Shapes (who wins, scaling direction, crossovers) are the reproduction
//! target — absolute numbers differ from the paper's H100/8B setup by
//! design (see DESIGN.md §2). Compare runs with
//! `cargo xtask bench-diff <old.json> <new.json>`.

mod harness;

use anyhow::Result;
use curing::coordinator::{default_pretrain_steps, Ctx};
use harness::{run_workloads, tables, workload_specs, BenchCtx};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.iter().any(|a| a == "--help" || a == "-h") {
        print_usage();
        return Ok(());
    }
    let filters: Vec<String> =
        raw.into_iter().filter(|a| !a.starts_with('-') && a != "bench").collect();

    let workload_names: Vec<&str> = workload_specs().iter().map(|s| s.name).collect();
    let table_names: Vec<&str> = tables::table_specs().iter().map(|s| s.name).collect();
    for f in &filters {
        let known = f == "workloads"
            || f == "tables"
            || workload_names.contains(&f.as_str())
            || table_names.contains(&f.as_str());
        anyhow::ensure!(known, "unknown bench target '{f}' (try --help)");
    }
    let pick = |all: &[&'static str], group: &str| -> Vec<&'static str> {
        if filters.is_empty() || filters.iter().any(|f| f == group) {
            return all.to_vec();
        }
        all.iter().copied().filter(|n| filters.iter().any(|f| f == n)).collect()
    };
    let selected_workloads = pick(&workload_names, "workloads");
    let selected_tables = pick(&table_names, "tables");

    let quick = curing::util::config::bench_fast();
    let ctx = Ctx::new()?;
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;
    let pipe = ctx.pipeline("tiny")?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let b = BenchCtx::new(&ctx, quick, dense, calib)?;

    if !selected_workloads.is_empty() {
        let run = run_workloads(&b, &selected_workloads)?;
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_native.json");
        run.merge_into(&path)?;
        println!("\nwrote {}", path.display());
    }
    for spec in tables::table_specs() {
        if !selected_tables.contains(&spec.name) {
            continue;
        }
        println!("\n════════ table {} ════════", spec.name);
        println!("{}", spec.about);
        let t0 = std::time::Instant::now();
        (spec.run)(&b)?;
        println!("──── {} done in {:.1}s", spec.name, t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn print_usage() {
    println!(
        "curing perf barometer — named workload models + the paper's tables.

USAGE: cargo bench [-- name ...]
  workloads              every recorded workload model
  tables                 every print-only table/figure reproduction
  (default: both groups)

  workload models (recorded to BENCH_native.json, schema v2):"
    );
    for s in workload_specs() {
        println!("    {:<14} {}", s.name, s.about);
    }
    println!("\n  tables (print-only):");
    for s in tables::table_specs() {
        println!("    {:<14} {}", s.name, s.about);
    }
    println!(
        "
  Every workload declares its units (tokens/s, ms/iter, s, bytes, …),
  runs timed rows under a warmup + min-iters + CV-stop policy, and
  serializes params/measurements/samples into BENCH_native.json.
  Compare two recorded runs:  cargo xtask bench-diff old.json new.json
  Validate a recorded run:    cargo xtask bench-check BENCH_native.json

ENV: CURING_BENCH_FAST=1   quick mode (smoke sizes)
     CURING_PRETRAIN_STEPS  pretraining length (cached store)
     CURING_COMMIT          commit sha stamped into the recorded run
     CURING_BACKEND         native|pjrt"
    );
}

//! Benchmark + experiment harness: regenerates every table and figure of
//! the paper's evaluation (see DESIGN.md §4 for the index), plus micro-
//! benchmarks of the hot paths.
//!
//! Usage:
//!   cargo bench                 # everything (moderate sizes)
//!   cargo bench -- t1 f4        # subset
//!   CURING_BENCH_FAST=1 cargo bench   # smoke sizes
//!
//! Shapes (who wins, scaling direction, crossovers) are the reproduction
//! target — absolute numbers differ from the paper's H100/8B setup by
//! design (see DESIGN.md §2).

use anyhow::Result;
use curing::backend::native::math;
use curing::backend::KvPolicy;
use curing::calib::Calibration;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx, EvalSizes};
use curing::cur;
use curing::data::{self, Corpus, CorpusKind, TrainItem};
use curing::eval;
use curing::heal::{heal_layers, HealOptions, StepMode, SwitchedRunner};
use curing::linalg::{jacobi_svd, rand_svd, Mat};
use curing::model::ModelConfig;
use curing::peft::{init_adapters, trainable_params, Adapter};
use curing::pipeline::{LayerKind, LayerPlan, Pipeline};
use curing::serve::{spawn_gen_clients, ClusterServer, GenerationServer, Request};
use curing::tensor::{Tensor, TensorStore};
use curing::util::bench::{BenchResult, Bencher};
use curing::util::stats::mib;
use curing::util::{Json, JsonObj, Rng};
use curing::wanda::Selector;
use std::sync::mpsc::channel;
use std::time::Duration;

fn fast() -> bool {
    curing::util::config::bench_fast()
}

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.iter().any(|a| a == "--help" || a == "-h") {
        print_usage();
        return Ok(());
    }
    let filters: Vec<String> =
        raw.into_iter().filter(|a| !a.starts_with('-') && a != "bench").collect();
    let all = [
        "micro", "serve", "kv_cur", "t1", "t2", "t3", "f4", "f5", "f6", "f7", "f10", "t4",
        "t5", "t6",
    ];
    let selected: Vec<&str> = if filters.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|n| filters.iter().any(|f| f == n)).collect()
    };
    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    // The PEFT-comparison benches (f5/f6/f7) run the switched full-model
    // graphs natively — no artifacts, no skips, on every backend.
    for name in selected {
        println!("\n════════ bench {name} ════════");
        let t0 = std::time::Instant::now();
        match name {
            "micro" => micro(&ctx, &pipe, &dense)?,
            "serve" => serve_bench(&ctx)?,
            "kv_cur" => kv_cur_bench(&ctx)?,
            "t1" => t1(&ctx, &pipe, &dense, &calib)?,
            "t2" => t2(&ctx, &pipe, &dense, &calib)?,
            "t3" => t3(&ctx, &pipe, &dense, &calib)?,
            "f4" => f4(&ctx, &pipe, &dense, &calib)?,
            "f5" => f5(&ctx, &pipe, &dense, &calib)?,
            "f6" => f6(&ctx, &pipe, &dense, &calib)?,
            "f7" => f7(&ctx, &pipe, &dense, &calib)?,
            "f10" => f10(&ctx, &pipe, &dense)?,
            "t4" => t4(&ctx, &pipe, &dense, &calib)?,
            "t5" => t5(&ctx, &pipe, &dense, &calib)?,
            "t6" => t6(&ctx, &pipe, &dense, &calib)?,
            _ => unreachable!(),
        }
        println!("──── {name} done in {:.1}s", t0.elapsed().as_secs_f64());
    }
    Ok(())
}

fn print_usage() {
    println!(
        "curing bench harness — regenerates the paper's tables/figures.

USAGE: cargo bench [-- name ...]
  names: micro serve kv_cur t1 t2 t3 f4 f5 f6 f7 f10 t4 t5 t6 (default: all)
  f5/f6/f7 (the PEFT comparisons) run the switched full-model graphs
  natively — no pjrt, no artifacts.
  micro, serve, kv_cur, f5, f6 and f7 also write machine-readable
  results to BENCH_native.json at the repo root (perf trajectory across
  PRs); serve measures continuous-batching generation throughput at
  1/4/8 slots plus the packed-vs-unpacked NT head kernel; kv_cur
  measures the CUR-compressed KV cache (tokens/s, live cache bytes
  and quality vs the exact ring at keep 1.0/0.5/0.25); f5 records
  per-adapter heal losses incl. the Du KD-loss series CI checks.

ENV: CURING_BENCH_FAST=1   smoke sizes
     CURING_PRETRAIN_STEPS  pretraining length (cached store)
     CURING_BACKEND         native|pjrt"
    );
}

// ---------------------------------------------------------------- micro

/// Hot-path micro-benchmarks (decomposition math, kernels, runtime
/// calls, KV-cached decode). Also writes machine-readable results to
/// `BENCH_native.json` at the repo root so future PRs have a perf
/// trajectory to compare against.
fn micro(_ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore) -> Result<()> {
    let mut rng = Rng::new(1, 0);
    let b = if fast() { Bencher::quick() } else { Bencher::default() };
    let mut rows: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult| {
        println!("{}", r.row());
        rows.push(r);
    };
    let w_attn = Mat::random_normal(256, 256, &mut rng);
    let w_gate = Mat::random_normal(256, 704, &mut rng);
    let xnorm: Vec<f64> = (0..256).map(|_| rng.f64() + 0.1).collect();

    record(b.run("jacobi_svd 256x256 (exact)", || jacobi_svd(&w_attn)));
    let mut r2 = Rng::new(2, 0);
    record(b.run("rand_svd 256x704 k=16 (selection path)", || {
        rand_svd(&w_gate, 16, 8, 2, &mut r2)
    }));
    let mut r3 = Rng::new(3, 0);
    record(b.run("cur_decompose 256x704 r=16 (full)", || {
        cur::cur_decompose(&w_gate, &w_gate, 16, &mut r3).unwrap()
    }));
    let mut r4 = Rng::new(4, 0);
    record(b.run("wanda+deim select 256x256 r=16", || {
        curing::wanda::select_indices(Selector::Curing, &w_attn, &xnorm, 16, &mut r4).unwrap()
    }));
    println!("{}", b.run("matmul 256x256 * 256x256 (f64 Mat)", || w_attn.matmul(&w_attn)).row());

    // Tiled microkernels vs the scalar seed kernels (same threading).
    let mut r5 = Rng::new(5, 0);
    let (mk, kk, nk) = (256usize, 256usize, 256usize);
    let af = r5.normal_vec(mk * kk, 1.0);
    let bf = r5.normal_vec(kk * nk, 1.0);
    record(b.run("matmul_nn tiled 256x256x256", || math::matmul_nn(&af, &bf, mk, kk, nk)));
    record(b.run("matmul_nn scalar 256x256x256", || {
        math::matmul_nn_scalar(&af, &bf, mk, kk, nk)
    }));
    record(b.run("matmul_nt tiled 256x256x256", || math::matmul_nt(&af, &bf, mk, kk, nk)));
    record(b.run("matmul_nt scalar 256x256x256", || {
        math::matmul_nt_scalar(&af, &bf, mk, kk, nk)
    }));

    // Runtime latency: one dense vs one cured layer call (cached
    // train-path forward vs the cache-free inference forward).
    let cfg = &pipe.cfg;
    let mut rng5 = Rng::new(6, 0);
    let x = Tensor::from_f32(
        &[cfg.batch, cfg.seq, cfg.d_model],
        rng5.normal_vec(cfg.batch * cfg.seq * cfg.d_model, 1.0),
    );
    let backend = _ctx.rt.backend_name();
    record(b.run(&format!("{backend} layer_fwd_dense cached (b8 s64 d256)"), || {
        pipe.layer_forward(dense, 1, &LayerKind::Dense, &x).unwrap()
    }));
    record(b.run(&format!("{backend} layer_fwd_dense infer (b8 s64 d256)"), || {
        pipe.layer_forward_infer(dense, 1, &LayerKind::Dense, &x).unwrap()
    }));
    // A cured store for layer 1.
    let calib = Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    };
    let mut student = dense.clone();
    curing::compress::cure_layers(&mut student, cfg, &calib, &[1], &CompressOptions::default())?;
    let kind = LayerKind::Cured { rank: 16, combo: "all".into() };
    record(b.run(&format!("{backend} layer_fwd_cured r16 infer (b8 s64 d256)"), || {
        pipe.layer_forward_infer(&student, 1, &kind, &x).unwrap()
    }));

    // Greedy decode: prefill vs per-token, KV-cached vs the cache-free
    // replay reference, at (b=1, window=64) on the tiny config.
    let plan = LayerPlan::all_dense(cfg);
    let prompt: Vec<i32> = (1..9).collect();
    let n_dec = if fast() { 4 } else { 16 };
    let r_prefill = b.run("decode 1 tok = prefill (kv, b1 s64)", || {
        pipe.generate_greedy(dense, &plan, &[prompt.clone()], 1).unwrap()
    });
    record(r_prefill.clone());
    let r_kv = b.run(&format!("decode {n_dec} tok kv-cached (b1 s64)"), || {
        pipe.generate_greedy(dense, &plan, &[prompt.clone()], n_dec).unwrap()
    });
    record(r_kv.clone());
    let r_full = b.run(&format!("decode {n_dec} tok replay-reference (b1 s64)"), || {
        pipe.generate_greedy_uncached(dense, &plan, &[prompt.clone()], n_dec).unwrap()
    });
    record(r_full.clone());
    // Per-token decode latency: the KV path pays prefill once, then one
    // single-position pass per token; the reference replays the whole
    // history per token.
    let per_tok_kv = ((r_kv.mean_ms - r_prefill.mean_ms) / (n_dec as f64 - 1.0)).max(1e-6);
    let per_tok_full = r_full.mean_ms / n_dec as f64;
    let speedup = per_tok_full / per_tok_kv;
    println!(
        "decode per-token: kv {per_tok_kv:.4} ms vs replay {per_tok_full:.4} ms \
         -> {speedup:.1}x (prefill {:.4} ms, tokens/s kv {:.0})",
        r_prefill.mean_ms,
        1e3 / per_tok_kv
    );

    write_bench_json(backend, fast(), n_dec, per_tok_kv, per_tok_full, &r_prefill, &rows)?;
    Ok(())
}

fn bench_result_json(r: &BenchResult) -> Json {
    let mut o = JsonObj::new();
    o.insert("name", Json::Str(r.name.clone()));
    o.insert("iters", Json::Num(r.iters as f64));
    o.insert("mean_ms", Json::Num(r.mean_ms));
    o.insert("p50_ms", Json::Num(r.p50_ms));
    o.insert("p95_ms", Json::Num(r.p95_ms));
    o.insert("min_ms", Json::Num(r.min_ms));
    Json::Obj(o)
}

/// Machine-readable micro results at the repo root: the perf trajectory
/// future PRs compare against (CI validates the file parses).
fn write_bench_json(
    backend: &str,
    fast: bool,
    n_dec: usize,
    per_tok_kv: f64,
    per_tok_full: f64,
    prefill: &BenchResult,
    rows: &[BenchResult],
) -> Result<()> {
    let mut decode = JsonObj::new();
    decode.insert("n_tokens", Json::Num(n_dec as f64));
    decode.insert("prefill_ms", Json::Num(prefill.mean_ms));
    decode.insert("per_token_kv_ms", Json::Num(per_tok_kv));
    decode.insert("per_token_full_ms", Json::Num(per_tok_full));
    decode.insert("speedup", Json::Num(per_tok_full / per_tok_kv));
    decode.insert("tokens_per_s_kv", Json::Num(1e3 / per_tok_kv));
    decode.insert("tokens_per_s_full", Json::Num(1e3 / per_tok_full));
    merge_bench_json(vec![
        ("schema".to_string(), Json::Num(2.0)),
        ("backend".to_string(), Json::Str(backend.to_string())),
        ("config".to_string(), Json::Str("tiny".to_string())),
        ("fast".to_string(), Json::Bool(fast)),
        ("decode".to_string(), Json::Obj(decode)),
        ("rows".to_string(), Json::Arr(rows.iter().map(bench_result_json).collect())),
    ])
}

/// Merge top-level sections into `BENCH_native.json`, preserving
/// whatever other sections are already there (micro and serve each own
/// their keys and can run in either order).
fn merge_bench_json(sections: Vec<(String, Json)>) -> Result<()> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_native.json");
    let mut root = match std::fs::read_to_string(&path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Obj(o)) => o,
            _ => JsonObj::new(),
        },
        Err(_) => JsonObj::new(),
    };
    for (k, v) in sections {
        root.insert(k, v);
    }
    std::fs::write(&path, Json::Obj(root).to_string_pretty())?;
    println!("wrote {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------- serve

/// Continuous-batching generation throughput on the mini config: 8
/// requests decoded past the window-rotation boundary at 1 / 4 / 8
/// slots (slots=1 IS the sequential single-slot baseline the batched
/// numbers are measured against), plus the packed-vs-unpacked NT head
/// kernel at the fused-decode shape. Results land in the `serve`
/// section of `BENCH_native.json` (CI validates the keys).
fn serve_bench(ctx: &Ctx) -> Result<()> {
    let pipe = ctx.pipeline("mini")?;
    let cfg = pipe.cfg.clone();
    let mut rng = Rng::new(77, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let n_req = 8usize;
    // Past the rotation boundary: prompt 8 + n_new > seq 32.
    let n_new = if fast() { cfg.seq - 4 } else { cfg.seq + 8 };
    println!(
        "serve — continuous-batching generation, mini config \
         ({n_req} requests × {n_new} tokens, window {})",
        cfg.seq
    );
    let mut sec = JsonObj::new();
    sec.insert("config", Json::Str("mini".to_string()));
    sec.insert("requests", Json::Num(n_req as f64));
    sec.insert("n_new", Json::Num(n_new as f64));
    let mut tps = Vec::new();
    for &slots in &[1usize, 4, 8] {
        let (tx, rx) = channel::<Request>();
        let _resps = spawn_gen_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            8,
            n_new,
            n_req,
            1,
            0,
        );
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        println!(
            "  slots {slots}: {:>8.0} tok/s | occupancy {:>4.1} | prefills {} | \
             tok p50 {:.3} ms p95 {:.3} ms",
            stats.tokens_per_s,
            stats.mean_active_slots,
            stats.prefills,
            stats.tok_p50_ms,
            stats.tok_p95_ms
        );
        sec.insert(format!("tokens_per_s_slots{slots}"), Json::Num(stats.tokens_per_s));
        sec.insert(format!("tok_p50_ms_slots{slots}"), Json::Num(stats.tok_p50_ms));
        sec.insert(format!("tok_p95_ms_slots{slots}"), Json::Num(stats.tok_p95_ms));
        tps.push(stats.tokens_per_s);
    }
    let speedup = tps[tps.len() - 1] / tps[0].max(1e-9);
    println!("  8-slot batched vs sequential single-slot: {speedup:.1}x tokens/s");
    sec.insert("speedup_8_slots_vs_1", Json::Num(speedup));

    // Faulted traffic: the same workload at 4 slots against a backend
    // injecting ~1% decode faults — what rollback + per-slot retry and
    // the typed failure paths cost in throughput and tail latency when
    // the fleet is unhealthy (compare against the clean slots4 row).
    {
        let faults = curing::backend::fault::FaultPlan::parse("seed=7;decode=0.01")?;
        let frt = curing::runtime::Runtime::native().with_faults(faults);
        let fpipe = Pipeline { rt: &frt, cfg: cfg.clone() };
        let (tx, rx) = channel::<Request>();
        let _resps = spawn_gen_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            8,
            n_new,
            n_req,
            1,
            0,
        );
        drop(tx);
        let server = GenerationServer {
            pipe: &fpipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots: 4,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        println!(
            "  faulted (decode p=0.01, 4 slots): {:>8.0} tok/s | tok p95 {:.3} ms | \
             slot failures {}",
            stats.tokens_per_s, stats.tok_p95_ms, stats.slot_failures
        );
        sec.insert("tokens_per_s_faulted", Json::Num(stats.tokens_per_s));
        sec.insert("tok_p95_ms_faulted", Json::Num(stats.tok_p95_ms));
        sec.insert("slot_failures_faulted", Json::Num(stats.slot_failures as f64));
    }

    // Worker scaling: the same workload behind the supervised cluster
    // router at 1 / 2 / 4 / 8 replicated engines (2 KV slots each),
    // clean and with an injected crash plan — what replication buys in
    // throughput and what supervised replay costs when workers die.
    let cstore = std::sync::Arc::new(store.clone());
    for crash in [false, true] {
        let suffix = if crash { "_crash" } else { "" };
        for &workers in &[1usize, 2, 4, 8] {
            let (tx, rx) = channel::<Request>();
            let _resps = spawn_gen_clients(
                &tx,
                &ctx.vocab,
                CorpusKind::SynthC4,
                8,
                n_new,
                n_req,
                1,
                0,
            );
            drop(tx);
            let mut cluster =
                ClusterServer::new(cfg.clone(), cstore.clone(), plan.clone(), workers);
            cluster.max_wait = Duration::from_millis(5);
            cluster.retry_budget = 4;
            if crash {
                let plan =
                    curing::backend::fault::FaultPlan::parse("seed=5;decode=0.002:crash")?;
                cluster = cluster.with_fault_plan(plan);
            }
            let stats = cluster.run(rx)?;
            println!(
                "  workers {workers}{}: {:>8.0} tok/s | tok p95 {:.3} ms | crashes {} | \
                 retried {} | retired {}",
                if crash { " (crash p=0.002)" } else { "          " },
                stats.tokens_per_s,
                stats.tok_p95_ms,
                stats.worker_crashes,
                stats.retried_requests,
                stats.retired_workers
            );
            sec.insert(
                format!("tokens_per_s_workers{workers}{suffix}"),
                Json::Num(stats.tokens_per_s),
            );
            sec.insert(
                format!("tok_p95_ms_workers{workers}{suffix}"),
                Json::Num(stats.tok_p95_ms),
            );
            if crash {
                sec.insert(
                    format!("worker_crashes_workers{workers}{suffix}"),
                    Json::Num(stats.worker_crashes as f64),
                );
            }
        }
    }

    // Packed vs unpacked NT at the fused-decode head shape (8 active
    // rows, large-k B reused across steps — pack cost paid once).
    let b = if fast() { Bencher::quick() } else { Bencher::default() };
    let mut r = Rng::new(78, 0);
    let (m, k, n) = (8usize, 256usize, 512usize);
    let a = r.normal_vec(m * k, 1.0);
    let bt = r.normal_vec(n * k, 1.0);
    let packed = math::pack_nt(&bt, n, k);
    let r_packed =
        b.run("matmul_nt packed 8x256x512", || math::matmul_nt_packed(&a, &packed, m));
    let r_plain = b.run("matmul_nt unpacked 8x256x512", || math::matmul_nt(&a, &bt, m, k, n));
    println!("{}", r_packed.row());
    println!("{}", r_plain.row());
    sec.insert("nt_packed_ms", Json::Num(r_packed.mean_ms));
    sec.insert("nt_unpacked_ms", Json::Num(r_plain.mean_ms));
    merge_bench_json(vec![("serve".to_string(), Json::Obj(sec))])
}

// --------------------------------------------------------------- kv_cur

/// CUR-compressed KV cache (mini config): continuous-batching
/// generation under `--kv-policy cur:<keep>` at keep-ratios
/// 1.0 / 0.5 / 0.25, decoding well past the compaction high-water mark.
/// Records tokens/s, compaction counts and the mean per-slot live cache
/// bytes against the exact-ring bound, plus the quality harness at
/// keep 0.5: greedy-token agreement with the exact cache and the
/// teacher-forced decode-perplexity delta. Results land in the `kv_cur`
/// section of `BENCH_native.json` (CI validates the keys, including
/// live-bytes < exact bound).
fn kv_cur_bench(ctx: &Ctx) -> Result<()> {
    let pipe = ctx.pipeline("mini")?;
    let cfg = pipe.cfg.clone();
    let mut rng = Rng::new(79, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let (n_req, slots, prompt_len) = (8usize, 4usize, 8usize);
    let n_new = if fast() { cfg.seq + 8 } else { 2 * cfg.seq };
    let exact_slot_bytes =
        curing::backend::KvCache::exact_slot_bound(cfg.n_layers, cfg.seq, cfg.d_model);
    println!(
        "kv_cur — CUR-compressed KV cache, mini config ({n_req} requests × {n_new} tokens, \
         window {}, exact bound {exact_slot_bytes} B/slot)",
        cfg.seq
    );
    let mut sec = JsonObj::new();
    sec.insert("config", Json::Str("mini".to_string()));
    sec.insert("requests", Json::Num(n_req as f64));
    sec.insert("n_new", Json::Num(n_new as f64));
    sec.insert("exact_slot_bytes", Json::Num(exact_slot_bytes as f64));
    for (label, keep) in [("keep100", 1.0f32), ("keep50", 0.5), ("keep25", 0.25)] {
        let policy = KvPolicy::Cur { keep, sinks: 4, recent: 8 };
        let (tx, rx) = channel::<Request>();
        let _resps = spawn_gen_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            prompt_len,
            n_new,
            n_req,
            1,
            0,
        );
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(5),
            slots,
            kv_policy: policy,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        let live_per_slot = stats.kv_live_bytes_mean / slots as f64;
        println!(
            "  {label}: {:>8.0} tok/s | compactions {:>4} | live {:>7.0} B/slot \
             ({:.0}% of exact)",
            stats.tokens_per_s,
            stats.kv_compactions,
            live_per_slot,
            100.0 * live_per_slot / exact_slot_bytes as f64
        );
        sec.insert(format!("tokens_per_s_{label}"), Json::Num(stats.tokens_per_s));
        sec.insert(format!("live_bytes_{label}"), Json::Num(live_per_slot));
        sec.insert(format!("compactions_{label}"), Json::Num(stats.kv_compactions as f64));
    }
    // Quality harness at keep 0.5: greedy agreement + decode-ppl delta
    // vs the exact cache, on prompts decoding past the window.
    let mut corpus = Corpus::new(CorpusKind::SynthC4, 4242);
    let prompts: Vec<Vec<i32>> =
        (0..4).map(|_| corpus.sequence(&ctx.vocab, prompt_len)).collect();
    let exact = pipe.generate_greedy(&store, &plan, &prompts, n_new)?;
    let cur = pipe.generate_greedy_with_policy(
        &store,
        &plan,
        &prompts,
        n_new,
        KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 },
    )?;
    let total = (exact.len() * n_new) as f64;
    let matches: usize = exact
        .iter()
        .zip(&cur)
        .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
        .sum();
    let agreement = matches as f64 / total;
    let seqs: Vec<Vec<i32>> =
        (0..2).map(|_| corpus.sequence(&ctx.vocab, 2 * cfg.seq)).collect();
    let ppl_exact = eval::decode_perplexity(&pipe, &store, &plan, KvPolicy::Exact, &seqs)?;
    let ppl_cur = eval::decode_perplexity(
        &pipe,
        &store,
        &plan,
        KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 },
        &seqs,
    )?;
    println!(
        "  quality keep50: greedy agreement {:.3} | decode ppl exact {:.2} vs cur {:.2}",
        agreement, ppl_exact, ppl_cur
    );
    sec.insert("token_agreement_keep50", Json::Num(agreement));
    sec.insert("ppl_exact", Json::Num(ppl_exact));
    sec.insert("ppl_keep50", Json::Num(ppl_cur));
    merge_bench_json(vec![("kv_cur".to_string(), Json::Obj(sec))])
}

// ------------------------------------------------------------------- t1

/// Table 1: compression time (s) and size reduction vs #compressed layers.
fn t1(_ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let cfg = &pipe.cfg;
    let max_k = cfg.middle_layers().len();
    let ks: Vec<usize> = (1..=max_k).collect();
    println!("Table 1 analog — tiny model, r_max=16, combo=all (paper: linear scaling)");
    println!("{:<4} {:>10} {:>12} {:>10}", "k", "time (s)", "saved (MiB)", "saved (%)");
    let mut rng = Rng::new(0, 0);
    for &k in &ks {
        let layers =
            curing::compress::select_layers(cfg, calib, k, LayerStrategy::Angular, &mut rng)?;
        let mut student = dense.clone();
        let rep = curing::compress::cure_layers(
            &mut student,
            cfg,
            calib,
            &layers,
            &CompressOptions::default(),
        )?;
        println!(
            "{:<4} {:>10.3} {:>12.2} {:>10.2}",
            k,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            100.0 * rep.bytes_saved() as f64 / dense.total_bytes() as f64
        );
    }
    // Analytic size accounting for the base (~90M) config at its ranks
    // (paper reports GiB; shape = linear in k, ~2x params at 2x rank).
    if let Ok(base) = ModelConfig::from_manifest(pipe.rt.manifest(), "base") {
        println!(
            "\nbase (~{}M params) analytic saved-bytes per layer:",
            base.total_params / 1_000_000
        );
        for r in &base.ranks {
            println!(
                "  r_max={:<4} {:>10.2} MiB/layer",
                r,
                mib(base.bytes_saved_per_layer("all", *r)? as f64)
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------- t2

/// Table 2 + Figure 8: weight-combination ablation.
fn t2(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let k = 3;
    let sizes = eval_sizes();
    println!("Table 2 / Fig 8 analog — combos at k={k}, r_max=16");
    println!(
        "{:<6} {:>10} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "combo", "time (s)", "saved (MiB)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for combo in ["all", "gate", "qk", "qg", "kg"] {
        let opts = CompressOptions { combo: combo.into(), ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<6} {:>10.3} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            combo,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: 'all' saves most; 'qk' smallest saving, best metrics");
    Ok(())
}

// ------------------------------------------------------------------- t3

/// Table 3 + Figure 9: r_max ablation (paper {128,256,512} ↔ ours {8,16,32}).
fn t3(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let k = 3;
    let sizes = eval_sizes();
    println!("Table 3 / Fig 9 analog — rank sweep at k={k}");
    println!(
        "{:<6} {:>10} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "r_max", "time (s)", "saved (MiB)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for r in pipe.cfg.ranks.clone() {
        let opts = CompressOptions { r_max: r, ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<6} {:>10.3} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            r,
            rep.seconds_total,
            mib(rep.bytes_saved() as f64),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: larger rank → slower + less saving + better metrics");
    Ok(())
}

// ------------------------------------------------------------------- f4

/// Figure 4: metrics vs #compressed layers, with healing at one point.
fn f4(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let sizes = eval_sizes();
    let max_k = if fast() { 2 } else { pipe.cfg.middle_layers().len() };
    let heal_k = 3.min(max_k);
    let heal_steps = if fast() { 10 } else { 80 };
    println!("Fig 4 analog — metric degradation vs k, + healing at k={heal_k}");
    println!("{:<10} {:>9} {:>9} {:>7} {:>7}", "model", "c4_ppl", "wiki_ppl", "boolq", "mmlu");
    let base = ctx.eval_suite(pipe, dense, &LayerPlan::all_dense(&pipe.cfg), &sizes)?;
    println!(
        "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3} (random: boolq 0.5, mmlu 0.25)",
        "dense", base.c4_ppl, base.wiki_ppl, base.boolq_acc, base.mmlu_acc
    );
    for k in 1..=max_k {
        let (student, plan, _) = ctx.compress_k(
            pipe,
            dense,
            calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            format!("cured k={k}"),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    // Healing point.
    let (mut student, plan, _) = ctx.compress_k(
        pipe,
        dense,
        calib,
        heal_k,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;
    let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
    let mut opt = TensorStore::new();
    heal_layers(
        pipe,
        dense,
        &mut student,
        &mut opt,
        &ctx.vocab,
        &mut corpus,
        &HealOptions { steps: heal_steps, ..Default::default() },
        0,
    )?;
    let healed = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
    println!(
        "{:<10} {:>9.2} {:>9.2} {:>7.3} {:>7.3}  <- healing recovers",
        format!("healed k={heal_k}"),
        healed.c4_ppl,
        healed.wiki_ppl,
        healed.boolq_acc,
        healed.mmlu_acc
    );
    Ok(())
}

// ------------------------------------------------------------------- f5

/// Figure 5: healing curves — ΔU vs LoRA vs MoRA at equal budgets.
/// Runs natively (no artifacts); writes the `peft_heal` section of
/// `BENCH_native.json` (final loss + steps/s per adapter, plus the full
/// Du loss series — CI asserts it trends down).
fn f5(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    // Du always runs >= 20 steps: the acceptance gate is a
    // monotonically-trending-down KD loss series over >= 20 steps.
    let du_steps = if fast() { 20 } else { 30 };
    let other_steps = if fast() { 6 } else { 30 };
    let eval_every = if fast() { 5 } else { 10 };
    let k = 3;
    println!("Fig 5 analog — full-model healing (0.9·KD(T=10) + 0.1·CE), k={k}");
    let mut sec = JsonObj::new();
    sec.insert("config", Json::Str("tiny".to_string()));
    for adapter in [Adapter::Du, Adapter::Lora, Adapter::Mora] {
        let steps = if adapter == Adapter::Du { du_steps } else { other_steps };
        let (mut student, _plan, _) = ctx.compress_k(
            pipe,
            dense,
            calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut rng = Rng::new(11, 0);
        let mut adapters = init_adapters(adapter, &pipe.cfg, dense, calib, &mut rng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Heal);
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
        println!(
            "  {} (trainable ≈ {} params, {steps} steps):",
            adapter.label(),
            trainable_params(adapter, &pipe.cfg)?
        );
        let mut series = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            // Paper App. B uses 3e-4 at r=256; the tiny config's ΔU is
            // orders of magnitude smaller and needs a proportionally
            // hotter lr to move in few steps (same reasoning as
            // HealOptions::default — see EXPERIMENTS.md).
            let lr = curing::heal::cosine_lr(step, steps, 1e-2, steps / 5);
            let (toks, tgts) = corpus.batch(&ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
            let tokens = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
            let targets = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], tgts);
            let loss = runner.step(
                pipe,
                dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                None,
                lr,
                step + 1,
            )?;
            series.push(loss);
            if step % eval_every == 0 || step + 1 == steps {
                let mut wiki = Corpus::new(CorpusKind::SynthWiki, data::SEED_EVAL);
                let ppl = eval::perplexity_switched(
                    pipe,
                    dense,
                    &student,
                    &adapters,
                    adapter,
                    &ctx.vocab,
                    &mut wiki,
                    2,
                )?;
                println!("    step {step:>3}: loss {loss:.4}  wiki_ppl {ppl:.2}");
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let tag = adapter.tag();
        sec.insert(format!("final_loss_{tag}"), Json::Num(*series.last().unwrap()));
        sec.insert(format!("steps_per_s_{tag}"), Json::Num(steps as f64 / secs.max(1e-9)));
        if adapter == Adapter::Du {
            sec.insert(
                "du_loss_series",
                Json::Arr(series.iter().map(|&x| Json::Num(x)).collect()),
            );
        }
    }
    println!("expected shape: all recover; ΔU between LoRA and MoRA on wiki ppl (paper §5.2)");
    merge_bench_json(vec![("peft_heal".to_string(), Json::Obj(sec))])
}

// ------------------------------------------------------------------- f6

/// Figure 6: MRPC fine-tuning vs WikiText forgetting (4 methods).
/// Native; contributes per-adapter rows to the `peft_task` section of
/// `BENCH_native.json`.
fn f6(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let steps = if fast() { 6 } else { 30 };
    let eval_every = if fast() { 3 } else { 10 };
    let k = 3;
    let cfg = &pipe.cfg;
    // Fixed MRPC train/eval splits.
    let mut rng = Rng::new(77, 0);
    let train: Vec<TrainItem> =
        (0..64).map(|_| data::mrpc_item(&ctx.vocab, &mut rng, cfg.seq).1).collect();
    let eval_items: Vec<_> =
        (0..32).map(|_| data::mrpc_item(&ctx.vocab, &mut rng, cfg.seq).0).collect();
    println!("Fig 6 analog — fine-tune on synth-mrpc, watch synth-wiki ppl (forgetting)");
    let mut sec = JsonObj::new();
    sec.insert("config", Json::Str("tiny".to_string()));
    for adapter in Adapter::ALL {
        let (mut student, _plan, _) = ctx.compress_k(
            pipe,
            dense,
            calib,
            k,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut arng = Rng::new(12, 0);
        let mut adapters = init_adapters(adapter, cfg, dense, calib, &mut arng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Task);
        println!("  {}:", adapter.label());
        let mut last_loss = f64::NAN;
        let mut last_acc = f64::NAN;
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let lr = curing::heal::cosine_lr(step, steps, 3e-4, steps / 5);
            let (tokens, targets, mask) =
                eval::pack_train(&train, step * cfg.batch, cfg.batch, cfg.seq);
            let loss = runner.step(
                pipe,
                dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                Some(&mask),
                lr,
                step + 1,
            )?;
            last_loss = loss;
            if step % eval_every == 0 || step + 1 == steps {
                let acc = eval::choice_accuracy_switched(
                    pipe,
                    dense,
                    &student,
                    &adapters,
                    adapter,
                    &eval_items,
                )?;
                last_acc = acc;
                let mut wiki = Corpus::new(CorpusKind::SynthWiki, data::SEED_EVAL);
                let ppl = eval::perplexity_switched(
                    pipe,
                    dense,
                    &student,
                    &adapters,
                    adapter,
                    &ctx.vocab,
                    &mut wiki,
                    2,
                )?;
                println!(
                    "    step {step:>3}: task-loss {loss:.4}  mrpc-acc {acc:.3}  wiki_ppl {ppl:.2}"
                );
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let tag = adapter.tag();
        sec.insert(format!("final_loss_{tag}"), Json::Num(last_loss));
        sec.insert(format!("steps_per_s_{tag}"), Json::Num(steps as f64 / secs.max(1e-9)));
        sec.insert(format!("mrpc_acc_{tag}"), Json::Num(last_acc));
    }
    println!("expected shape: lora/mora adapt fastest but drift most on wiki;");
    println!("curlora barely learns but barely forgets; ΔU sits between (paper Fig 6)");
    merge_bench_json(vec![("peft_task".to_string(), Json::Obj(sec))])
}

// ------------------------------------------------------------------- f7

/// Figure 7: UUID→UUID memorization (loss + char accuracy).
fn f7(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let steps = if fast() { 6 } else { 30 };
    let eval_every = if fast() { 3 } else { 10 };
    let cfg = &pipe.cfg;
    let n_pairs = if fast() { 32 } else { 128 };
    let pairs = data::uuid_pairs(n_pairs, 2024);
    let items: Vec<TrainItem> =
        pairs.iter().map(|(a, b)| data::uuid_item(&ctx.vocab, a, b, cfg.seq)).collect();
    println!("Fig 7 analog — UUID→UUID mapping ({n_pairs} pairs, paper App. B format)");
    let mut uuid_acc = JsonObj::new();
    uuid_acc.insert("config", Json::Str("tiny".to_string()));
    for adapter in [Adapter::Du, Adapter::Lora, Adapter::Mora] {
        let (mut student, _plan, _) = ctx.compress_k(
            pipe,
            dense,
            calib,
            3,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let mut arng = Rng::new(13, 0);
        let mut adapters = init_adapters(adapter, cfg, dense, calib, &mut arng)?;
        let mut opt = TensorStore::new();
        let runner = SwitchedRunner::new(adapter, StepMode::Task);
        println!("  {}:", adapter.label());
        let mut last_acc = f64::NAN;
        for step in 0..steps {
            let lr = curing::heal::cosine_lr(step, steps, 1e-3, steps / 5);
            let (tokens, targets, mask) =
                eval::pack_train(&items, step * cfg.batch, cfg.batch, cfg.seq);
            let loss = runner.step(
                pipe,
                dense,
                &mut student,
                &mut adapters,
                &mut opt,
                &tokens,
                &targets,
                Some(&mask),
                lr,
                step + 1,
            )?;
            if step % eval_every == 0 || step + 1 == steps {
                // Char accuracy on a fixed batch of training pairs
                // (memorization task: train accuracy is the metric).
                let (tokens_e, targets_e, mask_e) =
                    eval::pack_train(&items, 0, cfg.batch, cfg.seq);
                let logits = eval::switched_logits(
                    pipe,
                    dense,
                    &student,
                    &adapters,
                    adapter,
                    &tokens_e,
                )?;
                let acc =
                    eval::char_accuracy_host(&logits, targets_e.i32s()?, mask_e.f32s()?)?;
                last_acc = acc;
                println!("    step {step:>3}: loss {loss:.4}  char-acc {acc:.3}");
            }
        }
        uuid_acc.insert(format!("uuid_char_acc_{}", adapter.tag()), Json::Num(last_acc));
    }
    println!("expected shape: MoRA > LoRA ≥ ΔU in convergence speed (paper Fig 7)");
    merge_bench_json(vec![("peft_uuid".to_string(), Json::Obj(uuid_acc))])
}

// ------------------------------------------------------------------ f10

/// Figure 10: calibration-set size ablation.
fn f10(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore) -> Result<()> {
    let sizes_cfg = eval_sizes();
    let calib_sizes: &[usize] = if fast() { &[16, 32] } else { &[32, 128, 512] };
    println!("Fig 10 analog — calibration size ablation (paper: 128 ≈ 1024)");
    println!(
        "{:<8} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "examples", "calib (s)", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for &n in calib_sizes {
        let t0 = std::time::Instant::now();
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_CALIB);
        let calib = curing::calib::calibrate(pipe, dense, &ctx.vocab, &mut corpus, n)?;
        let secs = t0.elapsed().as_secs_f64();
        let (student, plan, _) = ctx.compress_k(
            pipe,
            dense,
            &calib,
            3,
            LayerStrategy::Angular,
            &CompressOptions::default(),
        )?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes_cfg)?;
        println!(
            "{:<8} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            n, secs, suite.c4_ppl, suite.wiki_ppl, suite.boolq_acc, suite.mmlu_acc
        );
    }
    println!("expected shape: metrics ~flat with size; calibration time linear");
    Ok(())
}

// ------------------------------------------------------------------- t4

/// Table 4 + Figure 11: angular distances + layer-selection strategies.
fn t4(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let sizes = eval_sizes();
    println!("Table 4 analog — per-layer angular distances (ascending):");
    let mut order = pipe.cfg.middle_layers();
    order.sort_by(|&a, &b| calib.angular[a].total_cmp(&calib.angular[b]));
    for &l in &order {
        print!("  L{l}:{:.4}", calib.angular[l]);
    }
    println!("\n\nFig 11 analog — selection strategy vs metrics at k=3:");
    println!("{:<9} {:>9} {:>9} {:>7} {:>7}", "strategy", "c4_ppl", "wiki_ppl", "boolq", "mmlu");
    for strat in [LayerStrategy::Angular, LayerStrategy::LastN, LayerStrategy::Random] {
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, 3, strat, &CompressOptions::default())?;
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<9} {:>9.2} {:>9.2} {:>7.3} {:>7.3}   layers {:?}",
            strat.label(),
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc,
            rep.layers
        );
    }
    println!("expected shape: angular ≥ last-n > random (paper App. D.1)");
    Ok(())
}

// ------------------------------------------------------------------- t5

/// Table 5 + Figure 12: row/column selector ablation.
fn t5(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let sizes = eval_sizes();
    let k = 3;
    println!("Table 5 / Fig 12 analog — selector ablation at k={k}:");
    println!(
        "{:<8} {:>12} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "selector", "Σ‖CUR‖_F", "Σ‖W−CUR‖_F", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for sel in Selector::ALL {
        let opts = CompressOptions { selector: sel, ..Default::default() };
        let (student, plan, rep) =
            ctx.compress_k(pipe, dense, calib, k, LayerStrategy::Angular, &opts)?;
        let cur_fro: f64 = rep.weights.iter().map(|w| w.cur_fro).sum();
        let diff: f64 = rep.weights.iter().map(|w| w.diff_fro).sum();
        let suite = ctx.eval_suite(pipe, &student, &plan, &sizes)?;
        println!(
            "{:<8} {:>12.2} {:>12.2} {:>9.2} {:>9.2} {:>7.3} {:>7.3}",
            sel.label(),
            cur_fro,
            diff,
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("expected shape: CURing smallest ‖W−CUR‖_F; Random worst metrics");
    Ok(())
}

// ------------------------------------------------------------------- t6

/// Table 6: per-weight activation norms, teacher vs student vs healed.
fn t6(ctx: &Ctx, pipe: &Pipeline, dense: &TensorStore, calib: &Calibration) -> Result<()> {
    let k = 3;
    let (mut student, _plan, _) = ctx.compress_k(
        pipe,
        dense,
        calib,
        k,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;
    // One calibration batch provides the projection inputs X.
    let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_EVAL);
    let (toks, _) = corpus.batch(&ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
    let tokens = Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
    let fwd = pipe.forward_calib(dense, &tokens)?;
    let cured = curing::compress::cured_layers_of(&student);

    let table = |label: &str, student: &TensorStore| -> Result<()> {
        println!("  {label}:");
        println!(
            "    {:<6} {:>5} {:>12} {:>12} {:>12}",
            "layer", "proj", "‖XW‖ teach", "‖XCUR‖ stud", "‖W−CUR‖_F"
        );
        for &l in &cured {
            for row in eval::activation_rows(dense, student, l, &fwd.attn_in[l], &fwd.ffn_in[l])? {
                println!(
                    "    {:<6} {:>5} {:>12.2} {:>12.2} {:>12.2}",
                    row.layer, row.proj, row.teacher_norm, row.student_norm, row.weight_diff
                );
            }
        }
        Ok(())
    };
    println!("Table 6 analog — activation Frobenius norms (teacher vs student):");
    table("cured (no healing)", &student)?;
    // Heal and re-measure: differences must shrink (paper's claim).
    let heal_steps = if fast() { 10 } else { 60 };
    let mut hcorpus = Corpus::new(CorpusKind::SynthC4, data::SEED_HEAL);
    let mut opt = TensorStore::new();
    heal_layers(
        pipe,
        dense,
        &mut student,
        &mut opt,
        &ctx.vocab,
        &mut hcorpus,
        &HealOptions { steps: heal_steps, ..Default::default() },
        0,
    )?;
    table(&format!("healed ({heal_steps} steps)"), &student)?;
    println!("expected shape: healed ‖W−CUR‖_F shrinks; student norms approach teacher");
    Ok(())
}

fn eval_sizes() -> EvalSizes {
    if fast() {
        EvalSizes { ppl_batches: 1, boolq_items: 8, mmlu_items: 8 }
    } else {
        EvalSizes::default()
    }
}

//! Chaos suite: the serving engine under deterministic fault injection
//! ([`curing::backend::fault::FaultyBackend`]), deadlines, backpressure,
//! quarantine, degraded mode and graceful drain. Every trouble outcome
//! must be a typed [`ServeError`] on a response — never a panic, never
//! a silent wrong answer — and non-faulted generations must stay
//! bit-identical to a cache-free reference run.
//!
//! All tests are named `chaos_*` so the nightly ThreadSanitizer lane
//! can select them alongside the serve/kv suites.

use curing::backend::fault::{FaultPlan, FaultSite, FaultyBackend, InjectedFault};
use curing::backend::native::NativeBackend;
use curing::backend::{Backend, KvPolicy};
use curing::model::ModelConfig;
use curing::pipeline::{LayerPlan, Pipeline};
use curing::runtime::Runtime;
use curing::serve::{
    GenRequest, GenResponse, GenerationServer, Request, ScoreRequest, ScoreResponse, ServeError,
    ServeStats,
};
use curing::tensor::{Tensor, TensorStore};
use curing::util::Rng;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

/// The shared store every test serves: the mini config's dense init at
/// a fixed seed, so faulted and clean (oracle) runtimes see identical
/// weights.
fn mini_store() -> (ModelConfig, TensorStore) {
    let rt = Runtime::native();
    let cfg = ModelConfig::from_manifest(rt.manifest(), "mini").expect("mini config");
    let mut rng = Rng::new(31, 0);
    let store = cfg.init_dense(&mut rng);
    (cfg, store)
}

fn server<'p>(
    pipe: &'p Pipeline<'p>,
    store: &'p TensorStore,
    slots: usize,
) -> GenerationServer<'p> {
    GenerationServer {
        pipe,
        store,
        plan: LayerPlan::all_dense(&pipe.cfg),
        max_wait: Duration::from_millis(10),
        slots,
        kv_policy: KvPolicy::Exact,
        deadline: None,
        queue_cap: 0,
        tick: None,
    }
}

fn gen_request(
    prompt: Vec<i32>,
    n_new: usize,
    deadline: Option<Duration>,
) -> (Request, Receiver<GenResponse>) {
    let (rtx, rrx) = channel::<GenResponse>();
    let req = Request::Generate(GenRequest {
        prompt,
        n_new,
        enqueued: Instant::now(),
        deadline,
        respond: rtx,
    });
    (req, rrx)
}

fn score_request(seq: usize, seed: i32) -> (Request, Receiver<ScoreResponse>) {
    let (rtx, rrx) = channel::<ScoreResponse>();
    let tokens: Vec<i32> = (0..seq as i32).map(|i| (i * 7 + seed) % 384).collect();
    let targets: Vec<i32> = (0..seq as i32).map(|i| (i * 5 + seed + 1) % 384).collect();
    let req = Request::Score(ScoreRequest {
        tokens,
        targets,
        enqueued: Instant::now(),
        deadline: None,
        respond: rtx,
    });
    (req, rrx)
}

/// Same seed + same call sequence = same injected sites: the
/// determinism contract every other chaos test leans on. Two backends
/// built from one plan must produce an identical Ok/Err pattern over
/// an identical call sequence, with typed, downcastable errors.
#[test]
fn chaos_fault_plan_is_deterministic() {
    let (cfg, store) = mini_store();
    let x = Tensor::from_f32(&[1, 1, cfg.d_model], vec![0.25; cfg.d_model]);
    let ln_f = store.get("ln_f").unwrap().clone();
    let emb = store.get("emb").unwrap().clone();
    let pattern = |seed: u64| -> (Vec<bool>, u64) {
        let plan = FaultPlan::parse(&format!("seed={seed};head=0.5")).unwrap();
        let fb = FaultyBackend::new(Box::new(NativeBackend::new()), plan);
        let mut hits = Vec::new();
        for _ in 0..60 {
            match fb.head_logits(&cfg, &x, &ln_f, &emb) {
                Ok(logits) => {
                    assert!(logits.f32s().unwrap().iter().all(|v| v.is_finite()));
                    hits.push(false);
                }
                Err(e) => {
                    let inj = e
                        .downcast_ref::<InjectedFault>()
                        .expect("injected faults must stay downcastable");
                    assert_eq!(inj.site, FaultSite::Head);
                    hits.push(true);
                }
            }
        }
        (hits, fb.injected())
    };
    let (a, a_injected) = pattern(7);
    let (b, b_injected) = pattern(7);
    assert_eq!(a, b, "same seed must inject at the same calls");
    assert_eq!(a_injected, b_injected);
    assert!(a.iter().any(|&h| h), "p=0.5 over 60 calls never fired");
    assert!(a.iter().any(|&h| !h), "p=0.5 over 60 calls always fired");
    assert_eq!(a_injected, a.iter().filter(|&&h| h).count() as u64);
}

/// Mixed score + generate traffic against a backend injecting decode
/// errors and NaN head poisoning (≈5%/2% per call): every response is
/// either a success or a typed error, and every *successful* generation
/// is bit-identical to a cache-free oracle run on a clean runtime —
/// fault isolation never perturbs a surviving request's stream.
#[test]
fn chaos_mixed_traffic_survivors_match_cachefree_oracle() {
    let (cfg, store) = mini_store();
    let plan = FaultPlan::parse("seed=11;decode=0.05;head=0.02:nan").unwrap();
    let rt = Runtime::native().with_faults(plan);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let n_new = 12usize;
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| (0..3 + (i % 4)).map(|j| (13 * i + 7 * j + 1) % 384).collect())
        .collect();
    let (tx, rx) = channel::<Request>();
    // Generation clients on real threads (the TSan lane watches these),
    // submitting known prompts so the oracle can replay them.
    let mut gen_rxs = Vec::new();
    let mut handles = Vec::new();
    for half in prompts.chunks(4) {
        let mut reqs = Vec::new();
        for p in half {
            let (req, rrx) = gen_request(p.clone(), n_new, None);
            reqs.push(req);
            gen_rxs.push(rrx);
        }
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            for req in reqs {
                let _ = tx.send(req);
            }
        }));
    }
    let mut score_rxs = Vec::new();
    for i in 0..2 {
        let (req, rrx) = score_request(cfg.seq, 50 + i);
        tx.send(req).unwrap();
        score_rxs.push(rrx);
    }
    drop(tx);
    let stats = server(&pipe, &store, 2).run(rx).unwrap();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(stats.gen_served, prompts.len());
    assert!(
        stats.slot_failures > 0,
        "the fault plan never fired — the test exercised nothing"
    );
    // Oracle on a clean (fault-free) runtime, cache-free decode path.
    let clean = Runtime::native();
    let clean_pipe = Pipeline { rt: &clean, cfg: cfg.clone() };
    let lplan = LayerPlan::all_dense(&cfg);
    let mut ok = 0usize;
    for (p, rrx) in prompts.iter().zip(gen_rxs) {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        match resp.error {
            None => {
                let want = clean_pipe
                    .generate_greedy_uncached(&store, &lplan, &[p.clone()], n_new)
                    .unwrap();
                assert_eq!(
                    resp.tokens, want[0],
                    "non-faulted request diverged from the cache-free oracle for {p:?}"
                );
                ok += 1;
            }
            Some(ServeError::Failed { .. }) => {
                // Partial tokens (if any) are a prefix of the oracle
                // stream — the failure cut the request short, it never
                // corrupted what was already emitted.
                let want = clean_pipe
                    .generate_greedy_uncached(&store, &lplan, &[p.clone()], n_new)
                    .unwrap();
                assert!(
                    resp.tokens.len() <= want[0].len()
                        && resp.tokens == want[0][..resp.tokens.len()],
                    "failed request's partial tokens diverged for {p:?}"
                );
            }
            Some(other) => panic!("unexpected error kind under faults: {other:?}"),
        }
    }
    assert_eq!(ok + stats.slot_failures, prompts.len());
    for rrx in score_rxs {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        match resp.error {
            None => assert!(resp.mean_nll.is_finite()),
            Some(ServeError::Failed { .. }) => assert!(resp.mean_nll.is_nan()),
            Some(other) => panic!("unexpected score error under faults: {other:?}"),
        }
    }
}

/// Deadlines at both eviction points: an already-expired request is
/// timed out straight from the queue (empty tokens), and a request too
/// large for its budget is evicted mid-decode keeping its partial
/// stream. Both come back as typed [`ServeError::Timeout`].
#[test]
fn chaos_deadline_evicts_queued_and_mid_decode() {
    let (cfg, store) = mini_store();
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    // Queued eviction: a zero deadline expires before admission.
    let (tx, rx) = channel::<Request>();
    let (req_a, rx_a) = gen_request(vec![1, 2, 3], 4, Some(Duration::ZERO));
    let (req_b, rx_b) = gen_request(vec![4, 5, 6], 4, None);
    tx.send(req_a).unwrap();
    tx.send(req_b).unwrap();
    drop(tx);
    let stats = server(&pipe, &store, 1).run(rx).unwrap();
    let a = rx_a.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(a.error, Some(ServeError::Timeout { deadline_ms: 0 }));
    assert!(a.tokens.is_empty(), "a queued eviction never decoded anything");
    let b = rx_b.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(b.error, None);
    assert_eq!(b.tokens.len(), 4);
    assert_eq!(stats.timed_out, 1);
    assert_eq!(stats.gen_served, 2);
    // Mid-decode eviction: 5000 tokens cannot fit a 5 ms budget; the
    // response keeps whatever was decoded before the cutoff.
    let (tx, rx) = channel::<Request>();
    let n_new = 5000usize;
    let (req_c, rx_c) = gen_request(vec![7, 8, 9], n_new, Some(Duration::from_millis(5)));
    tx.send(req_c).unwrap();
    drop(tx);
    let stats = server(&pipe, &store, 1).run(rx).unwrap();
    let c = rx_c.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(c.error, Some(ServeError::Timeout { deadline_ms: 5 }));
    assert!(c.tokens.len() < n_new, "a 5 ms deadline cannot decode {n_new} tokens");
    assert_eq!(stats.timed_out, 1);
}

/// Bounded admission: with `queue_cap = 2` and six requests already on
/// the channel, exactly two are admitted and four shed with a typed
/// [`ServeError::Overloaded`] carrying the observed depth.
#[test]
fn chaos_overload_sheds_beyond_queue_cap() {
    let (cfg, store) = mini_store();
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for i in 0..6 {
        let (req, rrx) = gen_request(vec![1 + i, 2 + i, 3 + i], 2, None);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let mut srv = server(&pipe, &store, 2);
    srv.queue_cap = 2;
    let stats = srv.run(rx).unwrap();
    assert_eq!(stats.rejected, 4);
    assert_eq!(stats.gen_served, 2);
    let mut shed = 0usize;
    let mut served = 0usize;
    for rrx in resp_rxs {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        match resp.error {
            None => {
                assert_eq!(resp.tokens.len(), 2);
                served += 1;
            }
            Some(ServeError::Overloaded { depth, cap }) => {
                assert_eq!(cap, 2);
                assert_eq!(depth, 2, "shed at a full backlog");
                assert!(resp.tokens.is_empty());
                shed += 1;
            }
            Some(other) => panic!("unexpected shed error: {other:?}"),
        }
    }
    assert_eq!((served, shed), (2, 4));
}

/// Graceful drain: a [`Request::Shutdown`] stops admission (later
/// requests get [`ServeError::ShuttingDown`]), finishes the accepted
/// work, and reports the final stats on the shutdown channel — while
/// the request channel is still connected.
#[test]
fn chaos_graceful_drain_returns_final_stats() {
    let (cfg, store) = mini_store();
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let (tx, rx) = channel::<Request>();
    let (req1, rx1) = gen_request(vec![1, 2, 3], 3, None);
    let (req2, rx2) = gen_request(vec![4, 5], 3, None);
    let (stx, srx) = channel::<ServeStats>();
    let (req3, rx3) = gen_request(vec![6, 7], 3, None);
    tx.send(req1).unwrap();
    tx.send(req2).unwrap();
    tx.send(Request::Shutdown(stx)).unwrap();
    tx.send(req3).unwrap();
    // tx stays alive: the exit below is the drain, not a disconnect.
    let stats = server(&pipe, &store, 2).run(rx).unwrap();
    drop(tx);
    assert_eq!(stats.gen_served, 2);
    assert_eq!(stats.rejected, 1);
    for rrx in [rx1, rx2] {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.error, None);
        assert_eq!(resp.tokens.len(), 3);
    }
    let resp3 = rx3.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(resp3.error, Some(ServeError::ShuttingDown));
    let reported = srx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert_eq!(reported.gen_served, stats.gen_served);
    assert_eq!(reported.rejected, stats.rejected);
    assert_eq!(reported.tokens_generated, stats.tokens_generated);
}

/// Slot quarantine: with one lane and a backend failing every decode,
/// three consecutive request failures quarantine the slot; later
/// generations are answered (typed) instead of hanging, and the server
/// still exits cleanly.
#[test]
fn chaos_quarantine_shrinks_capacity_after_repeated_failures() {
    let (cfg, store) = mini_store();
    let plan = FaultPlan::parse("seed=5;decode=1.0").unwrap();
    let rt = Runtime::native().with_faults(plan);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for i in 0..5 {
        let (req, rrx) = gen_request(vec![1 + i, 2 + i], 3, None);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let stats = server(&pipe, &store, 1).run(rx).unwrap();
    assert_eq!(stats.gen_served, 5);
    assert_eq!(stats.slot_failures, curing::serve::QUARANTINE_AFTER);
    assert_eq!(stats.quarantined_slots, 1);
    for (i, rrx) in resp_rxs.into_iter().enumerate() {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        let Some(ServeError::Failed { detail }) = resp.error else {
            panic!("request {i} must fail typed, got {:?}", resp.error);
        };
        if i < curing::serve::QUARANTINE_AFTER {
            // Admitted and prefilled: the first token survives as a
            // partial stream even though every decode step failed.
            assert_eq!(resp.tokens.len(), 1, "request {i} kept its prefill token");
        } else {
            assert!(
                detail.contains("quarantined"),
                "request {i} must name the quarantine, got '{detail}'"
            );
            assert!(resp.tokens.is_empty());
        }
    }
}

/// Degraded mode: a backlog at ≥3/4 of `queue_cap` pushes a `cur` KV
/// policy down a keep level (counted in `degraded_steps`) while every
/// request still completes successfully.
#[test]
fn chaos_degraded_mode_steps_keep_down_under_backlog() {
    let (cfg, store) = mini_store();
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for i in 0..4 {
        let (req, rrx) = gen_request(vec![1 + i, 2 + i, 3 + i], 6, None);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let mut srv = server(&pipe, &store, 1);
    srv.kv_policy = KvPolicy::Cur { keep: 0.5, sinks: 2, recent: 4 };
    srv.queue_cap = 4;
    let stats = srv.run(rx).unwrap();
    assert!(
        stats.degraded_steps >= 1,
        "a backlog of 3 on cap 4 must trip degraded mode"
    );
    assert_eq!(stats.gen_served, 4);
    assert_eq!(stats.rejected, 0, "cap 4 admits all four requests");
    for rrx in resp_rxs {
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.error, None, "degraded mode must not fail requests");
        assert_eq!(resp.tokens.len(), 6);
    }
}

/// Scoring under head faults: NaN poisoning surfaces as a typed
/// non-finite failure, hard errors as a typed backend failure — never
/// a silent garbage score, never a server abort.
#[test]
fn chaos_score_faults_fail_typed() {
    let (cfg, store) = mini_store();
    for spec in ["seed=3;head=1.0:nan", "seed=3;head=1.0"] {
        let plan = FaultPlan::parse(spec).unwrap();
        let rt = Runtime::native().with_faults(plan);
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let (tx, rx) = channel::<Request>();
        let (req, rrx) = score_request(cfg.seq, 9);
        tx.send(req).unwrap();
        drop(tx);
        let stats = server(&pipe, &store, 1).run(rx).unwrap();
        assert_eq!(stats.served, 0, "a faulted score must not count as served");
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.mean_nll.is_nan());
        assert!(
            matches!(resp.error, Some(ServeError::Failed { .. })),
            "spec '{spec}' must fail typed, got {:?}",
            resp.error
        );
    }
}

//! Cluster suite: the supervised multi-worker server under worker
//! crashes, hangs, circuit-breaker retirement and graceful drain.
//! Replayed requests must stay bit-identical to the cache-free oracle,
//! dead capacity must turn into typed errors instead of hangs, and a
//! drained cluster must merge every worker's stats.
//!
//! All tests are named `cluster_*` so the nightly ThreadSanitizer lane
//! can select them alongside the serve/kv/chaos suites.

use curing::backend::fault::{
    mute_injected_crash_reports, FaultPlan, FaultSite, FaultyBackend, InjectedCrash,
};
use curing::backend::native::NativeBackend;
use curing::backend::Backend;
use curing::model::ModelConfig;
use curing::pipeline::{LayerPlan, Pipeline};
use curing::runtime::Runtime;
use curing::serve::{ClusterServer, GenRequest, GenResponse, Request, ServeError, ServeStats};
use curing::tensor::{Tensor, TensorStore};
use curing::util::Rng;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The shared store every test serves: the mini config's dense init at
/// a fixed seed, so cluster workers and the clean oracle runtime see
/// identical weights.
fn mini_store() -> (ModelConfig, Arc<TensorStore>) {
    let rt = Runtime::native();
    let cfg = ModelConfig::from_manifest(rt.manifest(), "mini").expect("mini config");
    let mut rng = Rng::new(31, 0);
    let store = cfg.init_dense(&mut rng);
    (cfg, Arc::new(store))
}

/// A test-sized cluster: 1 KV slot per worker, fast supervision knobs.
fn cluster(cfg: &ModelConfig, store: &Arc<TensorStore>, workers: usize) -> ClusterServer {
    let mut c = ClusterServer::new(cfg.clone(), store.clone(), LayerPlan::all_dense(cfg), workers);
    c.slots = 1;
    c.max_wait = Duration::from_millis(5);
    c.backoff_base = Duration::from_millis(1);
    c.backoff_max = Duration::from_millis(20);
    c
}

/// A worker-runtime factory where worker 0 always crashes at `site` and
/// every other worker is clean.
fn crashy_worker_zero(site: &str) -> curing::serve::WorkerRuntime {
    let spec = format!("seed=1;{site}=1.0:crash");
    Arc::new(move |w| {
        if w == 0 {
            Ok(Runtime::native().with_faults(FaultPlan::parse(&spec)?))
        } else {
            Ok(Runtime::native())
        }
    })
}

fn gen_request(prompt: Vec<i32>, n_new: usize) -> (Request, Receiver<GenResponse>) {
    let (rtx, rrx) = channel::<GenResponse>();
    let req = Request::Generate(GenRequest {
        prompt,
        n_new,
        enqueued: Instant::now(),
        deadline: None,
        respond: rtx,
    });
    (req, rrx)
}

fn test_prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n as i32).map(|i| (0..3 + (i % 4)).map(|j| (13 * i + 7 * j + 1) % 384).collect()).collect()
}

/// Oracle token streams: cache-free greedy decode on a clean runtime.
fn oracle(cfg: &ModelConfig, store: &TensorStore, prompts: &[Vec<i32>], n_new: usize) -> Vec<Vec<i32>> {
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::all_dense(cfg);
    prompts
        .iter()
        .map(|p| {
            pipe.generate_greedy_uncached(store, &plan, &[p.clone()], n_new).unwrap().remove(0)
        })
        .collect()
}

/// The `crash` action round-trips through the fault grammar and raises
/// a downcastable [`InjectedCrash`] panic payload at the armed site.
#[test]
fn cluster_crash_fault_grammar_and_payload() {
    let plan = FaultPlan::parse("seed=5;decode=0.01:crash").unwrap();
    let shown = plan.to_string();
    assert!(shown.contains("crash"), "Display must name the crash action: {shown}");
    let reparsed = FaultPlan::parse(&shown).unwrap();
    assert_eq!(reparsed.to_string(), shown, "grammar must round-trip");

    mute_injected_crash_reports();
    let (cfg, store) = mini_store();
    let x = Tensor::from_f32(&[1, 1, cfg.d_model], vec![0.25; cfg.d_model]);
    let ln_f = store.get("ln_f").unwrap().clone();
    let emb = store.get("emb").unwrap().clone();
    let fb = FaultyBackend::new(
        Box::new(NativeBackend::new()),
        FaultPlan::parse("seed=5;head=1.0:crash").unwrap(),
    );
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        fb.head_logits(&cfg, &x, &ln_f, &emb)
    }));
    let payload = caught.expect_err("an armed crash rule must panic");
    let crash = payload
        .downcast_ref::<InjectedCrash>()
        .expect("crash payload must downcast to InjectedCrash");
    assert_eq!(crash.site, FaultSite::Head);
    assert_eq!(crash.seq, 1);
}

/// The chaos centerpiece: worker 0 panics on every prefill (the
/// injected `crash` action), worker 1 is clean. Every request must
/// still succeed — replayed onto healthy capacity — with a token
/// stream bit-identical to the cache-free oracle, while the supervisor
/// respawns worker 0 with backoff and finally retires it via the
/// circuit breaker.
#[test]
fn cluster_crash_replay_matches_cachefree_oracle() {
    let (cfg, store) = mini_store();
    let n_new = 4usize;
    let prompts = test_prompts(12);
    let mut c = cluster(&cfg, &store, 2);
    c.factory = crashy_worker_zero("prefill");
    c.breaker_crashes = 2;
    // Generous budget: a replay may land on worker 0's next (equally
    // doomed) incarnation before the breaker retires it.
    c.retry_budget = 10;
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for p in &prompts {
        let (req, rrx) = gen_request(p.clone(), n_new);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let stats = c.run(rx).unwrap();
    let want = oracle(&cfg, &store, &prompts, n_new);
    for ((p, rrx), want) in prompts.iter().zip(resp_rxs).zip(want) {
        let resp = rrx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.error, None, "request {p:?} must survive worker crashes");
        assert_eq!(
            resp.tokens, want,
            "replayed request {p:?} diverged from the cache-free oracle"
        );
    }
    assert_eq!(stats.gen_served, prompts.len());
    assert_eq!(stats.tokens_generated, prompts.len() * n_new);
    assert!(stats.worker_crashes >= 2, "worker 0 must crash at least twice: {stats:?}");
    assert!(stats.worker_restarts >= 1, "worker 0 must respawn after backoff: {stats:?}");
    assert!(stats.retried_requests >= 1, "crashed dispatches must be replayed: {stats:?}");
    assert_eq!(stats.retired_workers, 1, "the breaker must retire worker 0: {stats:?}");
}

/// Circuit breaker on the last worker: a crash-looping single worker is
/// respawned with backoff, retired after `breaker_crashes` crashes, and
/// the cluster answers everything left with typed errors — the
/// all-retired terminal path never hangs.
#[test]
fn cluster_breaker_retirement_drains_typed_instead_of_hanging() {
    let (cfg, store) = mini_store();
    let mut c = cluster(&cfg, &store, 1);
    c.factory = crashy_worker_zero("prefill");
    c.breaker_crashes = 2;
    c.retry_budget = 1;
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for p in test_prompts(3) {
        let (req, rrx) = gen_request(p, 3);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let stats = c.run(rx).unwrap();
    assert_eq!(stats.worker_crashes, 2, "breaker fires at exactly 2 crashes: {stats:?}");
    assert_eq!(stats.worker_restarts, 1, "one respawn between the two crashes: {stats:?}");
    assert_eq!(stats.retired_workers, 1, "the only worker must retire: {stats:?}");
    assert!(stats.retried_requests >= 1, "in-flight work must be replayed: {stats:?}");
    for (i, rrx) in resp_rxs.into_iter().enumerate() {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        match resp.error {
            Some(ServeError::AllWorkersRetired { retired }) => assert_eq!(retired, 1),
            Some(ServeError::RetriesExhausted { attempts }) => {
                assert!(attempts >= 2, "exhaustion implies at least one replay")
            }
            other => panic!("request {i} must fail typed on a dead cluster, got {other:?}"),
        }
        assert!(resp.tokens.is_empty());
    }
}

/// Requests arriving after every worker retired are shed at intake with
/// the typed terminal error (not queued onto capacity that will never
/// come back).
#[test]
fn cluster_all_retired_sheds_new_arrivals() {
    let (cfg, store) = mini_store();
    let mut c = cluster(&cfg, &store, 1);
    c.factory = crashy_worker_zero("prefill");
    c.breaker_crashes = 1; // first crash retires the only worker
    c.retry_budget = 0;
    let (tx, rx) = channel::<Request>();
    let (req, rrx) = gen_request(vec![1, 2, 3], 3);
    tx.send(req).unwrap();
    // A client that keeps submitting while the cluster dies: the late
    // requests must come back typed, never hang the intake loop.
    let late = std::thread::spawn(move || {
        let mut rxs = Vec::new();
        for i in 0..20 {
            let (req, rrx) = gen_request(vec![4 + i, 5, 6], 3);
            if tx.send(req).is_err() {
                break;
            }
            rxs.push(rrx);
            std::thread::sleep(Duration::from_millis(20));
        }
        rxs
    });
    let stats = c.run(rx).unwrap();
    assert_eq!(stats.retired_workers, 1);
    assert_eq!(stats.worker_restarts, 0, "breaker at 1 leaves no room for a respawn");
    let first = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(
        matches!(
            first.error,
            Some(ServeError::AllWorkersRetired { .. }) | Some(ServeError::RetriesExhausted { .. })
        ),
        "the crashed request must fail typed, got {:?}",
        first.error
    );
    let mut terminal = 0usize;
    for rrx in late.join().unwrap() {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_ne!(resp.error, None, "no request can succeed on a fully retired cluster");
        if matches!(resp.error, Some(ServeError::AllWorkersRetired { .. })) {
            terminal += 1;
        }
    }
    assert!(terminal >= 1, "at least one late arrival must see the terminal error");
}

/// A hung worker (every decode stalls far past the heartbeat deadline)
/// is detected by liveness, abandoned, and its in-flight request is
/// replayed on the healthy worker — the response still matches the
/// oracle bit-for-bit.
#[test]
fn cluster_hung_worker_detected_and_work_replayed() {
    let (cfg, store) = mini_store();
    let n_new = 2usize;
    let prompts = test_prompts(4);
    let mut c = cluster(&cfg, &store, 2);
    c.heartbeat = Duration::from_millis(50);
    c.breaker_crashes = 2;
    c.retry_budget = 6;
    // Worker 0 sleeps 250 ms on every decode call — 5× the heartbeat
    // deadline; worker 1 is clean.
    c.factory = Arc::new(|w| {
        if w == 0 {
            Ok(Runtime::native().with_faults(FaultPlan::parse("seed=1;decode=1.0:delay250")?))
        } else {
            Ok(Runtime::native())
        }
    });
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for p in &prompts {
        let (req, rrx) = gen_request(p.clone(), n_new);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let stats = c.run(rx).unwrap();
    assert!(
        stats.worker_crashes >= 1,
        "the stalled worker must miss its heartbeat: {stats:?}"
    );
    assert!(stats.retried_requests >= 1, "the hung worker's request must replay: {stats:?}");
    let want = oracle(&cfg, &store, &prompts, n_new);
    for ((p, rrx), want) in prompts.iter().zip(resp_rxs).zip(want) {
        let resp = rrx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.error, None, "request {p:?} must survive the hang");
        assert_eq!(resp.tokens, want, "replayed request {p:?} diverged from the oracle");
    }
}

/// Graceful cluster drain: [`Request::Shutdown`] finishes accepted
/// work, sheds later arrivals typed, reports merged stats on the
/// shutdown channel, and the merge carries the workers' machine-level
/// counters (prefills, decode steps) alongside the router's
/// request-level ones.
#[test]
fn cluster_graceful_drain_merges_worker_stats() {
    let (cfg, store) = mini_store();
    let n_new = 3usize;
    let prompts = test_prompts(4);
    let c = cluster(&cfg, &store, 2);
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for p in &prompts {
        let (req, rrx) = gen_request(p.clone(), n_new);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    let (stx, srx) = channel::<ServeStats>();
    tx.send(Request::Shutdown(stx)).unwrap();
    let (late_req, late_rx) = gen_request(vec![9, 8, 7], n_new);
    tx.send(late_req).unwrap();
    // tx stays alive: the exit below is the drain, not a disconnect.
    let stats = c.run(rx).unwrap();
    drop(tx);
    assert_eq!(stats.gen_served, prompts.len());
    assert_eq!(stats.tokens_generated, prompts.len() * n_new);
    assert_eq!(stats.rejected, 1, "the post-shutdown arrival is shed");
    assert_eq!(stats.worker_crashes, 0);
    assert_eq!(stats.worker_restarts, 0);
    assert_eq!(stats.retired_workers, 0);
    // Machine-level counters exist only inside the workers — their
    // presence proves the clean-exit stats merged into the total.
    assert_eq!(stats.prefills, prompts.len(), "one prefill per request, merged from workers");
    assert!(stats.decode_steps > 0, "decode steps merge from worker stats");
    for rrx in resp_rxs {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.error, None);
        assert_eq!(resp.tokens.len(), n_new);
    }
    let late = late_rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(late.error, Some(ServeError::ShuttingDown));
    let reported = srx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(reported.gen_served, stats.gen_served);
    assert_eq!(reported.tokens_generated, stats.tokens_generated);
    assert_eq!(reported.prefills, stats.prefills);
    assert_eq!(reported.rejected, stats.rejected);
}

/// Two clean workers split a batch of requests (least-outstanding
/// dispatch), every stream matches the oracle, and nothing crashes or
/// retries — the supervised path costs no correctness on the happy
/// path.
#[test]
fn cluster_clean_run_matches_oracle_with_no_supervision_events() {
    let (cfg, store) = mini_store();
    let n_new = 4usize;
    let prompts = test_prompts(6);
    let c = cluster(&cfg, &store, 2);
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for p in &prompts {
        let (req, rrx) = gen_request(p.clone(), n_new);
        tx.send(req).unwrap();
        resp_rxs.push(rrx);
    }
    drop(tx);
    let stats = c.run(rx).unwrap();
    let want = oracle(&cfg, &store, &prompts, n_new);
    for ((p, rrx), want) in prompts.iter().zip(resp_rxs).zip(want) {
        let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.error, None);
        assert_eq!(resp.tokens, want, "clean cluster run diverged from the oracle for {p:?}");
    }
    assert_eq!(stats.gen_served, prompts.len());
    assert_eq!(stats.worker_crashes, 0);
    assert_eq!(stats.worker_restarts, 0);
    assert_eq!(stats.retried_requests, 0);
    assert_eq!(stats.retired_workers, 0);
    assert_eq!(stats.prefills, prompts.len());
}

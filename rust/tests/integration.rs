//! Integration tests over the native backend.
//!
//! These exercise the real coordinator paths (embed → layers → head,
//! compression, healing, evaluation) end-to-end on the pure-Rust CPU
//! backend — no artifacts, no skips. With `--features pjrt` plus a real
//! `xla` checkout and `make artifacts`, the same paths run on the PJRT
//! backend via `CURING_BACKEND=pjrt`.

use curing::backend::Backend;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{Ctx, EvalSizes};
use curing::model::ModelConfig;
use curing::pipeline::{LayerKind, LayerPlan, Pipeline};
use curing::runtime::Runtime;
use curing::tensor::{Tensor, TensorStore};
use curing::util::Rng;

fn runtime() -> Runtime {
    Runtime::native()
}

fn mini_cfg(rt: &Runtime) -> ModelConfig {
    ModelConfig::from_manifest(rt.manifest(), "mini").expect("mini config")
}

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::from_f32(shape, rng.normal_vec(shape.iter().product(), std))
}

fn flat_calib(cfg: &ModelConfig) -> curing::calib::Calibration {
    curing::calib::Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    }
}

#[test]
fn embed_runs_and_gathers() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let mut rng = Rng::new(1, 0);
    let store = {
        let mut s = TensorStore::new();
        s.insert("emb", rand_t(&mut rng, &[cfg.vocab, cfg.d_model], 1.0));
        s
    };
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let tokens = Tensor::from_i32(
        &[cfg.batch, cfg.seq],
        (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect(),
    );
    let x = pipe.embed(&store, &tokens).unwrap();
    assert_eq!(x.shape, vec![cfg.batch, cfg.seq, cfg.d_model]);
    // Row 0 token id 0 -> embedding row 0.
    let e = store.get("emb").unwrap().f32s().unwrap();
    let xs = x.f32s().unwrap();
    for j in 0..cfg.d_model {
        assert_eq!(xs[j], e[j]);
    }
}

#[test]
fn dense_layer_and_cured_layer_run() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(2, 0);
    let mut store = cfg.init_dense(&mut rng);
    let x = rand_t(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 1.0);

    let y = pipe.layer_forward(&store, 0, &LayerKind::Dense, &x).unwrap();
    assert_eq!(y.shape, x.shape);
    assert!(y.f32s().unwrap().iter().all(|v| v.is_finite()));

    // Cure layer 1 and run the factored chain.
    let calib = flat_calib(&cfg);
    let opts = CompressOptions { r_max: 8, ..Default::default() };
    curing::compress::cure_layers(&mut store, &cfg, &calib, &[1], &opts).unwrap();
    let kind = LayerKind::Cured { rank: 8, combo: "all".into() };
    let y2 = pipe.layer_forward(&store, 1, &kind, &x).unwrap();
    assert_eq!(y2.shape, x.shape);
    assert!(y2.f32s().unwrap().iter().all(|v| v.is_finite()));
}

/// Compression fidelity through the real execution path: cure one layer
/// of a *synthetically low-rank* model at a rank >= the true rank, and
/// verify the cured pipeline output matches the dense pipeline output.
#[test]
fn cured_pipeline_exact_on_low_rank_weights() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(11, 0);
    let mut store = cfg.init_dense(&mut rng);
    // Make layer 2's q/k/gate rank-4 (well under the rank-rule's 8).
    for (proj, n) in [("q", cfg.d_model), ("k", cfg.d_model), ("gate", cfg.d_inter)] {
        let a = curing::linalg::Mat::random_normal(cfg.d_model, 4, &mut rng);
        let bmat = curing::linalg::Mat::random_normal(4, n, &mut rng);
        let mut w = a.matmul(&bmat);
        w.scale(0.05);
        store.insert(format!("L2.w_{proj}"), w.to_tensor());
    }
    let x = rand_t(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 1.0);
    let y_dense = pipe.layer_forward(&store, 2, &LayerKind::Dense, &x).unwrap();
    let calib = flat_calib(&cfg);
    let opts = CompressOptions { r_max: 8, ..Default::default() };
    curing::compress::cure_layers(&mut store, &cfg, &calib, &[2], &opts).unwrap();
    let kind = LayerKind::Cured { rank: 8, combo: "all".into() };
    let y_cur = pipe.layer_forward(&store, 2, &kind, &x).unwrap();
    let a = y_dense.f32s().unwrap();
    let b = y_cur.f32s().unwrap();
    let err: f64 =
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt();
    let norm: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(err < 1e-3 * norm, "rel err {}", err / norm);
}

/// The per-layer heal step must reduce the layer MSE on recoverable
/// ΔU-subspace damage.
#[test]
fn heal_step_reduces_layer_mse() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(12, 0);
    let mut dense = cfg.init_dense(&mut rng);
    // Random init (std 0.02) makes the block output nearly insensitive to
    // q/k/gate; scale layer 2's weights up to trained-model magnitudes so
    // the healing objective has signal.
    for w in ["w_q", "w_k", "w_v", "w_o", "w_gate", "w_up", "w_down"] {
        let t = dense.get_mut(&format!("L2.{w}")).unwrap();
        for x in t.f32s_mut().unwrap() {
            *x *= 8.0;
        }
    }
    let mut student = dense.clone();
    let calib = flat_calib(&cfg);
    // Rank-4 compression, then corrupt U0 so there is *recoverable*
    // damage in the ΔU subspace (the fresh U0 = C^+ W R^+ is already
    // Frobenius-optimal, so healing a just-cured random-init model has
    // almost nothing to recover — paper Thm 4.3).
    let opts = CompressOptions { r_max: 4, ..Default::default() };
    curing::compress::cure_layers(&mut student, &cfg, &calib, &[2], &opts).unwrap();
    for proj in ["q", "k", "gate"] {
        let du = student.get_mut(&format!("L2.du_{proj}")).unwrap();
        for x in du.f32s_mut().unwrap() {
            *x = rng.normal() * 0.5;
        }
    }
    let vocab = curing::data::Vocab::build();
    let mut corpus = curing::data::Corpus::new(curing::data::CorpusKind::SynthC4, 99);
    let mut opt = TensorStore::new();
    let hopts = curing::heal::HealOptions { steps: 30, base_lr: 1e-2, warmup: 3 };
    let hist = curing::heal::heal_layers(
        &pipe, &dense, &mut student, &mut opt, &vocab, &mut corpus, &hopts, 0,
    )
    .unwrap();
    let first: f64 = hist[..3].iter().map(|p| p.loss).sum::<f64>() / 3.0;
    let last: f64 = hist[hist.len() - 3..].iter().map(|p| p.loss).sum::<f64>() / 3.0;
    assert!(
        last < first * 0.9,
        "healing did not reduce MSE: first {first} last {last}"
    );
    // dU must have moved away from its corrupted start.
    let du = student.get("L2.du_q").unwrap();
    assert!(du.fro_norm() > 0.0);
}

/// Greedy generation produces exactly n_new in-vocabulary tokens and is
/// deterministic for a fixed store.
#[test]
fn generation_is_deterministic_and_in_vocab() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(21, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = LayerPlan::all_dense(&cfg);
    let prompt = vec![1i32, 5, 9, 12];
    let a = pipe.generate_greedy(&store, &plan, &[prompt.clone()], 6).unwrap();
    let b = pipe.generate_greedy(&store, &plan, &[prompt], 6).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), 6);
    assert!(a[0].iter().all(|&t| (t as usize) < cfg.vocab));
}

/// Streaming KV decode (per-slot prefill + fused batched ring decode)
/// must emit token-for-token identical ids to the cache-free replay
/// reference — on dense and cured pipelines, with ragged prompt
/// lengths, and across the window-rotation boundary, where the ring
/// buffer overwrites the oldest position instead of re-prefilling.
#[test]
fn kv_decode_matches_replay_reference() {
    let rt = runtime();
    assert!(rt.backend().supports_kv_decode(), "native backend must decode with a KV cache");
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(23, 0);
    let mut store = cfg.init_dense(&mut rng);
    let prompts = vec![vec![1i32, 5, 9], vec![2i32, 3, 4, 7, 8], vec![11i32, 2]];
    // Enough new tokens to fill the seq-32 window and rotate it for
    // every row (prompt + n_new > seq).
    let n_new = cfg.seq + 4;
    let plan = LayerPlan::all_dense(&cfg);
    let kv = pipe.generate_greedy(&store, &plan, &prompts, n_new).unwrap();
    let full = pipe.generate_greedy_uncached(&store, &plan, &prompts, n_new).unwrap();
    assert_eq!(kv, full, "dense KV decode diverged from the replay reference");
    assert_eq!(kv[0].len(), n_new);

    // Batch independence: each row of the fused multi-slot run must
    // equal its own single-prompt run.
    for (i, p) in prompts.iter().enumerate() {
        let solo = pipe.generate_greedy(&store, &plan, &[p.clone()], n_new).unwrap();
        assert_eq!(solo[0], kv[i], "row {i} changed under batching");
    }

    // Same check through a cured layer (the factored q/k/gate chain).
    let calib = flat_calib(&cfg);
    let opts = CompressOptions { r_max: 8, ..Default::default() };
    curing::compress::cure_layers(&mut store, &cfg, &calib, &[1], &opts).unwrap();
    let plan = LayerPlan::with_cured(&cfg, &[1], 8, "all");
    let kv = pipe.generate_greedy(&store, &plan, &prompts, n_new).unwrap();
    let full = pipe.generate_greedy_uncached(&store, &plan, &prompts, n_new).unwrap();
    assert_eq!(kv, full, "cured KV decode diverged from the replay reference");
}

#[test]
fn missing_weights_are_reported_by_name() {
    let rt = runtime();
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let store = TensorStore::new();
    let x = Tensor::zeros(&[1, 2, 32]);
    let err = pipe.layer_forward(&store, 0, &LayerKind::Dense, &x).unwrap_err();
    assert!(err.to_string().contains("L0."), "err: {err}");
    let tokens = Tensor::from_i32(&[1, 2], vec![0, 1]);
    let err = pipe.embed(&store, &tokens).unwrap_err();
    assert!(err.to_string().contains("emb"), "err: {err}");
}

#[test]
fn shape_and_range_violations_rejected() {
    let rt = runtime();
    let cfg = mini_cfg(&rt);
    let pipe = Pipeline::new(&rt, "mini").unwrap();
    let mut rng = Rng::new(22, 0);
    let store = cfg.init_dense(&mut rng);
    // Out-of-vocab token id.
    let bad = Tensor::from_i32(&[1, 2], vec![0, cfg.vocab as i32]);
    assert!(pipe.embed(&store, &bad).is_err());
    // Wrong input rank to a layer.
    let flat = Tensor::zeros(&[4, cfg.d_model]);
    assert!(pipe.layer_forward(&store, 0, &LayerKind::Dense, &flat).is_err());
}

/// The headline acceptance path: pretrain → calibrate → compress → eval →
/// heal → eval, entirely on the native backend (this used to require
/// `make artifacts`).
#[test]
fn e2e_compress_heal_eval_on_native_backend() {
    let root = std::env::temp_dir().join(format!("curing_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let ctx = Ctx::with_runtime(Runtime::native(), &root).unwrap();
    let pipe = ctx.pipeline("mini").unwrap();

    // 1. Pretrain a few steps (enough to move off random init).
    let mut last_loss = f64::NAN;
    let (dense, losses) =
        ctx.pretrain("mini", 6, 1e-3, 42, &mut |_, l| last_loss = l).unwrap();
    assert_eq!(losses.len(), 6);
    assert!(last_loss.is_finite());

    // 2. Calibrate on a handful of examples.
    let mut corpus =
        curing::data::Corpus::new(curing::data::CorpusKind::SynthC4, curing::data::SEED_CALIB);
    let calib =
        curing::calib::calibrate(&pipe, &dense, &ctx.vocab, &mut corpus, 8).unwrap();
    assert_eq!(calib.angular.len(), pipe.cfg.n_layers);
    assert!(calib.angular.iter().all(|a| a.is_finite()));
    assert!(calib.attn_norms[0].iter().any(|&x| x > 0.0));

    // 3. Compress two layers.
    let opts = CompressOptions { r_max: 4, ..Default::default() };
    let (mut student, plan, report) = ctx
        .compress_k(&pipe, &dense, &calib, 2, LayerStrategy::Angular, &opts)
        .unwrap();
    assert_eq!(report.layers.len(), 2);
    assert!(report.bytes_saved() > 0);
    assert!(student.total_params() < dense.total_params());

    // 4. Evaluate dense and cured.
    let sizes = EvalSizes { ppl_batches: 1, boolq_items: 4, mmlu_items: 4 };
    let dense_suite = ctx
        .eval_suite(&pipe, &dense, &LayerPlan::all_dense(&pipe.cfg), &sizes)
        .unwrap();
    let cured_suite = ctx.eval_suite(&pipe, &student, &plan, &sizes).unwrap();
    for s in [&dense_suite, &cured_suite] {
        assert!(s.c4_ppl.is_finite() && s.c4_ppl > 1.0, "{}", s.row());
        assert!(s.wiki_ppl.is_finite() && s.wiki_ppl > 1.0, "{}", s.row());
        assert!((0.0..=1.0).contains(&s.boolq_acc));
        assert!((0.0..=1.0).contains(&s.mmlu_acc));
    }

    // 5. Heal and re-evaluate.
    let mut hcorpus =
        curing::data::Corpus::new(curing::data::CorpusKind::SynthC4, curing::data::SEED_HEAL);
    let mut opt = TensorStore::new();
    let hopts = curing::heal::HealOptions { steps: 10, base_lr: 3e-3, warmup: 2 };
    let hist = curing::heal::heal_layers(
        &pipe, &dense, &mut student, &mut opt, &ctx.vocab, &mut hcorpus, &hopts, 0,
    )
    .unwrap();
    assert_eq!(hist.len(), 10);
    assert!(hist.iter().all(|p| p.loss.is_finite()));
    let healed_suite = ctx.eval_suite(&pipe, &student, &plan, &sizes).unwrap();
    assert!(healed_suite.c4_ppl.is_finite() && healed_suite.c4_ppl > 1.0);

    // 6. The cured store saves and reloads losslessly.
    let dir = root.join("stores").join("e2e_student");
    student.save(&dir).unwrap();
    let reloaded = TensorStore::load(&dir).unwrap();
    assert_eq!(reloaded.len(), student.len());
    assert_eq!(curing::compress::cured_layers_of(&reloaded), report.layers);
    let _ = std::fs::remove_dir_all(&root);
}

//! Integration tests over the AOT artifacts + PJRT runtime.
//!
//! These need `make artifacts` to have run (they are skipped with a clear
//! message otherwise) and exercise the real load→compile→execute path the
//! coordinator uses in production.

use curing::model::ModelConfig;
use curing::runtime::{Bindings, Runtime};
use curing::tensor::Tensor;
use curing::util::Rng;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::from_f32(shape, rng.normal_vec(shape.iter().product(), std))
}

#[test]
fn embed_fwd_runs_and_gathers() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::from_manifest(&rt.manifest, "tiny").unwrap();
    let mut rng = Rng::new(1, 0);
    let emb = rand_t(&mut rng, &[cfg.vocab, cfg.d_model], 1.0);
    let tokens = Tensor::from_i32(
        &[cfg.batch, cfg.seq],
        (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect(),
    );
    let out = rt
        .execute("tiny_embed_fwd", &Bindings::new().bind("tokens", &tokens).bind("emb", &emb))
        .unwrap();
    let x = &out["x"];
    assert_eq!(x.shape, vec![cfg.batch, cfg.seq, cfg.d_model]);
    // Row 0 token id 0 -> embedding row 0.
    let e = emb.f32s().unwrap();
    let xs = x.f32s().unwrap();
    for j in 0..cfg.d_model {
        assert_eq!(xs[j], e[j]);
    }
}

#[test]
fn dense_layer_and_cured_layer_run() {
    let Some(rt) = runtime() else { return };
    let cfg = ModelConfig::from_manifest(&rt.manifest, "tiny").unwrap();
    let mut rng = Rng::new(2, 0);
    let d = cfg.d_model;
    let x = rand_t(&mut rng, &[cfg.batch, cfg.seq, d], 1.0);

    // Dense layer.
    let mut b = Bindings::new().bind("x", &x);
    let store = cfg.init_dense(&mut rng);
    for name in cfg.dense_layer_param_names(0) {
        let stripped = name.strip_prefix("L0.").unwrap().to_string();
        b.bind_owned(format!("L.{stripped}"), store.get(&name).unwrap().clone());
    }
    let out = rt.execute("tiny_layer_fwd_dense", &b).unwrap();
    let y = &out["y"];
    assert_eq!(y.shape, vec![cfg.batch, cfg.seq, d]);
    assert!(y.f32s().unwrap().iter().all(|v| v.is_finite()));

    // Cured layer (rank 16, combo all) with random factors.
    let r = 16usize;
    let mut b2 = Bindings::new().bind("x", &x);
    b2.bind_owned("L.ln1", Tensor::from_f32(&[d], vec![1.0; d]));
    b2.bind_owned("L.ln2", Tensor::from_f32(&[d], vec![1.0; d]));
    for w in ["q", "k"] {
        b2.bind_owned(format!("L.c_{w}"), rand_t(&mut rng, &[d, r], 0.05));
        b2.bind_owned(format!("L.u_{w}"), rand_t(&mut rng, &[r, r], 0.05));
        b2.bind_owned(format!("L.r_{w}"), rand_t(&mut rng, &[r, d], 0.05));
    }
    b2.bind_owned("L.c_gate", rand_t(&mut rng, &[d, r], 0.05));
    b2.bind_owned("L.u_gate", rand_t(&mut rng, &[r, r], 0.05));
    b2.bind_owned("L.r_gate", rand_t(&mut rng, &[r, cfg.d_inter], 0.05));
    for w in ["w_v", "w_o"] {
        b2.bind_owned(format!("L.{w}"), rand_t(&mut rng, &[d, d], 0.02));
    }
    b2.bind_owned("L.w_up", rand_t(&mut rng, &[d, cfg.d_inter], 0.02));
    b2.bind_owned("L.w_down", rand_t(&mut rng, &[cfg.d_inter, d], 0.02));
    let out2 = rt.execute("tiny_layer_fwd_cured_r16_call", &b2).unwrap();
    let y2 = &out2["y"];
    assert_eq!(y2.shape, vec![cfg.batch, cfg.seq, d]);
    assert!(y2.f32s().unwrap().iter().all(|v| v.is_finite()));
}

/// Cross-check: the per-layer pipeline and the monolithic switched
/// artifact must produce the same NLL for the same dense model. This
/// validates the whole coordinator composition path end-to-end.
#[test]
fn pipeline_matches_switched_monolith() {
    let Some(rt) = runtime() else { return };
    let pipe = curing::pipeline::Pipeline::new(&rt, "tiny").unwrap();
    let cfg = &pipe.cfg;
    let mut rng = Rng::new(10, 0);
    let store = cfg.init_dense(&mut rng);
    let toks: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let tgts: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
    let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
    let targets = Tensor::from_i32(&[cfg.batch, cfg.seq], tgts);

    // Pipeline path (per-layer artifacts).
    let plan = curing::pipeline::LayerPlan::all_dense(cfg);
    let nll_pipe = pipe.nll(&store, &plan, &tokens, &targets).unwrap();

    // Monolith path (switched artifact, all switches = 0 -> dense).
    let spec = rt.spec("tiny_model_nll_switched").unwrap();
    let switches = Tensor::zeros(&[cfg.n_layers]);
    let mut b = Bindings::new()
        .bind("tokens", &tokens)
        .bind("targets", &targets)
        .bind("switches", &switches);
    for io in &spec.inputs {
        if b.get(&io.name).is_some() {
            continue;
        }
        if store.contains(&io.name) {
            b.bind_owned(io.name.clone(), store.get(&io.name).unwrap().clone());
        } else {
            b.bind_owned(io.name.clone(), Tensor::zeros(&io.shape));
        }
    }
    let out = rt.execute("tiny_model_nll_switched", &b).unwrap();
    let nll_mono = &out["nll"];

    let a = nll_pipe.f32s().unwrap();
    let c = nll_mono.f32s().unwrap();
    for (x, y) in a.iter().zip(c) {
        assert!(
            (x - y).abs() < 2e-3 * (1.0 + x.abs()),
            "pipeline {x} vs monolith {y}"
        );
    }
}

/// Compression fidelity through the real artifacts: cure one layer of a
/// *synthetically low-rank* model at a rank >= the true rank, and verify
/// the cured pipeline output matches the dense pipeline output.
#[test]
fn cured_pipeline_exact_on_low_rank_weights() {
    let Some(rt) = runtime() else { return };
    let pipe = curing::pipeline::Pipeline::new(&rt, "tiny").unwrap();
    let cfg = &pipe.cfg;
    let mut rng = Rng::new(11, 0);
    let mut store = cfg.init_dense(&mut rng);
    // Make layer 3's q/k/gate rank-8 (well under r_max=32).
    for (proj, n) in [("q", cfg.d_model), ("k", cfg.d_model), ("gate", cfg.d_inter)] {
        let a = curing::linalg::Mat::random_normal(cfg.d_model, 8, &mut rng);
        let bmat = curing::linalg::Mat::random_normal(8, n, &mut rng);
        let mut w = a.matmul(&bmat);
        w.scale(0.02);
        store.insert(format!("L3.w_{proj}"), w.to_tensor());
    }
    let x = rand_t(&mut rng, &[cfg.batch, cfg.seq, cfg.d_model], 1.0);
    let y_dense = pipe
        .layer_forward(&store, 3, &curing::pipeline::LayerKind::Dense, &x)
        .unwrap();
    // Cure layer 3 at r_max=32 (rank rule gives 32 here).
    let calib = curing::calib::Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    };
    let opts = curing::compress::CompressOptions { r_max: 32, ..Default::default() };
    curing::compress::cure_layers(&mut store, cfg, &calib, &[3], &opts).unwrap();
    let kind = curing::pipeline::LayerKind::Cured { rank: 32, combo: "all".into() };
    let y_cur = pipe.layer_forward(&store, 3, &kind, &x).unwrap();
    let a = y_dense.f32s().unwrap();
    let b = y_cur.f32s().unwrap();
    let err: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(err < 1e-3 * norm, "rel err {}", err / norm);
}

/// The per-layer heal step must reduce the layer MSE on a fixed batch.
#[test]
fn heal_step_reduces_layer_mse() {
    let Some(rt) = runtime() else { return };
    let pipe = curing::pipeline::Pipeline::new(&rt, "tiny").unwrap();
    let cfg = &pipe.cfg;
    let mut rng = Rng::new(12, 0);
    let mut dense = cfg.init_dense(&mut rng);
    // Random init (std 0.02) makes the block output nearly insensitive to
    // q/k/gate; scale layer 2's weights up to trained-model magnitudes so
    // the healing objective has signal.
    for w in ["w_q", "w_k", "w_v", "w_o", "w_gate", "w_up", "w_down"] {
        let t = dense.get_mut(&format!("L2.{w}")).unwrap();
        for x in t.f32s_mut().unwrap() {
            *x *= 8.0;
        }
    }
    let mut student = dense.clone();
    let calib = curing::calib::Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    };
    // Rank-8 compression, then corrupt U0 so there is *recoverable*
    // damage in the ΔU subspace (the fresh U0 = C^+ W R^+ is already
    // Frobenius-optimal, so healing a just-cured random-init model has
    // almost nothing to recover — paper Thm 4.3).
    let opts = curing::compress::CompressOptions { r_max: 8, ..Default::default() };
    curing::compress::cure_layers(&mut student, cfg, &calib, &[2], &opts).unwrap();
    for proj in ["q", "k", "gate"] {
        let du = student.get_mut(&format!("L2.du_{proj}")).unwrap();
        for x in du.f32s_mut().unwrap() {
            *x = rng.normal() * 0.5;
        }
    }
    let vocab = curing::data::Vocab::build();
    let mut corpus = curing::data::Corpus::new(curing::data::CorpusKind::SynthC4, 99);
    let mut opt = curing::tensor::TensorStore::new();
    let hopts = curing::heal::HealOptions { steps: 30, base_lr: 1e-2, warmup: 3 };
    let hist = curing::heal::heal_layers(
        &pipe, &dense, &mut student, &mut opt, &vocab, &mut corpus, &hopts, 0,
    )
    .unwrap();
    let first: f64 = hist[..3].iter().map(|p| p.loss).sum::<f64>() / 3.0;
    let last: f64 = hist[hist.len() - 3..].iter().map(|p| p.loss).sum::<f64>() / 3.0;
    assert!(
        last < first * 0.9,
        "healing did not reduce MSE: first {first} last {last}"
    );
    // dU must have moved away from zero.
    let du = student.get("L2.du_q").unwrap();
    assert!(du.fro_norm() > 0.0);
}

/// Greedy generation produces exactly n_new in-vocabulary tokens and is
/// deterministic for a fixed store.
#[test]
fn generation_is_deterministic_and_in_vocab() {
    let Some(rt) = runtime() else { return };
    let pipe = curing::pipeline::Pipeline::new(&rt, "tiny").unwrap();
    let cfg = &pipe.cfg;
    let mut rng = Rng::new(21, 0);
    let store = cfg.init_dense(&mut rng);
    let plan = curing::pipeline::LayerPlan::all_dense(cfg);
    let prompt = vec![1i32, 5, 9, 12];
    let a = pipe.generate_greedy(&store, &plan, &[prompt.clone()], 6).unwrap();
    let b = pipe.generate_greedy(&store, &plan, &[prompt], 6).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].len(), 6);
    assert!(a[0].iter().all(|&t| (t as usize) < cfg.vocab));
}

#[test]
fn missing_input_is_reported_by_name() {
    let Some(rt) = runtime() else { return };
    let err = rt.execute("tiny_embed_fwd", &Bindings::new()).unwrap_err();
    assert!(err.to_string().contains("tokens"), "err: {err}");
}

#[test]
fn shape_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let bad = Tensor::from_i32(&[1, 2], vec![0, 1]);
    let emb = Tensor::zeros(&[512, 256]);
    let err = rt
        .execute("tiny_embed_fwd", &Bindings::new().bind("tokens", &bad).bind("emb", &emb))
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "err: {err}");
}

//! CUR-compressed KV cache: compaction semantics, protection
//! invariants and quality bounds of `KvPolicy::Cur` against the exact
//! sliding-window ring (native backend).

use curing::backend::{Backend, KvCache, KvPolicy};
use curing::model::ModelConfig;
use curing::pipeline::{LayerPlan, Pipeline};
use curing::runtime::Runtime;
use curing::tensor::TensorStore;
use curing::util::Rng;

fn setup(config: &str, seed: u64) -> (Runtime, ModelConfig, TensorStore) {
    let rt = Runtime::native();
    let cfg = ModelConfig::from_manifest(rt.manifest(), config).expect("config");
    let mut rng = Rng::new(seed, 0);
    let store = cfg.init_dense(&mut rng);
    (rt, cfg, store)
}

#[test]
fn kv_policy_parse_roundtrip() {
    assert_eq!(KvPolicy::parse("exact").unwrap(), KvPolicy::Exact);
    assert_eq!(
        KvPolicy::parse("cur:0.5").unwrap(),
        KvPolicy::Cur {
            keep: 0.5,
            sinks: KvPolicy::DEFAULT_SINKS,
            recent: KvPolicy::DEFAULT_RECENT
        }
    );
    assert_eq!(
        KvPolicy::parse("cur:0.25:2:6").unwrap(),
        KvPolicy::Cur { keep: 0.25, sinks: 2, recent: 6 }
    );
    for bad in ["", "cur", "cur:", "cur:0", "cur:1.5", "cur:0.5:2", "cur:0.5:a:b", "lru"] {
        assert!(KvPolicy::parse(bad).is_err(), "'{bad}' must not parse");
    }
    // Display round-trips through parse.
    let p = KvPolicy::parse("cur:0.5:2:6").unwrap();
    assert_eq!(KvPolicy::parse(&p.to_string()).unwrap(), p);
}

/// keep = 1.0 compacts by dropping exactly the oldest position — the
/// same eviction the exact ring performs by overwrite — so the whole
/// token stream must be bit-identical to the exact cache, across many
/// rotations and for ragged prompt lengths. This pins that the
/// compacted-lane machinery (append writes, position maps, compaction
/// copies, flat ascending attention) introduces zero numeric drift:
/// any keep < 1 divergence comes from eviction *choices* alone.
#[test]
fn keep_one_is_bit_identical_to_exact_ring() {
    let (rt, cfg, store) = setup("mini", 31);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::all_dense(&cfg);
    let prompts: Vec<Vec<i32>> = vec![
        vec![1, 5, 9],
        vec![2, 3, 4, 7, 8, 11, 13],
        vec![9, 8],
    ];
    let n_new = 2 * cfg.seq + 3; // dozens of evictions past the window
    let exact = pipe.generate_greedy(&store, &plan, &prompts, n_new).unwrap();
    let cur = pipe
        .generate_greedy_with_policy(
            &store,
            &plan,
            &prompts,
            n_new,
            KvPolicy::Cur { keep: 1.0, sinks: 4, recent: 8 },
        )
        .unwrap();
    assert_eq!(cur, exact, "keep=1.0 must be bit-identical to the exact ring");
}

/// Attention sinks (absolute position < sinks) and the newest `recent`
/// rows must survive every compaction, in every layer, while the lane
/// itself stays within capacity and keeps its maps consistent.
#[test]
fn sinks_and_recent_positions_survive_compaction() {
    let (rt, cfg, store) = setup("mini", 32);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::all_dense(&cfg);
    let (sinks, recent) = (3usize, 5usize);
    let policy = KvPolicy::Cur { keep: 0.4, sinks, recent };
    let mut kv = KvCache::with_policy(cfg.n_layers, 1, cfg.seq, cfg.d_model, policy);
    let packed = pipe.pack_head(&store).unwrap();
    let prompt: Vec<i32> = (1..=8).collect();
    let mut last =
        vec![pipe.prefill_slot(&store, &plan, &mut kv, 0, &prompt, packed.as_ref()).unwrap()];
    let n_steps = 3 * cfg.seq; // several compaction cycles
    for _ in 0..n_steps {
        last = pipe.decode_step(&store, &plan, &mut kv, &[0], &last, packed.as_ref()).unwrap();
        let pos_now = kv.next_pos[0];
        let fill = kv.fill[0];
        assert!(fill <= kv.cap, "lane overflowed");
        for l in 0..cfg.n_layers {
            let map = &kv.positions[l][0];
            assert_eq!(map.len(), fill, "layer {l} map out of sync");
            assert!(map.windows(2).all(|w| w[0] < w[1]), "layer {l} map not ascending");
            // Sinks: every stream position < sinks that ever entered
            // the cache is still there.
            for p in 0..sinks.min(pos_now) {
                assert!(map.contains(&p), "layer {l} evicted sink position {p}");
            }
            // Recent: the newest `recent` positions are all present.
            for p in pos_now.saturating_sub(recent)..pos_now {
                assert!(map.contains(&p), "layer {l} evicted recent position {p}");
            }
        }
    }
    assert!(kv.compactions >= 2, "expected repeated compactions, got {}", kv.compactions);
    // The compacted lane stays at or below the keep budget right after
    // a compaction: force one more and check the floor directly.
    while !kv.needs_compaction(0) {
        last = pipe.decode_step(&store, &plan, &mut kv, &[0], &last, packed.as_ref()).unwrap();
    }
    let before = kv.compactions;
    rt.backend().compress_kv_slot(&cfg, &mut kv, 0).unwrap();
    assert_eq!(kv.compactions, before + 1);
    let budget = (0.4 * cfg.seq as f64).round() as usize;
    assert!(
        kv.fill[0] <= budget.max(sinks + recent),
        "post-compaction fill {} above the keep budget {budget}",
        kv.fill[0]
    );
}

/// Quality harness at keep = 0.5 on the tiny config: the compressed
/// cache's greedy stream must agree with the exact cache on at least
/// the whole pre-compaction prefix (and typically far more), and the
/// teacher-forced decode perplexity must stay within a bounded delta —
/// dropping half the window may perturb, not destroy, the model.
#[test]
fn keep_half_divergence_and_ppl_bounded_on_tiny() {
    let (rt, cfg, store) = setup("tiny", 33);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::all_dense(&cfg);
    let policy = KvPolicy::Cur { keep: 0.5, sinks: 4, recent: 8 };
    let prompts: Vec<Vec<i32>> = vec![(1..=8).collect(), (20..=30).collect()];
    let n_new = cfg.seq + cfg.seq / 2; // 96 on tiny: past several compactions
    let exact = pipe.generate_greedy(&store, &plan, &prompts, n_new).unwrap();
    let cur = pipe
        .generate_greedy_with_policy(&store, &plan, &prompts, n_new, policy)
        .unwrap();
    // The first compaction cannot fire before the lane fills, so the
    // leading window-minus-prompt tokens are identical by construction;
    // overall agreement must clear half the stream.
    let total = (prompts.len() * n_new) as f64;
    let matches: usize = exact
        .iter()
        .zip(&cur)
        .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
        .sum();
    let agreement = matches as f64 / total;
    assert!(agreement >= 0.5, "greedy agreement {agreement:.3} below 0.5");
    for (a, b) in exact.iter().zip(&cur) {
        let prefix = cfg.seq - 16; // conservative pre-compaction span
        assert_eq!(&a[..prefix], &b[..prefix], "diverged before any compaction");
    }
    // Perplexity delta: teacher-forced decode NLL over sequences twice
    // the window, exact vs compressed cache.
    let mut rng = Rng::new(99, 0);
    let seqs: Vec<Vec<i32>> = (0..2)
        .map(|_| (0..2 * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect();
    let ppl_exact =
        curing::eval::decode_perplexity(&pipe, &store, &plan, KvPolicy::Exact, &seqs).unwrap();
    let ppl_cur =
        curing::eval::decode_perplexity(&pipe, &store, &plan, policy, &seqs).unwrap();
    assert!(ppl_exact.is_finite() && ppl_cur.is_finite());
    let delta_nll = (ppl_cur.ln() - ppl_exact.ln()).abs();
    assert!(
        delta_nll < 0.5,
        "decode-ppl delta too large: exact {ppl_exact:.2} vs cur {ppl_cur:.2} \
         ({delta_nll:.3} nats)"
    );
}

/// A compressed cache must reject decode on a full lane (the caller —
/// `Pipeline::decode_step` — is responsible for compacting first), and
/// slot recycling must clear the compaction state.
#[test]
fn full_lane_errors_and_reset_clears_state() {
    let (rt, cfg, store) = setup("mini", 34);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::all_dense(&cfg);
    let policy = KvPolicy::Cur { keep: 0.5, sinks: 2, recent: 4 };
    let mut kv = KvCache::with_policy(cfg.n_layers, 1, cfg.seq, cfg.d_model, policy);
    let packed = pipe.pack_head(&store).unwrap();
    let prompt: Vec<i32> = (1..=4).collect();
    let mut last =
        vec![pipe.prefill_slot(&store, &plan, &mut kv, 0, &prompt, packed.as_ref()).unwrap()];
    while !kv.needs_compaction(0) {
        last = pipe.decode_step(&store, &plan, &mut kv, &[0], &last, packed.as_ref()).unwrap();
    }
    // Bypassing the pipeline's compaction trigger must fail loudly.
    let params = pipe.layer_params(&store, 0, &plan.0[0]).unwrap();
    let x = curing::tensor::Tensor::from_f32(&[1, 1, cfg.d_model], vec![0.0; cfg.d_model]);
    let err = rt.backend().layer_decode_batch(&cfg, &params, &x, &mut kv, 0, &[0]);
    assert!(err.is_err(), "decode on a full lane must error");
    // decode_step compacts transparently and keeps going.
    last = pipe.decode_step(&store, &plan, &mut kv, &[0], &last, packed.as_ref()).unwrap();
    assert_eq!(last.len(), 1);
    assert!(kv.compactions >= 1);
    // Recycling the slot clears fill and the per-layer maps.
    kv.reset_slot(0);
    assert_eq!(kv.fill[0], 0);
    assert!(kv.positions.iter().all(|l| l[0].is_empty()));
    let t = pipe.prefill_slot(&store, &plan, &mut kv, 0, &prompt, packed.as_ref()).unwrap();
    assert!((0..cfg.vocab as i32).contains(&t));
}

#[test]
fn unsupported_capability_downcasts_to_typed_payload() {
    use curing::backend::native::NativeBackend;
    use curing::backend::Unsupported;
    let be = NativeBackend::new();
    let err = be.artifact_spec("step").unwrap_err();
    let u = err
        .downcast_ref::<Unsupported>()
        .expect("capability refusals carry a typed Unsupported payload");
    assert_eq!(u.backend, "native");
    assert!(u.op.contains("artifact"), "op names the capability: {}", u.op);
    // The rendered message keeps the old human-readable shape.
    assert!(err.to_string().starts_with("backend 'native' "), "{err}");
}

#[test]
fn kv_policy_parse_errors_downcast_to_spec_error() {
    use curing::backend::SpecError;
    for bad in ["lru", "cur:nope", "cur:0.5:x:4"] {
        let err = KvPolicy::parse(bad).unwrap_err();
        assert!(
            err.downcast_ref::<SpecError>().is_some(),
            "'{bad}' should be a typed usage error, got: {err}"
        );
    }
}

//! Determinism suite over the perf-barometer workloads: every named
//! workload model runs twice in-process at quick sizes, and the
//! non-timing fingerprints (parameter point, deterministic measurements
//! such as token-stream hashes / byte footprints / losses, and every
//! series) must match bit-for-bit. Timing rows and measurements marked
//! [`volatile`](curing::util::record::Measurement::volatile) are
//! excluded by construction.

#[path = "../benches/harness/mod.rs"]
#[allow(dead_code)]
mod harness;

use curing::coordinator::Ctx;
use curing::runtime::Runtime;
use harness::{workload_specs, BenchCtx};

#[test]
fn every_workload_fingerprint_is_stable_across_in_process_runs() -> anyhow::Result<()> {
    let root = std::env::temp_dir().join(format!("curing_bench_det_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let ctx = Ctx::with_runtime(Runtime::native(), &root)?;
    // Smoke-size setup mirroring CI's bench lane: a short cached
    // pretrain and a small calibration set — the fingerprints only have
    // to be *stable*, not representative.
    let dense = ctx.load_or_pretrain("tiny", 5)?;
    let pipe = ctx.pipeline("tiny")?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 16)?;
    let b = BenchCtx::new(&ctx, true, dense, calib)?;

    for spec in workload_specs() {
        let first = (spec.run)(&b)?;
        let second = (spec.run)(&b)?;
        let (fa, fb) = (first.fingerprint(), second.fingerprint());
        assert!(
            !fa.is_empty(),
            "workload {} recorded an empty fingerprint",
            spec.name
        );
        assert_eq!(
            fa, fb,
            "workload {} is not deterministic across in-process runs",
            spec.name
        );
    }
    let _ = std::fs::remove_dir_all(&root);
    Ok(())
}

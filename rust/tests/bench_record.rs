//! The recorded-run format (`util::record`, schema v2): round-trip,
//! schema/units validation, lossless v1 migration, and the
//! preserve-unknown-sections contract of [`RecordedRun::merge_into`]
//! that the old flat `merge_bench_json` writer kept for partial runs.

use curing::util::record::{Measurement, RecordedRun, Unit, WorkloadRecord};
use curing::util::Json;
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("curing_bench_record_{tag}_{}.json", std::process::id()))
}

fn sample_run() -> RecordedRun {
    let mut run = RecordedRun::new("native", true);
    run.commit = Some("deadbeef".to_string());

    let mut kv = WorkloadRecord::new("kv_cur");
    kv.param_str("config", "mini");
    kv.param_json("grid_keep", Json::Arr(vec![Json::Num(1.0), Json::Num(0.5)]));
    kv.put("exact_slot_bytes", Measurement::point(4096.0, Unit::Bytes));
    kv.put(
        "tokens_per_s[keep=0.5,slots=2]",
        Measurement::from_samples(vec![101.0, 99.0, 100.0], Unit::TokensPerS),
    );
    kv.put("live_bytes[keep=0.5,slots=2]", Measurement::point(2048.0, Unit::Bytes).volatile());
    kv.put("compactions[keep=0.5,slots=2]", Measurement::point(7.0, Unit::Count));
    run.put_workload(kv);

    let mut heal = WorkloadRecord::new("peft_heal");
    heal.param_num("du_steps", 20.0);
    heal.put("final_loss_du", Measurement::point(1.25, Unit::Nats));
    heal.put_series("du_loss", vec![3.0, 2.5, 2.0, 1.5, 1.25]);
    run.put_workload(heal);

    run.extra.push(("notes".to_string(), Json::Str("hand-kept".to_string())));
    run
}

// --------------------------------------------------------------- round-trip

#[test]
fn round_trips_through_json_without_loss() {
    let run = sample_run();
    let back = RecordedRun::from_json(&run.to_json()).expect("reparse");
    assert_eq!(run, back);
}

#[test]
fn round_trips_through_disk_via_merge() {
    let path = tmp_path("disk");
    let _ = std::fs::remove_file(&path);
    let run = sample_run();
    run.merge_into(&path).expect("write");
    let back = RecordedRun::load(&path).expect("load");
    assert_eq!(run, back);
    let _ = std::fs::remove_file(&path);
}

// --------------------------------------------------- schema / unit validation

#[test]
fn every_unit_survives_its_own_round_trip() {
    for unit in Unit::ALL {
        assert_eq!(Unit::parse(unit.as_str()), Some(unit), "{}", unit.as_str());
    }
    assert_eq!(Unit::parse("furlongs"), None);
}

#[test]
fn rejects_unknown_units_on_load() {
    let j = Json::parse(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1, "unit": "furlongs"}}}}}"#,
    )
    .expect("json");
    let err = RecordedRun::from_json(&j).unwrap_err().to_string();
    assert!(err.contains("unknown unit"), "{err}");
}

#[test]
fn rejects_non_finite_values_on_load() {
    // JSON cannot spell inf, but 1e999 overflows the f64 parse to it.
    let j = Json::parse(
        r#"{"schema": 2, "workloads": {"w": {"measurements":
            {"x": {"value": 1e999, "unit": "s"}}}}}"#,
    )
    .expect("json");
    let err = RecordedRun::from_json(&j).unwrap_err().to_string();
    assert!(err.contains("non-finite"), "{err}");
}

#[test]
fn deterministic_defaults_follow_the_unit() {
    assert!(!Measurement::point(1.0, Unit::MsPerIter).deterministic);
    assert!(!Measurement::from_samples(vec![1.0, 2.0], Unit::TokensPerS).deterministic);
    assert!(Measurement::point(1.0, Unit::Bytes).deterministic);
    assert!(Measurement::point(1.0, Unit::Nats).deterministic);
    assert!(!Measurement::point(1.0, Unit::Count).volatile().deterministic);
}

#[test]
fn fingerprint_excludes_timing_and_volatile_rows() {
    let run = sample_run();
    let fp = run.workload("kv_cur").expect("kv_cur").fingerprint();
    assert!(fp.contains("exact_slot_bytes"), "{fp}");
    assert!(fp.contains("compactions[keep=0.5,slots=2]"), "{fp}");
    // Timing row and volatile live-bytes must not pin the fingerprint.
    assert!(!fp.contains("tokens_per_s"), "{fp}");
    assert!(!fp.contains("live_bytes"), "{fp}");
    // Series do pin it.
    let hp = run.workload("peft_heal").expect("peft_heal").fingerprint();
    assert!(hp.contains("series du_loss"), "{hp}");
}

// ------------------------------------------------------------- v1 migration

/// A v1 file in the shape earlier PRs appended to `BENCH_native.json`:
/// flat sections, no units, `fast` flag, plus a section the migration
/// has never heard of.
const V1_TEXT: &str = r#"{
  "schema": 2,
  "backend": "native",
  "config": "tiny d_model=256",
  "fast": true,
  "rows": [
    {"name": "matmul_nn tiled", "iters": 9, "mean_ms": 1.5, "p50_ms": 1.4,
     "p95_ms": 1.9, "min_ms": 1.2}
  ],
  "decode": {"speedup": 3.5, "per_token_kv_ms": 0.8},
  "serve": {"tokens_per_s_slots4": 850.0, "slot_failures_faulted": 3,
            "scored": 16},
  "kv_cur": {"exact_slot_bytes": 4096, "live_bytes_keep50": 2000.5,
             "ppl_exact": 12.5, "token_agreement_keep50": 0.97},
  "peft_heal": {"final_loss_du": 1.25, "steps_per_s_du": 40.0,
                "du_loss_series": [3.0, 2.0, 1.5, 1.25]},
  "custom_section": {"anything": [1, 2, 3]}
}"#;

#[test]
fn migrates_v1_losslessly() {
    let j = Json::parse(V1_TEXT).expect("json");
    let run = RecordedRun::migrate_v1(j.as_obj().expect("obj"));
    assert_eq!(run.mode, "quick"); // fast: true
    assert_eq!(run.engine, "native");

    // rows -> micro, one measurement per recorded stat, units in ms.
    let micro = run.workload("micro").expect("micro");
    let mean = micro.get("matmul_nn tiled").expect("mean row");
    assert_eq!(mean.unit, Unit::MsPerIter);
    assert_eq!(mean.value, 1.5);
    assert_eq!(mean.iters, 9);
    assert_eq!(micro.get("matmul_nn tiled [p95]").expect("p95 row").value, 1.9);
    assert_eq!(micro.params.get("config").and_then(Json::as_str), Some("tiny d_model=256"));

    // Sections land under the workload names the new harness uses, with
    // units inferred per key.
    let decode = run.workload("decode_heavy").expect("decode_heavy");
    assert_eq!(decode.get("speedup").expect("speedup").unit, Unit::Ratio);
    assert_eq!(decode.get("per_token_kv_ms").expect("kv ms").unit, Unit::MsPerIter);

    let serve = run.workload("serve_mixed").expect("serve_mixed");
    assert_eq!(serve.get("tokens_per_s_slots4").expect("tps").unit, Unit::TokensPerS);
    // Fault-injection tallies migrate as volatile counts; plain counts
    // stay deterministic.
    let failures = serve.get("slot_failures_faulted").expect("failures");
    assert_eq!(failures.unit, Unit::Count);
    assert!(!failures.deterministic);
    assert!(serve.get("scored").expect("scored").deterministic);

    let kv = run.workload("kv_cur").expect("kv_cur");
    assert_eq!(kv.get("live_bytes_keep50").expect("live").unit, Unit::Bytes);
    assert_eq!(kv.get("ppl_exact").expect("ppl").unit, Unit::Ppl);
    assert_eq!(kv.get("token_agreement_keep50").expect("agreement").unit, Unit::Ratio);

    let heal = run.workload("peft_heal").expect("peft_heal");
    assert_eq!(heal.get("final_loss_du").expect("loss").unit, Unit::Nats);
    assert_eq!(heal.get("steps_per_s_du").expect("rate").unit, Unit::StepsPerS);
    let series = heal.series.iter().find(|(k, _)| k == "du_loss_series").expect("series");
    assert_eq!(series.1, vec![3.0, 2.0, 1.5, 1.25]);

    // The unknown section survives verbatim in `extra` and therefore in
    // the serialized v2 output.
    assert!(run.extra.iter().any(|(k, _)| k == "custom_section"));
    let out = run.to_json().to_string_pretty();
    assert!(out.contains("custom_section"), "{out}");

    // Nothing v1 said is dropped: every numeric leaf of every known
    // section is now a measurement or a series entry.
    assert_eq!(run.workload("micro").expect("micro").measurements.len(), 4);
    assert_eq!(decode.measurements.len(), 2);
    assert_eq!(serve.measurements.len(), 3);
    assert_eq!(kv.measurements.len(), 4);
    assert_eq!(heal.measurements.len(), 2);
    assert_eq!(heal.series.len(), 1);
}

#[test]
fn load_auto_migrates_v1_files() {
    let path = tmp_path("v1");
    std::fs::write(&path, V1_TEXT).expect("write v1");
    let run = RecordedRun::load(&path).expect("load");
    assert!(run.workload("serve_mixed").is_some());
    let _ = std::fs::remove_file(&path);
}

// ----------------------------------------------- merge preserves what it
// does not own (pins the old merge_bench_json contract)

#[test]
fn merge_into_preserves_unmerged_workloads_and_unknown_sections() {
    let path = tmp_path("merge");
    std::fs::write(&path, V1_TEXT).expect("seed v1 file");

    // A partial re-run: only kv_cur executed this invocation.
    let mut partial = RecordedRun::new("native", false);
    partial.commit = Some("cafe0001".to_string());
    let mut kv = WorkloadRecord::new("kv_cur");
    kv.put("exact_slot_bytes", Measurement::point(8192.0, Unit::Bytes));
    partial.put_workload(kv);
    partial.merge_into(&path).expect("merge");

    let merged = RecordedRun::load(&path).expect("reload");
    // Header reflects the new run...
    assert_eq!(merged.mode, "full");
    assert_eq!(merged.commit.as_deref(), Some("cafe0001"));
    // ...the re-run workload was replaced wholesale...
    let kv = merged.workload("kv_cur").expect("kv_cur");
    assert_eq!(kv.get("exact_slot_bytes").expect("bytes").value, 8192.0);
    assert!(kv.get("live_bytes_keep50").is_none());
    // ...and everything the partial run did not own survived: the other
    // migrated workloads and the unknown v1 section.
    for name in ["micro", "decode_heavy", "serve_mixed", "peft_heal"] {
        assert!(merged.workload(name).is_some(), "lost workload {name}");
    }
    assert!(merged.extra.iter().any(|(k, _)| k == "custom_section"));
    // The file on disk is now v2: loading it strictly (no migration)
    // succeeds.
    let text = std::fs::read_to_string(&path).expect("read");
    let j = Json::parse(&text).expect("json");
    assert!(RecordedRun::from_json(&j).is_ok());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn merge_into_a_fresh_path_creates_the_file() {
    let path = tmp_path("fresh");
    let _ = std::fs::remove_file(&path);
    sample_run().merge_into(&path).expect("merge into nothing");
    assert!(RecordedRun::load(&path).is_ok());
    let _ = std::fs::remove_file(&path);
}

//! Allocation accounting for the inference-only forward.
//!
//! A counting global allocator measures exactly what one
//! `layer_forward_infer` call allocates after scratch warmup. The
//! acceptance bound: the inference path must never allocate the
//! (b·nh·s·s) softmax-probs tensor the train/heal cache carries, so its
//! total allocation per call must stay strictly below that buffer's
//! size (the only fresh buffer is the (b·s·d) output).
//!
//! This lives in its own test binary so no sibling test thread pollutes
//! the process-wide counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use curing::backend::Backend;
use curing::model::ModelConfig;
use curing::pipeline::{LayerKind, Pipeline};
use curing::runtime::Runtime;
use curing::tensor::Tensor;
use curing::util::{Json, Rng};

struct CountingAlloc;

static ALLOCATED: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated() -> usize {
    ALLOCATED.load(Ordering::Relaxed)
}

#[test]
fn infer_path_performs_no_softmax_probs_allocation() {
    // Small enough that every kernel stays on the calling thread (no
    // worker-stack allocations in the measurement window).
    let manifest = Json::parse(
        r#"{"configs":{"t":{"vocab":64,"d_model":32,"n_layers":1,"n_heads":4,
        "d_inter":64,"seq":16,"batch":2,"ranks":[4],"default_rank":4,
        "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
    )
    .unwrap();
    let cfg = ModelConfig::from_manifest(&manifest, "t").unwrap();
    let (b, s, d, nh) = (cfg.batch, cfg.seq, cfg.d_model, cfg.n_heads);
    let mut rng = Rng::new(7, 0);
    let store = cfg.init_dense(&mut rng);
    let rt = Runtime::native();
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let params = pipe.layer_params(&store, 0, &LayerKind::Dense).unwrap();
    let x = Tensor::from_f32(&[b, s, d], rng.normal_vec(b * s * d, 1.0));
    let be = rt.backend();

    // Warm the scratch buffers and the RoPE table cache.
    for _ in 0..2 {
        be.layer_forward_infer(&cfg, &params, &x).unwrap();
    }

    let probs_bytes = b * nh * s * s * 4;
    let output_bytes = b * s * d * 4;
    assert!(
        output_bytes < probs_bytes,
        "test shape must separate output from probs ({output_bytes} vs {probs_bytes})"
    );

    let before = allocated();
    let y = be.layer_forward_infer(&cfg, &params, &x).unwrap();
    let infer_bytes = allocated() - before;
    assert_eq!(y.shape, x.shape);
    assert!(
        infer_bytes < probs_bytes,
        "inference forward allocated {infer_bytes} B — at least a \
         (b·nh·s·s) probs buffer ({probs_bytes} B) worth; the cache-free \
         path must only allocate its output (~{output_bytes} B)"
    );

    // Sanity that the counter sees real allocations: the cached
    // (train/heal) forward carries the probs buffer and then some.
    let before = allocated();
    let y2 = be.layer_forward(&cfg, &params, &x).unwrap();
    let cached_bytes = allocated() - before;
    assert_eq!(y2.shape, x.shape);
    assert!(
        cached_bytes >= probs_bytes,
        "cached forward allocated only {cached_bytes} B (< probs {probs_bytes} B)?"
    );
}

//! Integration tests of the native switched full-model graphs (the
//! PEFT comparisons, Figs 5–7): zero-adapter identity, strict
//! missing-tensor errors, and full-model ΔU healing.

use curing::backend::StepMode;
use curing::calib::Calibration;
use curing::compress::{cure_layers, CompressOptions};
use curing::heal::SwitchedRunner;
use curing::model::ModelConfig;
use curing::peft::{init_adapters, Adapter};
use curing::pipeline::{LayerPlan, Pipeline};
use curing::runtime::Runtime;
use curing::tensor::{Tensor, TensorStore};
use curing::util::Rng;

fn mini(rt: &Runtime) -> ModelConfig {
    ModelConfig::from_manifest(rt.manifest(), "mini").expect("mini config")
}

fn flat_calib(cfg: &ModelConfig) -> Calibration {
    Calibration {
        attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
        angular: vec![0.0; cfg.n_layers],
        n_examples: 1,
    }
}

/// Compressed student over a pretend-trained dense teacher, plus a
/// token batch.
fn setup(seed: u64) -> (Runtime, ModelConfig, TensorStore, TensorStore, Tensor, Tensor) {
    let rt = Runtime::native();
    let cfg = mini(&rt);
    let mut rng = Rng::new(seed, 0);
    let teacher = cfg.init_dense(&mut rng);
    let mut student = teacher.clone();
    let calib = flat_calib(&cfg);
    let opts = CompressOptions { r_max: 4, ..Default::default() };
    cure_layers(&mut student, &cfg, &calib, &[1, 2], &opts).unwrap();
    let (b, s) = (cfg.batch, cfg.seq);
    let toks: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
    let mut tgts = toks[1..].to_vec();
    tgts.push(0);
    let tokens = Tensor::from_i32(&[b, s], toks);
    let targets = Tensor::from_i32(&[b, s], tgts);
    (rt, cfg, teacher, student, tokens, targets)
}

/// A freshly initialized adapter is exactly inert: every family's
/// trainable factor starts at zero (LoRA B, MoRA M, CURLoRA U; Du has
/// no adapter store at all), so switched logits must equal the plain
/// cured-student logits bitwise.
#[test]
fn zero_initialized_adapters_are_identity() {
    let (rt, cfg, teacher, student, tokens, _) = setup(31);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let plan = LayerPlan::with_cured(&cfg, &[1, 2], 4, "all");
    let base = pipe.logits(&student, &plan, &tokens).unwrap();
    let calib = flat_calib(&cfg);
    for adapter in Adapter::ALL {
        let mut rng = Rng::new(7, 0);
        let adapters = init_adapters(adapter, &cfg, &teacher, &calib, &mut rng).unwrap();
        let switched =
            curing::eval::switched_logits(&pipe, &teacher, &student, &adapters, adapter, &tokens)
                .unwrap();
        assert_eq!(
            switched, base,
            "{adapter:?}: zero-initialized adapter changed the logits"
        );
    }
}

/// A misnamed active-family tensor must be a hard error — never a
/// silent zero-fill that evaluates (or trains) the base model.
#[test]
fn renamed_adapter_tensor_errors_instead_of_scoring() {
    let (rt, cfg, teacher, mut student, tokens, targets) = setup(32);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let calib = flat_calib(&cfg);
    let mut rng = Rng::new(8, 0);
    let mut adapters = init_adapters(Adapter::Lora, &cfg, &teacher, &calib, &mut rng).unwrap();
    // Sanity: intact store evaluates fine.
    curing::eval::switched_logits(&pipe, &teacher, &student, &adapters, Adapter::Lora, &tokens)
        .unwrap();
    // Rename one LoRA tensor (the satellite's typo scenario).
    let t = adapters.remove("L1.lora_a_q").unwrap();
    adapters.insert("L1.lora_a_q_oops", t);
    let err = curing::eval::switched_logits(
        &pipe, &teacher, &student, &adapters, Adapter::Lora, &tokens,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("lora_a_q"),
        "error must name the missing tensor, got: {err:#}"
    );
    // The train step must refuse too.
    let runner = SwitchedRunner::new(Adapter::Lora, StepMode::Heal);
    let mut opt = TensorStore::new();
    let err = runner
        .step(
            &pipe, &teacher, &mut student, &mut adapters, &mut opt, &tokens, &targets, None,
            1e-3, 1,
        )
        .unwrap_err();
    assert!(err.to_string().contains("lora_a_q"), "step error must name it, got: {err:#}");
    // An inactive family's absence stays fine: evaluating MoRA with a
    // proper MoRA store ignores the broken LoRA tensors entirely.
    let mora = init_adapters(Adapter::Mora, &cfg, &teacher, &calib, &mut rng).unwrap();
    curing::eval::switched_logits(&pipe, &teacher, &student, &mora, Adapter::Mora, &tokens)
        .unwrap();
}

/// A cured layer missing its ΔU tensor is a malformed student store:
/// the switched graphs must error, not skip it — for every adapter
/// family, since `U = U₀ + ΔU` merges silently when ΔU is absent.
#[test]
fn missing_student_delta_u_errors() {
    let (rt, cfg, teacher, mut student, tokens, _) = setup(33);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    student.remove("L1.du_q").unwrap();
    let adapters = TensorStore::new();
    let err = curing::eval::switched_logits(
        &pipe, &teacher, &student, &adapters, Adapter::Du, &tokens,
    )
    .unwrap_err();
    assert!(err.to_string().contains("du_q"), "error must name the factor, got: {err:#}");
    // The same malformed store must also refuse to score under a
    // non-Du adapter (the cured base would silently lose its heal).
    let calib = flat_calib(&cfg);
    let mut rng = Rng::new(10, 0);
    let lora = init_adapters(Adapter::Lora, &cfg, &teacher, &calib, &mut rng).unwrap();
    let err = curing::eval::switched_logits(
        &pipe, &teacher, &student, &lora, Adapter::Lora, &tokens,
    )
    .unwrap_err();
    assert!(err.to_string().contains("du_q"), "LoRA eval must error too, got: {err:#}");
}

/// Full-model ΔU healing on a fixed batch: 20 switched KD steps must
/// reduce the 0.9·KD(T=10) + 0.1·CE loss (deterministic descent — the
/// same batch every step).
#[test]
fn switched_du_heal_loss_decreases_on_fixed_batch() {
    let (rt, cfg, teacher, mut student, tokens, targets) = setup(34);
    let pipe = Pipeline { rt: &rt, cfg };
    let mut adapters = TensorStore::new();
    let mut opt = TensorStore::new();
    let runner = SwitchedRunner::new(Adapter::Du, StepMode::Heal);
    let mut losses = Vec::new();
    for step in 0..20 {
        let loss = runner
            .step(
                &pipe, &teacher, &mut student, &mut adapters, &mut opt, &tokens, &targets,
                None, 3e-3, step + 1,
            )
            .unwrap();
        assert!(loss.is_finite(), "step {step} loss {loss}");
        losses.push(loss);
    }
    let first: f64 = losses[..3].iter().sum::<f64>() / 3.0;
    let last: f64 = losses[losses.len() - 3..].iter().sum::<f64>() / 3.0;
    assert!(
        last < first,
        "switched ΔU healing must reduce the KD loss on a fixed batch: \
         first {first} last {last} (series {losses:?})"
    );
    // ΔU actually moved.
    let du = student.get("L1.du_q").unwrap();
    assert!(du.fro_norm() > 0.0, "ΔU never moved");
}

/// The switched step must accept every adapter family end-to-end on the
/// mini config (one step each, heal and task modes).
#[test]
fn all_families_step_in_both_modes() {
    let (rt, cfg, teacher, student, tokens, targets) = setup(35);
    let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
    let calib = flat_calib(&cfg);
    let mask = Tensor::from_f32(
        &[cfg.batch, cfg.seq],
        (0..cfg.batch * cfg.seq).map(|i| (i % 2) as f32).collect(),
    );
    for adapter in Adapter::ALL {
        for mode in [StepMode::Heal, StepMode::Task] {
            let mut rng = Rng::new(9, 0);
            let mut student = student.clone();
            let mut adapters =
                init_adapters(adapter, &cfg, &teacher, &calib, &mut rng).unwrap();
            let mut opt = TensorStore::new();
            let runner = SwitchedRunner::new(adapter, mode);
            let loss_mask = if mode == StepMode::Task { Some(&mask) } else { None };
            let loss = runner
                .step(
                    &pipe, &teacher, &mut student, &mut adapters, &mut opt, &tokens,
                    &targets, loss_mask, 1e-3, 1,
                )
                .unwrap();
            assert!(loss.is_finite() && loss > 0.0, "{adapter:?} {mode:?} loss {loss}");
        }
    }
}

//! Offline stand-in for the `xla` PJRT crate.
//!
//! Mirrors exactly the API surface `curing`'s PJRT backend uses, so the
//! `pjrt` feature always compiles without the (unvendorable) XLA C++
//! runtime. Every entry point fails at `PjRtClient::cpu()` with a clear
//! message; swap the `xla` path dependency in `rust/Cargo.toml` for a
//! real xla-rs checkout to execute artifacts.

#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub: the real XLA/PJRT runtime is not vendored in this build; \
         point the `xla` path dependency at an xla-rs checkout"
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Element types a literal can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("xla stub"));
    }
}

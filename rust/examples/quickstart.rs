//! Quickstart: compress a model with CURing in ~40 lines.
//!
//! Loads (or trains) the dense Llama-mini, compresses 3 layers with
//! DEIM-CUR over WANDA importance, and compares perplexity before/after —
//! the minimal end-to-end use of the public API.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx};
use curing::data::{Corpus, CorpusKind, SEED_EVAL};
use curing::eval::perplexity;
use curing::pipeline::LayerPlan;
use curing::util::stats::mib;

fn main() -> Result<()> {
    // The coordinator context: PJRT runtime + vocab + run directory.
    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;

    // The "original" model (pretrained on synth-c4; cached on disk).
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;

    // Calibrate: WANDA activation norms + per-layer angular distances.
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;

    // Compress the 3 most redundant layers (smallest angular distance).
    let (student, plan, report) = ctx.compress_k(
        &pipe,
        &dense,
        &calib,
        3,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;
    println!(
        "compressed layers {:?} in {:.2}s — saved {:.2} MiB",
        report.layers,
        report.seconds_total,
        mib(report.bytes_saved() as f64)
    );

    // Perplexity before/after on held-out synth-c4.
    let mut eval_a = Corpus::new(CorpusKind::SynthC4, SEED_EVAL);
    let mut eval_b = Corpus::new(CorpusKind::SynthC4, SEED_EVAL);
    let dense_plan = LayerPlan::all_dense(&pipe.cfg);
    let ppl_dense = perplexity(&pipe, &dense, &dense_plan, &ctx.vocab, &mut eval_a, 4)?;
    let ppl_cured = perplexity(&pipe, &student, &plan, &ctx.vocab, &mut eval_b, 4)?;
    println!("perplexity: dense {ppl_dense:.2} -> cured {ppl_cured:.2}");
    println!("(run `cargo run --release --example e2e_reproduction` for healing)");
    Ok(())
}

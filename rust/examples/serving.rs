//! Serving demo: the batching eval server fronting the original vs the
//! CURing-compressed model — throughput/latency with multi-threaded
//! clients (the deployment story the paper's intro motivates: same
//! input/output interface, smaller model, no architecture change).
//!
//! Run: cargo run --release --example serving [-- --clients 4 --requests 8]

use anyhow::Result;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx};
use curing::data::CorpusKind;
use curing::pipeline::LayerPlan;
use curing::serve::{spawn_clients, BatchingServer};
use curing::util::cli::Args;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let clients = args.usize_opt("clients", 4);
    let per_client = args.usize_opt("requests", 8);
    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let (student, plan, _) = ctx.compress_k(
        &pipe,
        &dense,
        &calib,
        3,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;

    for (label, store, plan) in [
        ("original", &dense, LayerPlan::all_dense(&pipe.cfg)),
        ("cured(k=3)", &student, plan),
    ] {
        let (rx, _resps) = spawn_clients(
            &ctx.vocab,
            CorpusKind::SynthC4,
            pipe.cfg.seq,
            clients,
            per_client,
            2,
        );
        let server = BatchingServer {
            pipe: &pipe,
            store,
            plan,
            max_wait: Duration::from_millis(25),
        };
        let stats = server.run(rx, clients * per_client)?;
        println!(
            "{label:<11} {} reqs | {:>6.1} seq/s | occupancy {:>4.1}/{} | padded {} | p50 {:>6.1} ms | p95 {:>6.1} ms",
            stats.served,
            stats.throughput_seq_per_s,
            stats.mean_batch_occupancy,
            pipe.cfg.batch,
            stats.padded_rows,
            stats.p50_latency_ms,
            stats.p95_latency_ms
        );
    }
    println!("\n(The cured pipeline replaces three dense layers with rank-16 CUR chains;");
    println!(" same request interface, fewer FLOPs per layer, smaller weights.)");
    Ok(())
}

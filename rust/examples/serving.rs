//! Serving demo: the continuous-batching server fronting the original
//! vs the CURing-compressed model — scoring throughput plus batched
//! greedy generation over KV-cache slots (the deployment story the
//! paper's intro motivates: same input/output interface, smaller model,
//! no architecture change).
//!
//! Run: cargo run --release --example serving [-- --clients 4 --requests 8 --slots 4 --tokens 24 --kv-policy cur:0.5]

use anyhow::Result;
use curing::backend::KvPolicy;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx};
use curing::data::CorpusKind;
use curing::pipeline::LayerPlan;
use curing::serve::{
    drain_gen_responses, drain_score_responses, spawn_gen_clients, spawn_score_clients,
    GenerationServer, Request,
};
use curing::util::cli::Args;
use std::sync::mpsc::channel;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let clients = args.usize_opt("clients", 4);
    let per_client = args.usize_opt("requests", 8);
    let slots = args.usize_opt("slots", 4);
    let n_new = args.usize_opt("tokens", 24);
    let kv_policy = KvPolicy::parse(&args.str_opt("kv-policy", "exact"))?;
    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let (student, plan, _) = ctx.compress_k(
        &pipe,
        &dense,
        &calib,
        3,
        LayerStrategy::Angular,
        &CompressOptions::default(),
    )?;

    for (label, store, plan) in [
        ("original", &dense, LayerPlan::all_dense(&pipe.cfg)),
        ("cured(k=3)", &student, plan),
    ] {
        // Mixed traffic on one queue: scoring clients + generation
        // clients; generation requests are admitted into free KV slots
        // mid-flight while partial scoring batches flush in between.
        let (tx, rx) = channel::<Request>();
        let scores = spawn_score_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            pipe.cfg.seq,
            clients,
            per_client,
            2,
        );
        let gens = spawn_gen_clients(
            &tx,
            &ctx.vocab,
            CorpusKind::SynthC4,
            8,
            n_new,
            clients,
            per_client,
            2,
        );
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store,
            plan,
            max_wait: Duration::from_millis(25),
            slots,
            kv_policy,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx)?;
        println!(
            "{label:<11} score: {} reqs | {:>6.1} seq/s | occupancy {:>4.1}/{} | padded {} | p50 {:>6.1} ms",
            stats.served,
            stats.throughput_seq_per_s,
            stats.mean_batch_occupancy,
            pipe.cfg.batch,
            stats.padded_rows,
            stats.p50_latency_ms,
        );
        println!(
            "{label:<11} gen:   {} reqs / {} toks | {:>6.1} tok/s | slots {:>4.1}/{} | prefills {} | tok p50 {:>5.2} ms p95 {:>5.2} ms",
            stats.gen_served,
            stats.tokens_generated,
            stats.tokens_per_s,
            stats.mean_active_slots,
            slots,
            stats.prefills,
            stats.tok_p50_ms,
            stats.tok_p95_ms,
        );
        if stats.kv_compactions > 0 {
            println!(
                "{label:<11} kv:    policy {kv_policy} | {} compactions | mean live {:.3} MiB",
                stats.kv_compactions,
                stats.kv_live_bytes_mean / (1024.0 * 1024.0),
            );
        }
        // Per-request outcomes as the clients saw them (typed errors,
        // not just the aggregate counters).
        let (_, score_tally) = drain_score_responses(&scores);
        let (_, gen_tally) = drain_gen_responses(&gens);
        println!("{label:<11} reqs:  score {score_tally} | gen {gen_tally}");
    }
    println!("\n(The cured pipeline replaces three dense layers with rank-16 CUR chains;");
    println!(" same request interface, fewer FLOPs per layer, smaller weights. Each");
    println!(" generation request prefills once — the ring-buffer KV window rotates");
    println!(" recompute-free — and decode steps fuse all active slots into one pass.)");
    Ok(())
}

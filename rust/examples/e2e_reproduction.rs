//! End-to-end reproduction driver (the EXPERIMENTS.md headline run).
//!
//! Exercises every layer of the stack on a real small workload:
//!   1. pretrain the dense Llama-mini on synth-c4 (full-model AOT train
//!      step; cached across runs),
//!   2. calibrate (WANDA norms + angular distances, paper Table 4),
//!   3. CURing-compress k layers (DEIM-CUR on WANDA importance),
//!   4. evaluate the Figure-4 suite before/after,
//!   5. heal with layer-wise knowledge distillation (ΔU only),
//!   6. re-evaluate and also run a few full-model KD steps,
//! and writes a JSON record under runs/records/.
//!
//! Usage: cargo run --release --example e2e_reproduction [-- --layers 3
//!        --heal-steps 120 --rank 16]

use anyhow::Result;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx, EvalSizes};
use curing::data::{Corpus, CorpusKind, SEED_HEAL};
use curing::heal::{heal_layers, HealOptions, StepMode, SwitchedRunner};
use curing::pipeline::LayerPlan;
use curing::tensor::TensorStore;
use curing::util::cli::Args;
use curing::util::stats::mib;
use curing::util::{Json, JsonObj};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let k = args.usize_opt("layers", 3);
    let heal_steps = args.usize_opt("heal-steps", 120);
    let rank = args.usize_opt("rank", 16);
    let pre_steps = args.usize_opt("pretrain-steps", default_pretrain_steps());

    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;
    let mut record = JsonObj::new();

    println!("== CURing end-to-end reproduction (tiny Llama-mini, k={k}, r_max={rank}) ==\n");

    // 1. Pretrain (cached).
    println!("[1/6] pretraining dense model ({pre_steps} steps, cached)...");
    let dense = ctx.load_or_pretrain("tiny", pre_steps)?;
    println!(
        "      {} params, {:.1} MiB f32",
        dense.total_params(),
        mib(dense.total_bytes() as f64)
    );

    // 2. Calibrate.
    println!("[2/6] calibrating on 128 synth-c4 examples...");
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    println!("      angular distances (paper Table 4 analog), ascending:");
    let mut order = pipe.cfg.middle_layers();
    order.sort_by(|&a, &b| calib.angular[a].total_cmp(&calib.angular[b]));
    for &l in &order {
        println!("        layer {:>2}: {:.4}", l, calib.angular[l]);
    }
    record.insert(
        "angular",
        Json::Arr(calib.angular.iter().map(|&x| Json::Num(x)).collect()),
    );

    // 3. Baseline evaluation.
    let sizes = EvalSizes::default();
    println!("[3/6] evaluating the original model...");
    let dense_plan = LayerPlan::all_dense(&pipe.cfg);
    let base = ctx.eval_suite(&pipe, &dense, &dense_plan, &sizes)?;
    println!("      dense:    {}", base.row());

    // 4. Compress.
    println!("[4/6] CURing-compressing {k} layers (WANDA+DEIM, r_max={rank})...");
    let opts = CompressOptions { r_max: rank, ..Default::default() };
    let (mut student, plan, report) =
        ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
    println!(
        "      layers {:?} in {:.2}s, saved {:.2} MiB ({:.1}% of model)",
        report.layers,
        report.seconds_total,
        mib(report.bytes_saved() as f64),
        100.0 * report.bytes_saved() as f64 / dense.total_bytes() as f64
    );
    let cured = ctx.eval_suite(&pipe, &student, &plan, &sizes)?;
    println!("      cured:    {}", cured.row());

    // 5. Heal (layer-wise KD on ΔU).
    println!("[5/6] healing: layer-wise KD for {heal_steps} steps (ΔU only)...");
    let mut corpus = Corpus::new(CorpusKind::SynthC4, SEED_HEAL);
    let mut opt = TensorStore::new();
    let hopts = HealOptions { steps: heal_steps, ..Default::default() };
    let hist = heal_layers(
        &pipe, &dense, &mut student, &mut opt, &ctx.vocab, &mut corpus, &hopts, 0,
    )?;
    let mut curve = Vec::new();
    for p in &hist {
        if p.step % 20 == 0 || p.step + 1 == hist.len() {
            println!("        step {:>4}: layer-MSE {:.6}", p.step, p.loss);
        }
        curve.push(Json::Num(p.loss));
    }
    record.insert("heal_curve", Json::Arr(curve));
    let healed = ctx.eval_suite(&pipe, &student, &plan, &sizes)?;
    println!("      healed:   {}", healed.row());

    // 6. A few full-model KD steps (0.9·KD(T=10) + 0.1·CE) to exercise
    // the switched training path end to end. Runs on every backend: the
    // native backend executes the blended full-model graph directly, the
    // pjrt backend dispatches the switched AOT artifact.
    println!("[6/6] full-model KD (switched ΔU graph, 5 steps)...");
    let runner = SwitchedRunner::new(curing::peft::Adapter::Du, StepMode::Heal);
    let mut adapters = TensorStore::new();
    let mut fullopt = TensorStore::new();
    for step in 0..5 {
        let (toks, tgts) = corpus.batch(&ctx.vocab, pipe.cfg.batch, pipe.cfg.seq);
        let tokens =
            curing::tensor::Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], toks);
        let targets =
            curing::tensor::Tensor::from_i32(&[pipe.cfg.batch, pipe.cfg.seq], tgts);
        let loss = runner.step(
            &pipe, &dense, &mut student, &mut adapters, &mut fullopt, &tokens, &targets,
            None, 1e-4, step + 1,
        )?;
        println!("        step {step}: loss {loss:.4}");
    }
    let final_suite = ctx.eval_suite(&pipe, &student, &plan, &sizes)?;
    println!("      final:    {}", final_suite.row());

    // Record + summary.
    let suite_json = |s: &curing::coordinator::Suite| {
        let mut o = JsonObj::new();
        o.insert("c4_ppl", Json::Num(s.c4_ppl));
        o.insert("wiki_ppl", Json::Num(s.wiki_ppl));
        o.insert("boolq", Json::Num(s.boolq_acc));
        o.insert("mmlu", Json::Num(s.mmlu_acc));
        Json::Obj(o)
    };
    record.insert("dense", suite_json(&base));
    record.insert("cured", suite_json(&cured));
    record.insert("healed", suite_json(&healed));
    record.insert("final", suite_json(&final_suite));
    record.insert("k", Json::Num(k as f64));
    record.insert("rank", Json::Num(rank as f64));
    record.insert("bytes_saved", Json::Num(report.bytes_saved() as f64));
    record.insert("compress_seconds", Json::Num(report.seconds_total));
    let path = ctx.write_record("e2e_reproduction", &Json::Obj(record))?;
    println!("\nrecord written to {}", path.display());

    println!("\n== summary (paper Fig. 4 shape: compress hurts, healing recovers) ==");
    println!("  dense  c4_ppl {:.2} | cured {:.2} | healed {:.2}", base.c4_ppl, cured.c4_ppl, healed.c4_ppl);
    println!("  dense  wiki   {:.2} | cured {:.2} | healed {:.2}", base.wiki_ppl, cured.wiki_ppl, healed.wiki_ppl);
    Ok(())
}

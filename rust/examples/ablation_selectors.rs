//! Selector ablation (paper Appendix D.2, Table 5 + Figure 12):
//! CURing (WANDA+DEIM) vs WANDA-only vs DEIM-only vs weight-magnitude vs
//! random row/column selection, at equal rank and layer set.
//!
//! Run: cargo run --release --example ablation_selectors [-- --layers 3]

use anyhow::Result;
use curing::compress::{CompressOptions, LayerStrategy};
use curing::coordinator::{default_pretrain_steps, Ctx, EvalSizes};
use curing::util::cli::Args;
use curing::wanda::Selector;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let k = args.usize_opt("layers", 3);
    let ctx = Ctx::new()?;
    let pipe = ctx.pipeline("tiny")?;
    let dense = ctx.load_or_pretrain("tiny", default_pretrain_steps())?;
    let calib = ctx.calibrate_cached(&pipe, &dense, 128)?;
    let sizes = EvalSizes::default();

    println!("selector ablation, k={k} layers, r_max=16 (paper Table 5 / Fig 12)\n");
    println!(
        "{:<8} {:>14} {:>10} {:>10} {:>8} {:>8}",
        "selector", "Σ‖W−CUR‖_F", "c4_ppl", "wiki_ppl", "boolq", "mmlu"
    );
    for sel in Selector::ALL {
        let opts = CompressOptions { selector: sel, ..Default::default() };
        let (student, plan, report) =
            ctx.compress_k(&pipe, &dense, &calib, k, LayerStrategy::Angular, &opts)?;
        let total_diff: f64 = report.weights.iter().map(|w| w.diff_fro).sum();
        let suite = ctx.eval_suite(&pipe, &student, &plan, &sizes)?;
        println!(
            "{:<8} {:>14.3} {:>10.2} {:>10.2} {:>8.3} {:>8.3}",
            sel.label(),
            total_diff,
            suite.c4_ppl,
            suite.wiki_ppl,
            suite.boolq_acc,
            suite.mmlu_acc
        );
    }
    println!("\nExpected shape: CURing has the smallest ‖W−CUR‖_F and the most stable metrics;");
    println!("Random is worst (paper Appendix D.2).");
    Ok(())
}

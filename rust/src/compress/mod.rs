//! The CURing compression pipeline (paper §4): layer selection + per-
//! weight DEIM-CUR factorization, producing a cured tensor store and the
//! Table 1/2/5 accounting.

use crate::calib::Calibration;
use crate::cur::rank_rule;
use crate::linalg::Mat;
use crate::model::{combo_targets, ModelConfig};
use crate::tensor::{Tensor, TensorStore};
use crate::util::Rng;
use crate::wanda::{cur_with_selector, Selector};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Layer-selection strategy (paper §4.1 + Appendix D.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerStrategy {
    /// Smallest angular distance first (CURing's choice).
    Angular,
    /// Last N eligible layers (the Appendix D.1 baseline).
    LastN,
    /// Uniform random eligible layers.
    Random,
}

impl LayerStrategy {
    pub fn label(&self) -> &'static str {
        match self {
            LayerStrategy::Angular => "angular",
            LayerStrategy::LastN => "last-n",
            LayerStrategy::Random => "random",
        }
    }

    pub fn parse(s: &str) -> Result<LayerStrategy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "angular" => LayerStrategy::Angular,
            "last-n" | "lastn" | "last" => LayerStrategy::LastN,
            "random" => LayerStrategy::Random,
            other => anyhow::bail!("unknown layer strategy '{other}'"),
        })
    }
}

/// Pick `k` layers to compress among the eligible middle layers.
pub fn select_layers(
    cfg: &ModelConfig,
    calib: &Calibration,
    k: usize,
    strategy: LayerStrategy,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    let eligible = cfg.middle_layers();
    ensure!(k <= eligible.len(), "k={k} exceeds {} eligible layers", eligible.len());
    let mut chosen = match strategy {
        LayerStrategy::Angular => {
            let mut order = eligible.clone();
            order.sort_by(|&a, &b| calib.angular[a].total_cmp(&calib.angular[b]));
            order.truncate(k);
            order
        }
        LayerStrategy::LastN => {
            let mut order = eligible.clone();
            order.reverse();
            order.truncate(k);
            order
        }
        LayerStrategy::Random => {
            let picks = rng.sample_distinct(eligible.len(), k);
            picks.into_iter().map(|i| eligible[i]).collect()
        }
    };
    chosen.sort_unstable();
    Ok(chosen)
}

/// Per-weight compression record (feeds Tables 1, 2, 5).
#[derive(Debug, Clone)]
pub struct WeightReport {
    pub layer: usize,
    pub proj: String,
    pub rank: usize,
    pub w_fro: f64,
    pub cur_fro: f64,
    pub diff_fro: f64,
    pub sigma_next: f64,
    pub params_dense: usize,
    pub params_cur: usize,
    pub seconds: f64,
}

/// Whole-run compression report.
#[derive(Debug, Clone, Default)]
pub struct CompressReport {
    pub layers: Vec<usize>,
    pub weights: Vec<WeightReport>,
    pub seconds_total: f64,
}

impl CompressReport {
    pub fn bytes_saved(&self) -> usize {
        self.weights
            .iter()
            .map(|w| (w.params_dense.saturating_sub(w.params_cur)) * 4)
            .sum()
    }

    /// Σ‖W − CUR‖_F per layer (Table 5 rows).
    pub fn layer_diff_fro(&self, layer: usize) -> f64 {
        self.weights.iter().filter(|w| w.layer == layer).map(|w| w.diff_fro).sum()
    }

    pub fn layer_cur_fro(&self, layer: usize) -> f64 {
        self.weights.iter().filter(|w| w.layer == layer).map(|w| w.cur_fro).sum()
    }

    pub fn layer_w_fro(&self, layer: usize) -> f64 {
        self.weights.iter().filter(|w| w.layer == layer).map(|w| w.w_fro).sum()
    }
}

/// Options for one compression run.
#[derive(Debug, Clone)]
pub struct CompressOptions {
    pub combo: String,
    pub r_max: usize,
    pub selector: Selector,
    pub seed: u64,
}

impl Default for CompressOptions {
    fn default() -> Self {
        CompressOptions { combo: "all".into(), r_max: 16, selector: Selector::Curing, seed: 0 }
    }
}

/// Compress `layers` of the dense model in `store` (in place): replaces
/// `L{l}.w_{p}` with `L{l}.{c,u,du,r}_{p}` for each targeted projection.
/// `du` starts at zero — healing updates it (paper §4.5).
pub fn cure_layers(
    store: &mut TensorStore,
    cfg: &ModelConfig,
    calib: &Calibration,
    layers: &[usize],
    opts: &CompressOptions,
) -> Result<CompressReport> {
    let t_total = Instant::now();
    let mut rng = Rng::new(opts.seed, 0xC0DE);
    let mut report = CompressReport { layers: layers.to_vec(), ..Default::default() };
    let targets = combo_targets(&opts.combo)?;
    for &l in layers {
        ensure!(
            l > 0 && l + 1 < cfg.n_layers,
            "layer {l} not eligible (first/last are preserved, paper §4.1)"
        );
        for proj in targets {
            let t0 = Instant::now();
            let name = format!("L{l}.w_{proj}");
            let w_t = store.get(&name)?;
            let w = Mat::from_tensor(w_t)?;
            let (m, n) = (w.rows, w.cols);
            let rank = rank_rule(m, n, opts.r_max);
            let xnorm = calib.xnorm(l, proj)?;
            let f = cur_with_selector(opts.selector, &w, xnorm, rank, &mut rng)?;
            let rec = f.reconstruct();
            let diff = w.sub(&rec);
            report.weights.push(WeightReport {
                layer: l,
                proj: proj.to_string(),
                rank,
                w_fro: w.fro_norm(),
                cur_fro: rec.fro_norm(),
                diff_fro: diff.fro_norm(),
                sigma_next: f.sigma_next,
                params_dense: m * n,
                params_cur: f.param_count(),
                seconds: t0.elapsed().as_secs_f64(),
            });
            store.remove(&name);
            store.insert(format!("L{l}.c_{proj}"), f.c.to_tensor());
            store.insert(format!("L{l}.u_{proj}"), f.u.to_tensor());
            store.insert(format!("L{l}.du_{proj}"), Tensor::zeros(&[rank, rank]));
            store.insert(format!("L{l}.r_{proj}"), f.r.to_tensor());
        }
    }
    report.seconds_total = t_total.elapsed().as_secs_f64();
    store.meta.insert("cured_layers".into(), join_usize(layers));
    store.meta.insert("combo".into(), opts.combo.clone());
    store.meta.insert("r_max".into(), opts.r_max.to_string());
    store.meta.insert("selector".into(), opts.selector.label().to_string());
    Ok(report)
}

/// Read back the cured-layer list persisted in store metadata.
pub fn cured_layers_of(store: &TensorStore) -> Vec<usize> {
    store
        .meta
        .get("cured_layers")
        .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
        .unwrap_or_default()
}

fn join_usize(xs: &[usize]) -> String {
    xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cfg() -> ModelConfig {
        let j = crate::util::Json::parse(
            r#"{"configs":{"t":{"vocab":64,"d_model":16,"n_layers":6,"n_heads":2,
            "d_inter":32,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    fn fake_calib(cfg: &ModelConfig, angular: Vec<f64>) -> Calibration {
        Calibration {
            attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
            ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
            angular,
            n_examples: 4,
        }
    }

    #[test]
    fn angular_selection_prefers_small_distance() {
        let cfg = fake_cfg();
        // Middle layers are 1..=4; give layer 3 the smallest distance.
        let calib = fake_calib(&cfg, vec![0.9, 0.5, 0.4, 0.1, 0.3, 0.9]);
        let mut rng = Rng::new(0, 0);
        let sel = select_layers(&cfg, &calib, 2, LayerStrategy::Angular, &mut rng).unwrap();
        assert_eq!(sel, vec![3, 4]);
    }

    #[test]
    fn lastn_and_random_eligible_only() {
        let cfg = fake_cfg();
        let calib = fake_calib(&cfg, vec![0.0; 6]);
        let mut rng = Rng::new(0, 0);
        let last = select_layers(&cfg, &calib, 3, LayerStrategy::LastN, &mut rng).unwrap();
        assert_eq!(last, vec![2, 3, 4]);
        for _ in 0..20 {
            let r = select_layers(&cfg, &calib, 2, LayerStrategy::Random, &mut rng).unwrap();
            assert!(r.iter().all(|&l| (1..=4).contains(&l)), "{r:?}");
        }
    }

    #[test]
    fn cure_layers_swaps_params_and_saves_bytes() {
        let cfg = fake_cfg();
        let calib = fake_calib(&cfg, vec![0.0; 6]);
        let mut rng = Rng::new(7, 0);
        let mut store = cfg.init_dense(&mut rng);
        let before = store.total_params();
        let opts = CompressOptions { r_max: 4, ..Default::default() };
        let rep = cure_layers(&mut store, &cfg, &calib, &[2, 3], &opts).unwrap();
        assert!(store.total_params() < before);
        assert!(rep.bytes_saved() > 0);
        assert!(!store.contains("L2.w_q"));
        assert!(store.contains("L2.c_q"));
        assert!(store.contains("L2.du_gate"));
        assert!(store.contains("L1.w_q"), "uncompressed layer untouched");
        assert_eq!(cured_layers_of(&store), vec![2, 3]);
        // 2 layers x 3 projections.
        assert_eq!(rep.weights.len(), 6);
        // Approximation is nontrivial but bounded.
        for w in &rep.weights {
            assert!(w.diff_fro > 0.0 && w.diff_fro < w.w_fro);
        }
    }

    #[test]
    fn first_last_layers_rejected() {
        let cfg = fake_cfg();
        let calib = fake_calib(&cfg, vec![0.0; 6]);
        let mut rng = Rng::new(8, 0);
        let mut store = cfg.init_dense(&mut rng);
        let opts = CompressOptions { r_max: 4, ..Default::default() };
        assert!(cure_layers(&mut store, &cfg, &calib, &[0], &opts).is_err());
        assert!(cure_layers(&mut store, &cfg, &calib, &[5], &opts).is_err());
    }

    #[test]
    fn selector_changes_approximation_quality() {
        // Run CURing vs Random on the same store; CURing should win on
        // total reconstruction error (paper Table 5).
        let cfg = fake_cfg();
        let calib = fake_calib(&cfg, vec![0.0; 6]);
        let total = |sel: Selector| {
            let mut rng = Rng::new(9, 0);
            let mut store = cfg.init_dense(&mut rng);
            let opts = CompressOptions { r_max: 4, selector: sel, ..Default::default() };
            let rep = cure_layers(&mut store, &cfg, &calib, &[1, 2, 3, 4], &opts).unwrap();
            rep.weights.iter().map(|w| w.diff_fro).sum::<f64>()
        };
        assert!(total(Selector::Curing) <= total(Selector::Random) * 1.02);
    }
}

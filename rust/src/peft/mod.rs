//! PEFT adapters and the paper's comparison experiments (§6.2, Figs 5–7):
//! CURing's ΔU update vs LoRA, MoRA and CURLoRA under equal trainable-
//! parameter budgets.
//!
//! Adapter parameters live in their own [`TensorStore`]; the switched
//! full-model artifacts blend them on top of the (possibly cured) base
//! model. Initialization follows each method's paper: LoRA A~N(0,σ),
//! B=0; MoRA M=0; CURLoRA C/R sampled by *inverted* WANDA importance
//! with U=0.

use crate::calib::Calibration;
use crate::linalg::Mat;
use crate::model::ModelConfig;
use crate::tensor::{Tensor, TensorStore};
use crate::util::Rng;
use crate::wanda::select_inverted;
use anyhow::{bail, Result};

/// Adapter family for the comparison experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Adapter {
    /// CURing's own ΔU update (the healing parameterization).
    Du,
    Lora,
    Mora,
    CurLora,
}

impl Adapter {
    pub const ALL: [Adapter; 4] = [Adapter::Du, Adapter::Lora, Adapter::Mora, Adapter::CurLora];

    pub fn label(&self) -> &'static str {
        match self {
            Adapter::Du => "curing-du",
            Adapter::Lora => "lora",
            Adapter::Mora => "mora",
            Adapter::CurLora => "curlora",
        }
    }

    /// Artifact-name suffix.
    pub fn tag(&self) -> &'static str {
        match self {
            Adapter::Du => "du",
            Adapter::Lora => "lora",
            Adapter::Mora => "mora",
            Adapter::CurLora => "curlora",
        }
    }

    pub fn parse(s: &str) -> Result<Adapter> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "du" | "curing" | "curing-du" => Adapter::Du,
            "lora" => Adapter::Lora,
            "mora" => Adapter::Mora,
            "curlora" => Adapter::CurLora,
            other => bail!("unknown adapter '{other}'"),
        })
    }

    /// Store-name prefix of this family's tensors (after the `L{l}.`
    /// layer part): `lora_a_q`, `mora_m_gate`, `cl_u_k`, `du_q`, …
    /// `Du`'s tensors live in the *student* store; the other three live
    /// in the adapter store.
    pub fn param_prefix(&self) -> &'static str {
        match self {
            Adapter::Du => "du_",
            Adapter::Lora => "lora_",
            Adapter::Mora => "mora_",
            Adapter::CurLora => "cl_",
        }
    }

    /// The adapter family owning a tensor-name suffix (`lora_a_q` →
    /// LoRA), if any. `du_*` maps to `Du` even though those tensors are
    /// student factors — callers that care distinguish via
    /// [`Adapter::param_prefix`].
    pub fn family_of_suffix(suffix: &str) -> Option<Adapter> {
        [Adapter::Lora, Adapter::Mora, Adapter::CurLora, Adapter::Du]
            .into_iter()
            .find(|a| suffix.starts_with(a.param_prefix()))
    }
}

/// Trainable-parameter count per adapter (for the equal-budget tables).
pub fn trainable_params(adapter: Adapter, cfg: &ModelConfig) -> Result<usize> {
    let mids = cfg.middle_layers().len();
    let r = cfg.default_rank;
    let per_layer = match adapter {
        Adapter::Du | Adapter::CurLora => 3 * r * r,
        Adapter::Mora => 3 * cfg.mora_rank * cfg.mora_rank,
        Adapter::Lora => {
            // Per projection, LoRA trains A (m×rl) + B (rl×n) = rl·(m+n).
            // Computed from each projection's own dims so the equal-budget
            // tables stay honest if q/k/gate shapes ever diverge.
            let rl = cfg.lora_rank;
            let mut total = 0;
            for p in ["q", "k", "gate"] {
                let (m, n) = cfg.weight_dims(p)?;
                total += rl * (m + n);
            }
            total
        }
    };
    Ok(mids * per_layer)
}

/// Initialize an adapter store for the middle layers.
///
/// * `Du` returns an empty store — ΔU tensors already live in the cured
///   student store (created at compression time).
/// * `CurLora` needs the *dense* teacher weights plus calibration norms to
///   do its inverted-importance sampling.
pub fn init_adapters(
    adapter: Adapter,
    cfg: &ModelConfig,
    teacher: &TensorStore,
    calib: &Calibration,
    rng: &mut Rng,
) -> Result<TensorStore> {
    let mut store = TensorStore::new();
    store.meta.insert("adapter".into(), adapter.label().into());
    let mids = cfg.middle_layers();
    match adapter {
        Adapter::Du => {}
        Adapter::Lora => {
            let rl = cfg.lora_rank;
            for &l in &mids {
                for proj in ["q", "k", "gate"] {
                    let (m, n) = cfg.weight_dims(proj)?;
                    store.insert(
                        format!("L{l}.lora_a_{proj}"),
                        Tensor::from_f32(&[m, rl], rng.normal_vec(m * rl, 0.02)),
                    );
                    store.insert(format!("L{l}.lora_b_{proj}"), Tensor::zeros(&[rl, n]));
                }
            }
        }
        Adapter::Mora => {
            let rm = cfg.mora_rank;
            for &l in &mids {
                for proj in ["q", "k", "gate"] {
                    store.insert(format!("L{l}.mora_m_{proj}"), Tensor::zeros(&[rm, rm]));
                }
            }
        }
        Adapter::CurLora => {
            let rc = cfg.default_rank;
            for &l in &mids {
                for proj in ["q", "k", "gate"] {
                    let w = Mat::from_tensor(teacher.get(&format!("L{l}.w_{proj}"))?)?;
                    let xnorm = calib.xnorm(l, proj)?;
                    let (rows, cols) = select_inverted(&w, xnorm, rc);
                    store.insert(format!("L{l}.cl_c_{proj}"), w.select_cols(&cols).to_tensor());
                    store.insert(format!("L{l}.cl_u_{proj}"), Tensor::zeros(&[rc, rc]));
                    store.insert(format!("L{l}.cl_r_{proj}"), w.select_rows(&rows).to_tensor());
                }
            }
        }
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"configs":{"t":{"vocab":64,"d_model":16,"n_layers":4,"n_heads":2,
            "d_inter":32,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    fn calib(cfg: &ModelConfig) -> Calibration {
        Calibration {
            attn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
            ffn_norms: vec![vec![1.0; cfg.d_model]; cfg.n_layers],
            angular: vec![0.0; cfg.n_layers],
            n_examples: 1,
        }
    }

    #[test]
    fn budgets_are_comparable() {
        let c = cfg();
        let du = trainable_params(Adapter::Du, &c).unwrap();
        let mora = trainable_params(Adapter::Mora, &c).unwrap();
        let curlora = trainable_params(Adapter::CurLora, &c).unwrap();
        // du == mora == curlora by construction.
        assert_eq!(du, mora);
        assert_eq!(du, curlora);
        // Exact closed forms: 3 r² per middle layer for the square
        // families; Σ rl·(m+n) over q/k/gate for LoRA.
        let mids = c.middle_layers().len();
        assert_eq!(du, mids * 3 * c.default_rank * c.default_rank);
        let lora = trainable_params(Adapter::Lora, &c).unwrap();
        let (d, di) = (c.d_model, c.d_inter);
        assert_eq!(
            lora,
            mids * (c.lora_rank * (d + d) * 2 + c.lora_rank * (d + di)),
            "LoRA budget must be Σ rl·(m+n) over q, k and gate"
        );
        // LoRA at its minimum rank is within a small factor.
        assert!(lora < du * 4, "lora={lora} du={du}");
    }

    #[test]
    fn lora_init_shapes() {
        let c = cfg();
        let mut rng = Rng::new(1, 0);
        let teacher = c.init_dense(&mut rng);
        let s = init_adapters(Adapter::Lora, &c, &teacher, &calib(&c), &mut rng).unwrap();
        let a = s.get("L1.lora_a_q").unwrap();
        assert_eq!(a.shape, vec![16, 1]);
        let b = s.get("L1.lora_b_gate").unwrap();
        assert_eq!(b.shape, vec![1, 32]);
        // B starts at zero (LoRA's delta is initially inert).
        assert!(b.f32s().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn curlora_uses_real_weight_slices() {
        let c = cfg();
        let mut rng = Rng::new(2, 0);
        let teacher = c.init_dense(&mut rng);
        let s = init_adapters(Adapter::CurLora, &c, &teacher, &calib(&c), &mut rng).unwrap();
        let cl_c = s.get("L1.cl_c_q").unwrap();
        assert_eq!(cl_c.shape, vec![16, 4]);
        // U starts at zero → adapter contributes nothing initially.
        let u = s.get("L1.cl_u_q").unwrap();
        assert!(u.f32s().unwrap().iter().all(|&x| x == 0.0));
        // Every column of cl_c is an actual column of the dense weight.
        let w = Mat::from_tensor(teacher.get("L1.w_q").unwrap()).unwrap();
        let cm = Mat::from_tensor(cl_c).unwrap();
        for j in 0..4 {
            let col = cm.col(j);
            let found = (0..w.cols).any(|wc| {
                let wcol = w.col(wc);
                wcol.iter().zip(&col).all(|(a, b)| (a - b).abs() < 1e-6)
            });
            assert!(found, "cl_c column {j} not a column of W");
        }
    }

    #[test]
    fn du_adapter_is_empty_store() {
        let c = cfg();
        let mut rng = Rng::new(3, 0);
        let teacher = c.init_dense(&mut rng);
        let s = init_adapters(Adapter::Du, &c, &teacher, &calib(&c), &mut rng).unwrap();
        assert!(s.is_empty());
    }

    #[test]
    fn adapter_parse() {
        for a in Adapter::ALL {
            assert_eq!(Adapter::parse(a.tag()).unwrap(), a);
        }
        assert!(Adapter::parse("nah").is_err());
    }
}

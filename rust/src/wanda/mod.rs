//! WANDA importance and the row/column selector ablation (paper §4.2,
//! Appendix D.2 / Table 5 / Figure 12).
//!
//! Five ways to pick the rows and columns that become CUR's R and C:
//!
//! * `Curing`   — WANDA importance matrix, then DEIM over its SVD (ours);
//! * `WandaOnly`— top row/column ℓ2-norms of the WANDA matrix directly;
//! * `DeimOnly` — DEIM over the SVD of the raw weight (no activations);
//! * `WeightMag`— top row/column ℓ2-norms of the raw weight;
//! * `Random`   — uniform random distinct indices.

use crate::cur::{cur_from_indices, deim, CurFactors};
use crate::linalg::{jacobi_svd, rand_svd, Mat};
use crate::util::stats::{nan_last_asc, nan_last_desc};
use crate::util::Rng;
use anyhow::{ensure, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selector {
    Curing,
    WandaOnly,
    DeimOnly,
    WeightMag,
    Random,
}

impl Selector {
    pub const ALL: [Selector; 5] = [
        Selector::Curing,
        Selector::WandaOnly,
        Selector::DeimOnly,
        Selector::WeightMag,
        Selector::Random,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Selector::Curing => "CURing",
            Selector::WandaOnly => "WANDA",
            Selector::DeimOnly => "DEIM",
            Selector::WeightMag => "Weight",
            Selector::Random => "Random",
        }
    }

    pub fn parse(s: &str) -> Result<Selector> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "curing" => Selector::Curing,
            "wanda" => Selector::WandaOnly,
            "deim" => Selector::DeimOnly,
            "weight" => Selector::WeightMag,
            "random" => Selector::Random,
            other => anyhow::bail!("unknown selector '{other}'"),
        })
    }
}

/// WANDA information matrix `S[i,j] = |W[i,j]| * xnorm[i]` where
/// `xnorm[i]` is the calibration ℓ2-norm of input feature i (paper
/// Fig. 2a). Rust-side reference of the L1 `wanda_score` kernel; the
/// kernel runs on-device during calibration, this one feeds the host-side
/// SVD at compression time.
pub fn importance_matrix(w: &Mat, xnorm: &[f64]) -> Mat {
    assert_eq!(w.rows, xnorm.len(), "xnorm length must match input dim");
    let mut s = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let scale = xnorm[i];
        for j in 0..w.cols {
            s[(i, j)] = w[(i, j)].abs() * scale;
        }
    }
    s
}

/// Pick `rank` row indices and `rank` column indices of `w`.
pub fn select_indices(
    selector: Selector,
    w: &Mat,
    xnorm: &[f64],
    rank: usize,
    rng: &mut Rng,
) -> Result<(Vec<usize>, Vec<usize>)> {
    ensure!(rank >= 1 && rank <= w.rows.min(w.cols), "rank {rank} out of range");
    match selector {
        Selector::Curing => {
            let s = importance_matrix(w, xnorm);
            deim_indices(&s, rank, rng)
        }
        Selector::DeimOnly => deim_indices(w, rank, rng),
        Selector::WandaOnly => {
            let s = importance_matrix(w, xnorm);
            Ok((top_row_norms(&s, rank), top_col_norms(&s, rank)))
        }
        Selector::WeightMag => Ok((top_row_norms(w, rank), top_col_norms(w, rank))),
        Selector::Random => {
            Ok((rng.sample_distinct(w.rows, rank), rng.sample_distinct(w.cols, rank)))
        }
    }
}

fn deim_indices(s: &Mat, rank: usize, rng: &mut Rng) -> Result<(Vec<usize>, Vec<usize>)> {
    let min_dim = s.rows.min(s.cols);
    let svd = if min_dim <= 96 { jacobi_svd(s) } else { rand_svd(s, rank, 8, 2, rng) };
    let idx: Vec<usize> = (0..rank).collect();
    let p_vecs = svd.u.select_cols(&idx);
    let q_vecs = svd.v.select_cols(&idx);
    Ok((deim(&p_vecs)?, deim(&q_vecs)?))
}

fn top_row_norms(s: &Mat, k: usize) -> Vec<usize> {
    let norms: Vec<f64> =
        (0..s.rows).map(|i| s.row(i).iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    top_k(&norms, k)
}

fn top_col_norms(s: &Mat, k: usize) -> Vec<usize> {
    let mut norms = vec![0.0f64; s.cols];
    for i in 0..s.rows {
        for (j, x) in s.row(i).iter().enumerate() {
            norms[j] += x * x;
        }
    }
    for n in &mut norms {
        *n = n.sqrt();
    }
    top_k(&norms, k)
}

/// NaN-proofing (`util::stats::nan_last_*` keys): degenerate
/// calibration (all-zero activations against zero weight rows) can push
/// 0·∞ products through the importance math, and the seed's
/// `partial_cmp().unwrap()` on the resulting NaN panicked
/// mid-compression. NaN scores sort as "least preferred" in both
/// directions — they carry no ordering information and must never beat
/// a finite score.
fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| nan_last_desc(scores[b]).total_cmp(&nan_last_desc(scores[a])));
    idx.truncate(k);
    idx
}

/// Inverted selection for CURLoRA (Fawi 2024): sample the *least*
/// important rows/columns so the adapter's implicit regularization
/// protects dominant features.
pub fn select_inverted(w: &Mat, xnorm: &[f64], rank: usize) -> (Vec<usize>, Vec<usize>) {
    let s = importance_matrix(w, xnorm);
    let rows = {
        let norms: Vec<f64> =
            (0..s.rows).map(|i| s.row(i).iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
        bottom_k(&norms, rank)
    };
    let cols = {
        let mut norms = vec![0.0f64; s.cols];
        for i in 0..s.rows {
            for (j, x) in s.row(i).iter().enumerate() {
                norms[j] += x * x;
            }
        }
        bottom_k(&norms, rank)
    };
    (rows, cols)
}

fn bottom_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    // Ascending by score, NaN last (a NaN row is not "least important" —
    // it is unranked, and must not crowd out real low-importance picks).
    idx.sort_by(|&a, &b| nan_last_asc(scores[a]).total_cmp(&nan_last_asc(scores[b])));
    idx.truncate(k);
    idx
}

/// Factorize with a named selector: the Table 5 workhorse.
pub fn cur_with_selector(
    selector: Selector,
    w: &Mat,
    xnorm: &[f64],
    rank: usize,
    rng: &mut Rng,
) -> Result<CurFactors> {
    let (rows, cols) = select_indices(selector, w, xnorm, rank, rng)?;
    Ok(cur_from_indices(w, &rows, &cols))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(m: usize, n: usize, seed: u64) -> (Mat, Vec<f64>, Rng) {
        let mut rng = Rng::new(seed, 0);
        let w = Mat::random_normal(m, n, &mut rng);
        let xnorm: Vec<f64> = (0..m).map(|_| rng.f64() + 0.1).collect();
        (w, xnorm, rng)
    }

    #[test]
    fn importance_matches_definition() {
        let (w, xnorm, _) = setup(6, 5, 1);
        let s = importance_matrix(&w, &xnorm);
        for i in 0..6 {
            for j in 0..5 {
                assert!((s[(i, j)] - w[(i, j)].abs() * xnorm[i]).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn all_selectors_return_valid_indices() {
        let (w, xnorm, mut rng) = setup(30, 20, 2);
        for sel in Selector::ALL {
            let (rows, cols) = select_indices(sel, &w, &xnorm, 6, &mut rng).unwrap();
            for set in [&rows, &cols] {
                assert_eq!(set.len(), 6, "{sel:?}");
                let mut s = (*set).clone();
                s.sort_unstable();
                s.dedup();
                assert_eq!(s.len(), 6, "{sel:?} duplicates");
            }
            assert!(rows.iter().all(|&i| i < 30));
            assert!(cols.iter().all(|&j| j < 20));
        }
    }

    #[test]
    fn curing_beats_random_on_structured_matrix() {
        // A matrix with dominant low-rank structure amplified by
        // activations: the informed selector must reconstruct better than
        // random (paper Table 5 ordering), averaged over trials.
        let mut errs = std::collections::HashMap::new();
        for trial in 0..6 {
            let mut rng = Rng::new(100 + trial, 0);
            let base = Mat::random_normal(40, 32, &mut rng);
            let mut w = base.clone();
            let u = Mat::random_normal(40, 4, &mut rng);
            let v = Mat::random_normal(4, 32, &mut rng);
            let dom = u.matmul(&v);
            for i in 0..w.data.len() {
                w.data[i] = 0.3 * w.data[i] + dom.data[i];
            }
            let xnorm: Vec<f64> = (0..40).map(|_| rng.f64() * 2.0 + 0.1).collect();
            for sel in [Selector::Curing, Selector::Random] {
                let f = cur_with_selector(sel, &w, &xnorm, 6, &mut rng).unwrap();
                let e = w.sub(&f.reconstruct()).fro_norm();
                *errs.entry(sel.label()).or_insert(0.0) += e;
            }
        }
        assert!(
            errs["CURing"] < errs["Random"],
            "CURing {} !< Random {}",
            errs["CURing"],
            errs["Random"]
        );
    }

    #[test]
    fn inverted_selection_picks_low_importance() {
        let (w, mut xnorm, _) = setup(20, 16, 3);
        for i in 0..4 {
            xnorm[i] = 100.0;
        }
        let (rows, _cols) = select_inverted(&w, &xnorm, 8);
        assert!(rows.iter().all(|&i| i >= 4), "inverted selection picked a dominant row: {rows:?}");
    }

    #[test]
    fn nan_scores_do_not_panic_or_win() {
        // Degenerate calibration can produce NaN importance scores
        // (0·∞ upstream); sorting must not panic, and the NaN entry
        // must lose to every finite candidate in both directions.
        let scores = vec![3.0, f64::NAN, 1.0, 2.0, 0.5];
        let top = top_k(&scores, 3);
        assert_eq!(top, vec![0, 3, 2], "top_k must prefer finite scores over NaN");
        let bottom = bottom_k(&scores, 3);
        assert_eq!(bottom, vec![4, 2, 3], "bottom_k must prefer finite scores over NaN");
        // All-NaN input still returns k valid, distinct indices.
        let all_nan = vec![f64::NAN; 4];
        let t = top_k(&all_nan, 2);
        assert_eq!(t.len(), 2);
        assert_ne!(t[0], t[1]);
        // End-to-end: an inverted selection over a weight matrix whose
        // importance goes NaN must error-free return distinct indices.
        let (w, mut xnorm, _) = setup(12, 10, 9);
        xnorm[3] = f64::NAN;
        let (rows, cols) = select_inverted(&w, &xnorm, 4);
        assert_eq!(rows.len(), 4);
        assert_eq!(cols.len(), 4);
        assert!(!rows.contains(&3), "the NaN-scored row must not be selected");
    }

    #[test]
    fn selector_parse_roundtrip() {
        for sel in Selector::ALL {
            let parsed = Selector::parse(&sel.label().to_ascii_lowercase()).unwrap();
            assert_eq!(parsed, sel);
        }
        assert!(Selector::parse("bogus").is_err());
    }
}

//! Model configuration, parameter naming, initialization and size
//! accounting for the Llama-mini family.
//!
//! The configuration is parsed from `artifacts/manifest.json` (written by
//! the Python AOT step), so Rust and JAX can never disagree about shapes.
//! Parameter names follow the canonical scheme the artifacts use:
//! `emb`, `ln_f`, `L{l}.ln1`, `L{l}.w_q`, ..., `L{l}.c_q`, `L{l}.u_q`,
//! `L{l}.du_q`, `L{l}.r_q`, ...

use crate::tensor::{Tensor, TensorStore};
use crate::util::{Json, Rng};
use anyhow::{anyhow, Result};

/// Weight-combination ablation of paper Appendix C.1.
pub const COMBOS: &[(&str, &[&str])] = &[
    ("all", &["q", "k", "gate"]),
    ("gate", &["gate"]),
    ("qk", &["q", "k"]),
    ("qg", &["q", "gate"]),
    ("kg", &["k", "gate"]),
];

pub fn combo_targets(combo: &str) -> Result<&'static [&'static str]> {
    COMBOS
        .iter()
        .find(|(name, _)| *name == combo)
        .map(|(_, t)| *t)
        .ok_or_else(|| anyhow!("unknown combo '{combo}'"))
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_inter: usize,
    pub seq: usize,
    pub batch: usize,
    pub ranks: Vec<usize>,
    pub default_rank: usize,
    pub lora_rank: usize,
    pub mora_rank: usize,
    pub total_params: usize,
}

impl ModelConfig {
    pub fn from_manifest(manifest: &Json, name: &str) -> Result<ModelConfig> {
        let c = manifest
            .at(&["configs", name])
            .ok_or_else(|| anyhow!("config '{name}' not in manifest"))?;
        let get = |k: &str| -> Result<usize> {
            c.at(&[k]).and_then(|v| v.as_usize()).ok_or_else(|| anyhow!("config missing {k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_inter: get("d_inter")?,
            seq: get("seq")?,
            batch: get("batch")?,
            ranks: c
                .at(&["ranks"])
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            default_rank: get("default_rank")?,
            lora_rank: get("lora_rank")?,
            mora_rank: get("mora_rank")?,
            total_params: get("total_params")?,
        })
    }

    /// Layers eligible for curing: all but first and last (paper §4.1).
    pub fn middle_layers(&self) -> Vec<usize> {
        (1..self.n_layers - 1).collect()
    }

    /// Dense weight dims of one projection: (m_in, n_out). Unknown
    /// projection names (e.g. from a user-supplied combo) are an error,
    /// not a panic.
    pub fn weight_dims(&self, proj: &str) -> Result<(usize, usize)> {
        Ok(match proj {
            "q" | "k" | "v" | "o" => (self.d_model, self.d_model),
            "gate" | "up" => (self.d_model, self.d_inter),
            "down" => (self.d_inter, self.d_model),
            other => return Err(anyhow!("unknown projection '{other}'")),
        })
    }

    /// Paper Eq. 2: rank rule — largest power of two under the parameter
    /// break-even point, clamped by r_max.
    pub fn rank_rule(&self, m: usize, n: usize, r_max: usize) -> usize {
        crate::cur::rank_rule(m, n, r_max)
    }

    /// Dense parameter count of one layer.
    pub fn params_per_layer(&self) -> usize {
        4 * self.d_model * self.d_model + 3 * self.d_model * self.d_inter + 2 * self.d_model
    }

    /// Parameters of a CUR factorization of projection `proj` at `rank`.
    pub fn cur_params(&self, proj: &str, rank: usize) -> Result<usize> {
        let (m, n) = self.weight_dims(proj)?;
        Ok(m * rank + rank * rank + rank * n)
    }

    /// Bytes saved (f32) by curing one layer with `combo` at `rank`.
    pub fn bytes_saved_per_layer(&self, combo: &str, rank: usize) -> Result<usize> {
        let mut saved = 0usize;
        for proj in combo_targets(combo)? {
            let (m, n) = self.weight_dims(proj)?;
            let dense = m * n;
            let cur = self.cur_params(proj, rank)?;
            saved += dense.saturating_sub(cur) * 4;
        }
        Ok(saved)
    }

    pub fn dense_layer_param_names(&self, l: usize) -> Vec<String> {
        ["ln1", "w_q", "w_k", "w_v", "w_o", "ln2", "w_gate", "w_up", "w_down"]
            .iter()
            .map(|s| format!("L{l}.{s}"))
            .collect()
    }

    /// All dense model parameter names in artifact (manifest) order.
    pub fn dense_param_names(&self) -> Vec<String> {
        let mut names = vec!["emb".to_string()];
        for l in 0..self.n_layers {
            names.extend(self.dense_layer_param_names(l));
        }
        names.push("ln_f".to_string());
        names
    }

    pub fn param_shape(&self, name: &str) -> Result<Vec<usize>> {
        let (d, di, v) = (self.d_model, self.d_inter, self.vocab);
        let suffix = name.split('.').next_back().unwrap_or(name);
        Ok(match suffix {
            "emb" => vec![v, d],
            "ln_f" | "ln1" | "ln2" => vec![d],
            "w_q" | "w_k" | "w_v" | "w_o" => vec![d, d],
            "w_gate" | "w_up" => vec![d, di],
            "w_down" => vec![di, d],
            other => return Err(anyhow!("no static shape for param '{other}'")),
        })
    }

    /// Initialize a dense model (GPT-2-style scaled normal init).
    pub fn init_dense(&self, rng: &mut Rng) -> TensorStore {
        let mut store = TensorStore::new();
        let std = 0.02f32;
        let resid_std = std / (2.0 * self.n_layers as f32).sqrt();
        let (d, di, v) = (self.d_model, self.d_inter, self.vocab);
        store.insert("emb", Tensor::from_f32(&[v, d], rng.normal_vec(v * d, std)));
        for l in 0..self.n_layers {
            store.insert(format!("L{l}.ln1"), Tensor::from_f32(&[d], vec![1.0; d]));
            store.insert(format!("L{l}.ln2"), Tensor::from_f32(&[d], vec![1.0; d]));
            for w in ["w_q", "w_k", "w_v"] {
                store.insert(format!("L{l}.{w}"), Tensor::from_f32(&[d, d], rng.normal_vec(d * d, std)));
            }
            // Residual-write projections get the depth-scaled init.
            store.insert(format!("L{l}.w_o"), Tensor::from_f32(&[d, d], rng.normal_vec(d * d, resid_std)));
            store.insert(format!("L{l}.w_gate"), Tensor::from_f32(&[d, di], rng.normal_vec(d * di, std)));
            store.insert(format!("L{l}.w_up"), Tensor::from_f32(&[d, di], rng.normal_vec(d * di, std)));
            store.insert(format!("L{l}.w_down"), Tensor::from_f32(&[di, d], rng.normal_vec(di * d, resid_std)));
        }
        store.insert("ln_f", Tensor::from_f32(&[d], vec![1.0; d]));
        store.meta.insert("config".into(), self.name.clone());
        store
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest() -> Json {
        Json::parse(
            r#"{"configs": {"tiny": {"vocab":512,"d_model":256,"n_layers":8,
            "n_heads":8,"d_inter":704,"seq":64,"batch":8,"ranks":[8,16,32],
            "default_rank":16,"lora_rank":1,"mora_rank":16,
            "total_params":6600000}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_config() {
        let cfg = ModelConfig::from_manifest(&tiny_manifest(), "tiny").unwrap();
        assert_eq!(cfg.d_model, 256);
        assert_eq!(cfg.middle_layers(), vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(cfg.ranks, vec![8, 16, 32]);
    }

    #[test]
    fn init_has_all_dense_params() {
        let cfg = ModelConfig::from_manifest(&tiny_manifest(), "tiny").unwrap();
        let mut rng = Rng::new(0, 0);
        let store = cfg.init_dense(&mut rng);
        for name in cfg.dense_param_names() {
            assert!(store.contains(&name), "missing {name}");
        }
        // Param count matches the analytic formula.
        let expect = cfg.vocab * cfg.d_model
            + cfg.n_layers * cfg.params_per_layer()
            + cfg.d_model;
        assert_eq!(store.total_params(), expect);
    }

    #[test]
    fn bytes_saved_positive_and_ordered() {
        let cfg = ModelConfig::from_manifest(&tiny_manifest(), "tiny").unwrap();
        let all = cfg.bytes_saved_per_layer("all", 16).unwrap();
        let gate = cfg.bytes_saved_per_layer("gate", 16).unwrap();
        let qk = cfg.bytes_saved_per_layer("qk", 16).unwrap();
        assert!(all > gate && gate > qk, "all={all} gate={gate} qk={qk}");
        // Larger rank saves less.
        let all32 = cfg.bytes_saved_per_layer("all", 32).unwrap();
        assert!(all32 < all);
    }

    #[test]
    fn combo_lookup() {
        assert!(combo_targets("all").is_ok());
        assert!(combo_targets("nope").is_err());
    }

    #[test]
    fn unknown_projection_and_param_are_errors() {
        let cfg = ModelConfig::from_manifest(&tiny_manifest(), "tiny").unwrap();
        assert!(cfg.weight_dims("sideways").is_err());
        assert!(cfg.cur_params("sideways", 8).is_err());
        assert!(cfg.param_shape("L0.w_mystery").is_err());
        assert_eq!(cfg.weight_dims("down").unwrap(), (704, 256));
        assert_eq!(cfg.param_shape("L2.w_gate").unwrap(), vec![256, 704]);
        assert_eq!(cfg.param_shape("emb").unwrap(), vec![512, 256]);
        assert_eq!(cfg.param_shape("ln_f").unwrap(), vec![256]);
    }
}

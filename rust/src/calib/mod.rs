//! Calibration (paper §4.1–4.2): one pass over calibration data collects
//! *both* signals CURing needs, exactly as the paper does concurrently:
//!
//! * WANDA activation norms — per-layer ℓ2 norms of each input feature of
//!   the attention input (for W^Q/W^K) and FFN input (for W^Gate);
//! * angular distances — `d(h_{n-1}, h_n) = arccos(·)/π` between
//!   consecutive layers' last-token hidden states, averaged over examples.

use crate::data::{Corpus, Vocab};
use crate::pipeline::Pipeline;
use crate::tensor::{Tensor, TensorStore};
use crate::util::{Json, JsonObj};
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct Calibration {
    /// Per layer: sqrt of accumulated Σx² per attention-input feature.
    pub attn_norms: Vec<Vec<f64>>,
    /// Per layer: same for the FFN input.
    pub ffn_norms: Vec<Vec<f64>>,
    /// `angular[l]` = mean angular distance between layer l's output and
    /// its input representation (layer l-1's output; l=0 compares to the
    /// embedding output).
    pub angular: Vec<f64>,
    pub n_examples: usize,
}

impl Calibration {
    /// WANDA xnorm vector for a projection of layer `l`. Only the paper's
    /// curable projections carry calibration norms; anything else (or a
    /// layer index beyond the calibrated depth) is a caller error surfaced
    /// as a `Result`, not a panic.
    pub fn xnorm(&self, l: usize, proj: &str) -> Result<&[f64]> {
        let norms = match proj {
            "q" | "k" => &self.attn_norms,
            "gate" => &self.ffn_norms,
            other => anyhow::bail!("no calibration norms for projection '{other}'"),
        };
        norms
            .get(l)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow::anyhow!("no calibration norms for layer {l}"))
    }

    pub fn to_json(&self) -> Json {
        let vecf = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        let mut o = JsonObj::new();
        o.insert("n_examples", Json::Num(self.n_examples as f64));
        o.insert("angular", vecf(&self.angular));
        o.insert("attn_norms", Json::Arr(self.attn_norms.iter().map(|v| vecf(v)).collect()));
        o.insert("ffn_norms", Json::Arr(self.ffn_norms.iter().map(|v| vecf(v)).collect()));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<Calibration> {
        let vecf = |j: &Json| -> Vec<f64> {
            j.as_arr().unwrap_or(&[]).iter().filter_map(|x| x.as_f64()).collect()
        };
        let mat = |j: Option<&Json>| -> Vec<Vec<f64>> {
            j.and_then(|x| x.as_arr()).unwrap_or(&[]).iter().map(vecf).collect()
        };
        Ok(Calibration {
            n_examples: j.at(&["n_examples"]).and_then(|x| x.as_usize()).unwrap_or(0),
            angular: j.at(&["angular"]).map(vecf).unwrap_or_default(),
            attn_norms: mat(j.at(&["attn_norms"])),
            ffn_norms: mat(j.at(&["ffn_norms"])),
        })
    }
}

/// Angular distance between two vectors: `(1/π) arccos(cos_sim)`.
pub fn angular_distance(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    let denom = (na.sqrt() * nb.sqrt()).max(1e-30);
    let cos = (dot / denom).clamp(-1.0, 1.0);
    cos.acos() / std::f64::consts::PI
}

/// Extract the last-token hidden state of each batch row: (b, s, d) -> b vectors.
fn last_token_rows(t: &Tensor) -> Result<Vec<&[f32]>> {
    let (b, s, d) = (t.shape[0], t.shape[1], t.shape[2]);
    let data = t.f32s()?;
    Ok((0..b).map(|i| &data[(i * s + s - 1) * d..(i * s + s) * d]).collect())
}

/// Run calibration over `n_examples` sequences drawn from `corpus`
/// (paper default: 128 C4 examples, batched).
pub fn calibrate(
    pipe: &Pipeline,
    store: &TensorStore,
    vocab: &Vocab,
    corpus: &mut Corpus,
    n_examples: usize,
) -> Result<Calibration> {
    let cfg = &pipe.cfg;
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let n_batches = n_examples.div_ceil(b).max(1);
    let mut attn_acc = vec![vec![0.0f64; d]; cfg.n_layers];
    let mut ffn_acc = vec![vec![0.0f64; d]; cfg.n_layers];
    let mut ang_acc = vec![0.0f64; cfg.n_layers];
    let mut count = 0usize;
    for _ in 0..n_batches {
        let (toks, _) = corpus.batch(vocab, b, s);
        let tokens = Tensor::from_i32(&[b, s], toks);
        let fwd = pipe.forward_calib(store, &tokens)?;
        for l in 0..cfg.n_layers {
            for (acc, &x) in attn_acc[l].iter_mut().zip(fwd.attn_sumsq[l].f32s()?) {
                *acc += x as f64;
            }
            for (acc, &x) in ffn_acc[l].iter_mut().zip(fwd.ffn_sumsq[l].f32s()?) {
                *acc += x as f64;
            }
            let prev = if l == 0 { &fwd.embed_out } else { &fwd.layer_outputs[l - 1] };
            let prev_rows = last_token_rows(prev)?;
            let cur_rows = last_token_rows(&fwd.layer_outputs[l])?;
            for (pa, pb) in prev_rows.iter().zip(&cur_rows) {
                ang_acc[l] += angular_distance(pa, pb);
            }
        }
        count += b;
    }
    Ok(Calibration {
        attn_norms: attn_acc
            .into_iter()
            .map(|v| v.into_iter().map(|x| x.sqrt()).collect())
            .collect(),
        ffn_norms: ffn_acc
            .into_iter()
            .map(|v| v.into_iter().map(|x| x.sqrt()).collect())
            .collect(),
        angular: ang_acc.into_iter().map(|x| x / count as f64).collect(),
        n_examples: count,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn angular_distance_basics() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((angular_distance(&a, &a) - 0.0).abs() < 1e-7);
        assert!((angular_distance(&a, &b) - 0.5).abs() < 1e-7);
        let c = [-1.0f32, 0.0];
        assert!((angular_distance(&a, &c) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn angular_distance_scale_invariant() {
        let a = [0.3f32, -1.2, 2.0];
        let b = [1.0f32, 0.4, -0.5];
        let scaled: Vec<f32> = b.iter().map(|x| x * 7.5).collect();
        assert!((angular_distance(&a, &b) - angular_distance(&a, &scaled)).abs() < 1e-6);
    }

    #[test]
    fn calibration_json_roundtrip() {
        let c = Calibration {
            attn_norms: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            ffn_norms: vec![vec![0.5, 0.25], vec![0.1, 0.2]],
            angular: vec![0.1, 0.2],
            n_examples: 128,
        };
        let j = c.to_json();
        let c2 = Calibration::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c2.n_examples, 128);
        assert_eq!(c2.angular, c.angular);
        assert_eq!(c2.attn_norms, c.attn_norms);
        assert_eq!(c2.ffn_norms, c.ffn_norms);
    }

    #[test]
    fn xnorm_routing() {
        let c = Calibration {
            attn_norms: vec![vec![1.0]],
            ffn_norms: vec![vec![2.0]],
            angular: vec![0.0],
            n_examples: 1,
        };
        assert_eq!(c.xnorm(0, "q").unwrap()[0], 1.0);
        assert_eq!(c.xnorm(0, "k").unwrap()[0], 1.0);
        assert_eq!(c.xnorm(0, "gate").unwrap()[0], 2.0);
        // Unknown projections and out-of-range layers error gracefully.
        assert!(c.xnorm(0, "down").is_err());
        assert!(c.xnorm(5, "q").is_err());
    }
}

//! DEIM-CUR decomposition — the paper's core algorithm (§3, §4.2).
//!
//! Pipeline per weight matrix:
//!   1. an importance matrix `S` (WANDA: `|W| ⊙ activation norms`, or an
//!      ablation variant from [`crate::wanda`]) is factorized by a
//!      truncated SVD `S ≈ P Σ Q^T`;
//!   2. DEIM picks exactly `r` row indices from `P` and `r` column
//!      indices from `Q` (Sorensen & Embree 2016);
//!   3. `C = W[:, q]`, `R = W[p, :]` are *actual* columns/rows of `W`,
//!      and `U = C^+ W R^+` (Frobenius-optimal link, Stewart 1999).
//!
//! Also implements the paper's Eq. 2 rank rule and the Theorem 3.1 error
//! constants `η_p = ‖(P[p,:])^{-1}‖₂`, `η_q = ‖(Q[:,q])^{-1}‖₂`.

use crate::linalg::{jacobi_svd, lu_solve, pinv, rand_svd, Mat, Svd};
use crate::util::Rng;
use anyhow::{ensure, Result};

/// Paper Eq. 2: the largest power of two below the parameter break-even
/// point `(sqrt(m² + 6mn + n²) − (m + n)) / 2`, clamped by `r_max`.
/// Powers of two keep MXU/accelerator tiles full.
pub fn rank_rule(m: usize, n: usize, r_max: usize) -> usize {
    let (mf, nf) = (m as f64, n as f64);
    let breakeven = ((mf * mf + 6.0 * mf * nf + nf * nf).sqrt() - (mf + nf)) / 2.0;
    if breakeven < 1.0 {
        return 1.min(r_max);
    }
    let pow = breakeven.log2().floor() as u32;
    let r = 1usize << pow;
    r.min(r_max)
}

/// DEIM index selection from a matrix of leading singular vectors
/// (rows = candidates, cols = vectors, importance-ordered).
///
/// Greedy interpolation: pick the largest entry of the first vector, then
/// for each next vector subtract the interpolation through the already-
/// picked rows and pick the largest residual. Returns exactly
/// `v.cols` distinct indices.
pub fn deim(v: &Mat) -> Result<Vec<usize>> {
    let (n, r) = (v.rows, v.cols);
    ensure!(r >= 1 && r <= n, "deim: need 1 <= r <= n (r={r}, n={n})");
    let mut picked: Vec<usize> = Vec::with_capacity(r);
    // First index: argmax |v[:, 0]|.
    let c0 = v.col(0);
    picked.push(argmax_abs(&c0));
    for j in 1..r {
        // Solve V[p, :j] c = v[p, j].
        let mut a = Mat::zeros(j, j);
        let mut b = vec![0.0; j];
        for (ii, &pi) in picked.iter().enumerate() {
            for jj in 0..j {
                a[(ii, jj)] = v[(pi, jj)];
            }
            b[ii] = v[(pi, j)];
        }
        let c = lu_solve(&a, &b)?;
        // Residual: v[:, j] - V[:, :j] c.
        let mut res = v.col(j);
        for (i, r_i) in res.iter_mut().enumerate() {
            for (jj, &cj) in c.iter().enumerate() {
                *r_i -= v[(i, jj)] * cj;
            }
        }
        // Zero already-picked entries (they are exactly interpolated, but
        // guard against float noise re-picking them).
        for &pi in &picked {
            res[pi] = 0.0;
        }
        picked.push(argmax_abs(&res));
    }
    Ok(picked)
}

fn argmax_abs(xs: &[f64]) -> usize {
    let mut best = 0;
    let mut bv = -1.0;
    for (i, &x) in xs.iter().enumerate() {
        if x.abs() > bv {
            bv = x.abs();
            best = i;
        }
    }
    best
}

/// The CUR factors of one weight matrix, plus provenance.
#[derive(Debug, Clone)]
pub struct CurFactors {
    pub c: Mat,            // m x r (columns of W)
    pub u: Mat,            // r x r
    pub r: Mat,            // r x n (rows of W)
    pub row_idx: Vec<usize>, // p
    pub col_idx: Vec<usize>, // q
    /// σ_{r+1} of the *importance* matrix (first neglected singular
    /// value), as estimated by the truncated SVD.
    pub sigma_next: f64,
}

impl CurFactors {
    /// Reconstruct the dense approximation `C U R` (tests/analysis only —
    /// the deployed path never materializes this).
    pub fn reconstruct(&self) -> Mat {
        self.c.matmul(&self.u).matmul(&self.r)
    }

    pub fn rank(&self) -> usize {
        self.u.rows
    }

    pub fn param_count(&self) -> usize {
        self.c.rows * self.c.cols + self.u.rows * self.u.cols + self.r.rows * self.r.cols
    }
}

/// Build CUR factors of `w` from explicit row/column indices:
/// `C = W[:, q]`, `R = W[p, :]`, `U = C^+ W R^+`.
pub fn cur_from_indices(w: &Mat, rows: &[usize], cols: &[usize]) -> CurFactors {
    let c = w.select_cols(cols);
    let r = w.select_rows(rows);
    let u = pinv(&c).matmul(w).matmul(&pinv(&r));
    CurFactors {
        c,
        u,
        r,
        row_idx: rows.to_vec(),
        col_idx: cols.to_vec(),
        sigma_next: f64::NAN,
    }
}

/// Full DEIM-CUR of `w` guided by an `importance` matrix of the same
/// shape (pass `w.abs()`-like scores, e.g. WANDA). `rank` must satisfy
/// `rank <= min(m, n)`.
pub fn cur_decompose(
    w: &Mat,
    importance: &Mat,
    rank: usize,
    rng: &mut Rng,
) -> Result<CurFactors> {
    ensure!(
        importance.rows == w.rows && importance.cols == w.cols,
        "importance shape mismatch"
    );
    let min_dim = w.rows.min(w.cols);
    ensure!(rank >= 1 && rank <= min_dim, "rank {rank} out of range (min dim {min_dim})");
    // Truncated SVD of the importance matrix. Ask for one extra value to
    // report sigma_{r+1}.
    let want = (rank + 1).min(min_dim);
    let svd = svd_for_selection(importance, want, rng);
    let p_vecs = take_cols(&svd.u, rank);
    let q_vecs = take_cols(&svd.v, rank);
    let rows = deim(&p_vecs)?;
    let cols = deim(&q_vecs)?;
    let mut factors = cur_from_indices(w, &rows, &cols);
    factors.sigma_next = if svd.s.len() > rank { svd.s[rank] } else { 0.0 };
    Ok(factors)
}

/// Exact SVD for small problems, randomized for large ones.
fn svd_for_selection(s: &Mat, k: usize, rng: &mut Rng) -> Svd {
    let min_dim = s.rows.min(s.cols);
    if min_dim <= 96 {
        jacobi_svd(s)
    } else {
        rand_svd(s, k, 8, 2, rng)
    }
}

fn take_cols(m: &Mat, k: usize) -> Mat {
    let idx: Vec<usize> = (0..k).collect();
    m.select_cols(&idx)
}

/// Value-guided KV-cache position selection (PAPERS: *Value-Guided KV
/// Compression via Approximated CUR Decomposition*, arXiv:2509.15038):
/// pick the `keep` cached positions whose keys span the most
/// informative subspace, exactly the way the compression path picks
/// rows of a weight matrix — truncated SVD of an importance-weighted
/// matrix, then DEIM over the leading left singular vectors.
///
/// `keys` is the (n positions × d) cached post-RoPE key matrix of one
/// layer/slot lane; `weights` is one non-negative mass estimate per
/// position (the serving path uses ‖k_i‖·‖v_i‖ — the value norm bounds
/// position `i`'s contribution to the attention output, the key norm
/// its score leverage). Each key row is scaled by its weight before
/// factorization, so high-mass positions dominate the subspace DEIM
/// interpolates.
///
/// Deterministic (fixed internal seed on the randomized-SVD path).
/// Returns `keep` distinct indices into `0..n`, unsorted; when the
/// matrix cannot supply `keep` singular vectors (`keep > d`) the
/// remainder is filled greedily by descending weight.
pub fn select_kv_positions(keys: &Mat, weights: &[f64], keep: usize) -> Result<Vec<usize>> {
    let n = keys.rows;
    ensure!(weights.len() == n, "need one weight per cached position");
    ensure!(keep >= 1 && keep <= n, "keep {keep} out of range 1..={n}");
    if keep == n {
        return Ok((0..n).collect());
    }
    let mut s = Mat::zeros(n, keys.cols);
    for i in 0..n {
        ensure!(weights[i].is_finite() && weights[i] >= 0.0, "weight {i} must be finite >= 0");
        let w = weights[i].max(1e-12);
        for (dst, &src) in s.row_mut(i).iter_mut().zip(keys.row(i)) {
            *dst = src * w;
        }
    }
    let r = keep.min(keys.cols);
    let mut rng = Rng::new(0x5eed, 0);
    let svd = svd_for_selection(&s, r, &mut rng);
    let p_vecs = take_cols(&svd.u, r);
    let mut picked = deim(&p_vecs)?;
    if picked.len() < keep {
        let mut in_set = vec![false; n];
        for &i in &picked {
            in_set[i] = true;
        }
        let mut rest: Vec<usize> = (0..n).filter(|&i| !in_set[i]).collect();
        // Descending by weight, NaN last (the crate-wide NaN sort
        // policy): a row whose importance is undefined must never be
        // kept ahead of a finite one. Weights are clamped >= 1e-12
        // upstream, so this is defense-in-depth.
        use crate::util::stats::nan_last_desc;
        rest.sort_by(|&a, &b| nan_last_desc(weights[b]).total_cmp(&nan_last_desc(weights[a])));
        picked.extend(rest.into_iter().take(keep - picked.len()));
    }
    Ok(picked)
}

/// Theorem 3.1 error constants for DEIM selections:
/// `η_p = ‖(P[p, :])^{-1}‖₂ = 1/σ_min(P[p, :])` and likewise for q.
// curlint: allow(dead-pub) -- paper Theorem 3.1 error-bound API; exercised by the property tests, kept pub for error-analysis tooling
pub fn deim_error_constants(p_vecs: &Mat, rows: &[usize], q_vecs: &Mat, cols: &[usize]) -> (f64, f64) {
    let pp = p_vecs.select_rows(rows);
    let qq = q_vecs.select_rows(cols); // Q[:, q] rows of V matrix = entries V[q, :]
    let eta = |m: &Mat| -> f64 {
        let svd = jacobi_svd(m);
        let smin = svd.s.last().copied().unwrap_or(0.0);
        if smin <= 0.0 {
            f64::INFINITY
        } else {
            1.0 / smin
        }
    };
    (eta(&pp), eta(&qq))
}

/// Approximation error report for one factorization.
#[derive(Debug, Clone)]
// curlint: allow(dead-pub) -- paper error-bound API; reached through approx_error, kept pub for error-analysis tooling
pub struct CurError {
    pub fro: f64,
    pub spectral: f64,
    pub w_fro: f64,
    pub cur_fro: f64,
}

// curlint: allow(dead-pub) -- paper error-bound API; exercised by the factorization tests, kept pub for error-analysis tooling
pub fn approx_error(w: &Mat, f: &CurFactors, rng: &mut Rng) -> CurError {
    let rec = f.reconstruct();
    let diff = w.sub(&rec);
    CurError {
        fro: diff.fro_norm(),
        spectral: diff.spectral_norm(rng),
        w_fro: w.fro_norm(),
        cur_fro: rec.fro_norm(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_rule_paper_values() {
        // Llama3.1-8B W^Q: 4096x4096 -> breakeven 1696 -> 1024, clamped 256.
        assert_eq!(rank_rule(4096, 4096, 256), 256);
        assert_eq!(rank_rule(4096, 4096, 4096), 1024);
        // tiny attention 256x256 -> 64; gate 256x704 -> 128.
        assert_eq!(rank_rule(256, 256, 256), 64);
        assert_eq!(rank_rule(256, 704, 256), 128);
        // r_max clamps.
        assert_eq!(rank_rule(256, 256, 16), 16);
    }

    #[test]
    fn rank_rule_always_break_even() {
        // The CUR parameter count must beat dense whenever the rule fires.
        let mut rng = Rng::new(0, 0);
        for _ in 0..200 {
            let m = 8 + rng.below(600);
            let n = 8 + rng.below(600);
            let r = rank_rule(m, n, usize::MAX);
            if r >= 1 {
                assert!(
                    m * r + r * r + r * n <= m * n,
                    "rank rule violates break-even: m={m} n={n} r={r}"
                );
            }
        }
    }

    #[test]
    fn deim_distinct_in_range() {
        let mut rng = Rng::new(1, 0);
        for _ in 0..20 {
            let n = 10 + rng.below(80);
            let r = 1 + rng.below(9.min(n - 1));
            let a = Mat::random_normal(n, r, &mut rng);
            let idx = deim(&a).unwrap();
            assert_eq!(idx.len(), r);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r, "duplicate deim indices");
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn deim_picks_obvious_rows() {
        // Identity-like singular vectors: DEIM must pick the peaks.
        let mut v = Mat::zeros(6, 2);
        v[(3, 0)] = 1.0;
        v[(1, 1)] = 1.0;
        let idx = deim(&v).unwrap();
        assert_eq!(idx, vec![3, 1]);
    }

    #[test]
    fn cur_exact_on_low_rank() {
        // If rank(W) = r, DEIM-CUR at rank r is exact.
        let mut rng = Rng::new(2, 0);
        let b = Mat::random_normal(40, 4, &mut rng);
        let c = Mat::random_normal(4, 30, &mut rng);
        let w = b.matmul(&c);
        let f = cur_decompose(&w, &w, 4, &mut rng).unwrap();
        let err = f.reconstruct().sub(&w).fro_norm();
        assert!(err < 1e-8 * w.fro_norm(), "err={err}");
    }

    #[test]
    fn cur_error_bound_theorem() {
        // ||W - CUR||_2 <= (eta_p + eta_q) sigma_{r+1}, selection on W itself.
        let mut rng = Rng::new(3, 0);
        for trial in 0..5 {
            let w = Mat::random_normal(30, 24, &mut rng);
            let r = 6;
            let svd = jacobi_svd(&w);
            let p_vecs = take_cols(&svd.u, r);
            let q_vecs = take_cols(&svd.v, r);
            let rows = deim(&p_vecs).unwrap();
            let cols = deim(&q_vecs).unwrap();
            let f = cur_from_indices(&w, &rows, &cols);
            let (eta_p, eta_q) = deim_error_constants(&p_vecs, &rows, &q_vecs, &cols);
            let err2 = w.sub(&f.reconstruct()).spectral_norm(&mut rng);
            let bound = (eta_p + eta_q) * svd.s[r];
            assert!(
                err2 <= bound * 1.0001,
                "trial {trial}: spectral err {err2} > bound {bound}"
            );
        }
    }

    #[test]
    fn cur_uses_actual_rows_cols() {
        // Interpretability claim: C and R are verbatim slices of W.
        let mut rng = Rng::new(4, 0);
        let w = Mat::random_normal(20, 16, &mut rng);
        let f = cur_decompose(&w, &w, 5, &mut rng).unwrap();
        for (jj, &j) in f.col_idx.iter().enumerate() {
            for i in 0..w.rows {
                assert_eq!(f.c[(i, jj)], w[(i, j)]);
            }
        }
        for (ii, &i) in f.row_idx.iter().enumerate() {
            for j in 0..w.cols {
                assert_eq!(f.r[(ii, j)], w[(i, j)]);
            }
        }
    }

    #[test]
    fn cur_nonnegativity_preserved() {
        // If W >= 0, C and R are >= 0 (paper §3.2: property preservation).
        let mut rng = Rng::new(5, 0);
        let mut w = Mat::random_normal(24, 18, &mut rng);
        for x in &mut w.data {
            *x = x.abs();
        }
        let f = cur_decompose(&w, &w, 4, &mut rng).unwrap();
        assert!(f.c.data.iter().all(|&x| x >= 0.0));
        assert!(f.r.data.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn u_is_frobenius_optimal() {
        // U = C^+ W R^+ minimizes ||W - C U R||_F over U: perturbing U
        // must not reduce the error.
        let mut rng = Rng::new(6, 0);
        let w = Mat::random_normal(18, 14, &mut rng);
        let f = cur_decompose(&w, &w, 5, &mut rng).unwrap();
        let base = w.sub(&f.reconstruct()).fro_norm();
        for _ in 0..10 {
            let mut fu = f.clone();
            let i = rng.below(5);
            let j = rng.below(5);
            fu.u[(i, j)] += 0.01;
            let perturbed = w.sub(&fu.reconstruct()).fro_norm();
            assert!(perturbed >= base - 1e-9, "perturbed {perturbed} < base {base}");
        }
    }

    #[test]
    fn kv_selection_distinct_in_range_and_deterministic() {
        let mut rng = Rng::new(21, 0);
        for _ in 0..10 {
            let n = 12 + rng.below(50);
            let d = 8 + rng.below(24);
            let keep = 1 + rng.below(n - 1);
            let keys = Mat::random_normal(n, d, &mut rng);
            let weights: Vec<f64> = (0..n).map(|_| rng.f64() + 0.05).collect();
            let idx = select_kv_positions(&keys, &weights, keep).unwrap();
            assert_eq!(idx.len(), keep);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), keep, "duplicate kv indices");
            assert!(idx.iter().all(|&i| i < n));
            // Same inputs, same picks (no hidden randomness).
            assert_eq!(select_kv_positions(&keys, &weights, keep).unwrap(), idx);
        }
    }

    #[test]
    fn kv_selection_prefers_high_mass_positions() {
        // Eight positions: rows 1, 4 and 6 carry large orthogonal keys
        // with large weights, the rest are tiny noise. Value-guided
        // selection at keep=3 must find exactly the heavy trio.
        let mut rng = Rng::new(22, 0);
        let (n, d) = (8usize, 16usize);
        let mut keys = Mat::random_normal(n, d, &mut rng);
        keys.scale(0.01);
        let mut weights = vec![0.05f64; n];
        for (axis, &i) in [1usize, 4, 6].iter().enumerate() {
            for j in 0..d {
                keys[(i, j)] = 0.0;
            }
            keys[(i, axis)] = 10.0;
            weights[i] = 5.0 - axis as f64; // distinct masses break SVD ties
        }
        let mut idx = select_kv_positions(&keys, &weights, 3).unwrap();
        idx.sort_unstable();
        assert_eq!(idx, vec![1, 4, 6]);
    }

    #[test]
    fn kv_selection_keep_all_and_overflow_fill() {
        let mut rng = Rng::new(23, 0);
        // keep == n short-circuits to the identity selection.
        let keys = Mat::random_normal(6, 4, &mut rng);
        let w = vec![1.0; 6];
        let mut idx = select_kv_positions(&keys, &w, 6).unwrap();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1, 2, 3, 4, 5]);
        // keep > d: DEIM supplies d picks, the rest fill by weight.
        let keys = Mat::random_normal(7, 2, &mut rng);
        let w: Vec<f64> = (0..7).map(|i| i as f64 + 0.5).collect();
        let idx = select_kv_positions(&keys, &w, 5).unwrap();
        assert_eq!(idx.len(), 5);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn kv_selection_rejects_non_finite_weights() {
        // Regression guard for the crate-wide NaN ordering policy: an
        // undefined importance weight must be a hard error at the API
        // boundary, never a position silently ranked ahead of finite
        // ones (the failure mode behind the original wanda NaN panic).
        let mut rng = Rng::new(24, 0);
        let keys = Mat::random_normal(6, 4, &mut rng);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let mut w = vec![1.0f64; 6];
            w[3] = bad;
            let err = select_kv_positions(&keys, &w, 2).unwrap_err();
            assert!(err.to_string().contains("finite"), "weight {bad}: {err}");
        }
    }

    #[test]
    fn param_count_matches_rank_formula() {
        let mut rng = Rng::new(7, 0);
        let w = Mat::random_normal(50, 30, &mut rng);
        let f = cur_decompose(&w, &w, 8, &mut rng).unwrap();
        assert_eq!(f.param_count(), 50 * 8 + 8 * 8 + 8 * 30);
        assert_eq!(f.rank(), 8);
    }
}

//! A supervised multi-worker serving cluster.
//!
//! [`ClusterServer`] replicates the single-threaded
//! [`GenerationServer`] engine across N worker threads — each with its
//! own KV slots and its own `Runtime` (backend handles are not `Send`,
//! so every worker builds one in-thread via [`WorkerRuntime`]) over a
//! shared [`TensorStore`] — behind a [`ClusterRouter`] that does
//! least-outstanding-work dispatch and bounded cluster-wide admission.
//! Worker lifecycle (heartbeats, `catch_unwind` crash detection,
//! exponential-backoff respawn, circuit-breaker retirement) belongs to
//! the [`Supervisor`](super::supervisor::Supervisor).
//!
//! The cluster speaks the engine's protocol verbatim: send
//! [`Request`]s (Score / Generate / Shutdown) on the channel passed to
//! [`ClusterServer::run`], read typed responses, and either drop the
//! sender or send [`Request::Shutdown`] for a graceful drain that
//! merges every worker's [`ServeStats`].
//!
//! **Replay correctness.** A request in flight on a dying worker is
//! re-queued to a healthy one (bounded by
//! [`ClusterServer::retry_budget`]). Greedy decode is deterministic
//! and a replay re-prefills from the prompt, so a replayed request's
//! token stream is bit-identical to an unfaulted run — the cluster
//! tests assert this against the cache-free oracle. With every worker
//! retired, queued and later requests are answered with
//! [`ServeError::AllWorkersRetired`] instead of hanging.

use super::supervisor::{Supervisor, SupervisorConfig, WorkerEvent, WorkerExit, WorkerSeed};
use super::{
    GenRequest, GenResponse, GenerationServer, Request, ScoreRequest, ScoreResponse, ServeError,
    ServeStats,
};
use crate::backend::fault::{mute_injected_crash_reports, FaultPlan, InjectedCrash};
use crate::backend::KvPolicy;
use crate::model::ModelConfig;
use crate::pipeline::{LayerPlan, Pipeline};
use crate::runtime::Runtime;
use crate::tensor::TensorStore;
use crate::util::stats::percentile;
use anyhow::Result;
use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Builds one worker's [`Runtime`] *inside* the worker thread (the
/// handles are not `Send`). The argument is the worker id — a
/// per-worker fault plan or backend choice hangs here.
pub type WorkerRuntime = Arc<dyn Fn(usize) -> Result<Runtime> + Send + Sync>;

/// The multi-worker server. Mirrors [`GenerationServer`]'s knobs per
/// worker and adds the cluster-level ones (admission, retry budget,
/// supervision). All fields are public so tests and benches can tune
/// the topology directly; [`ClusterServer::new`] picks serving-grade
/// defaults.
pub struct ClusterServer {
    pub cfg: ModelConfig,
    /// Weights shared by every worker (plain data: `Send + Sync`).
    pub store: Arc<TensorStore>,
    pub plan: LayerPlan,
    /// Worker threads (each a full [`GenerationServer`]).
    pub workers: usize,
    /// KV slots per worker.
    pub slots: usize,
    pub kv_policy: KvPolicy,
    /// Scoring flush cap per worker; clamped to `heartbeat / 4` so an
    /// idle worker still beats in time.
    pub max_wait: Duration,
    /// Cluster-default per-request deadline (a request's own overrides).
    pub deadline: Option<Duration>,
    /// Bounded cluster-wide admission: max undispatched requests before
    /// intake sheds with [`ServeError::Overloaded`]. `0` = unbounded.
    pub queue_cap: usize,
    /// Replays allowed per request after worker deaths before it is
    /// answered [`ServeError::RetriesExhausted`].
    pub retry_budget: usize,
    /// Heartbeat deadline for hung-worker detection.
    pub heartbeat: Duration,
    /// First respawn backoff; doubles per crash up to `backoff_max`.
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// Circuit breaker: crashes inside `breaker_window` that retire a
    /// worker permanently.
    pub breaker_crashes: usize,
    pub breaker_window: Duration,
    /// Per-worker runtime factory (fault plans are injected here).
    pub factory: WorkerRuntime,
}

impl ClusterServer {
    /// A cluster over `workers` clean native workers with defaults
    /// sized for the test/bench models.
    pub fn new(
        cfg: ModelConfig,
        store: Arc<TensorStore>,
        plan: LayerPlan,
        workers: usize,
    ) -> ClusterServer {
        ClusterServer {
            cfg,
            store,
            plan,
            workers,
            slots: 2,
            kv_policy: KvPolicy::Exact,
            max_wait: Duration::from_millis(10),
            deadline: None,
            queue_cap: 0,
            retry_budget: 2,
            heartbeat: Duration::from_millis(200),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(500),
            breaker_crashes: 3,
            breaker_window: Duration::from_secs(10),
            factory: Arc::new(|_| Ok(Runtime::native())),
        }
    }

    /// Wrap every worker's backend in a [`FaultPlan`], with the seed
    /// decorrelated per worker (same plan + same worker id = same
    /// injection stream, across respawns too — a crash-looping worker
    /// crash-loops deterministically, which is what the breaker tests
    /// pin).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> ClusterServer {
        self.factory = Arc::new(move |w| {
            let mut p = plan.clone();
            p.seed = plan.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(w as u64 + 1);
            Ok(Runtime::native().with_faults(p))
        });
        self
    }

    /// Serve until the request channel disconnects and all accepted
    /// work has drained (or was answered with a typed error). Runs the
    /// router and supervisor on the calling thread; workers are spawned
    /// threads.
    pub fn run(&self, rx: Receiver<Request>) -> Result<ServeStats> {
        mute_injected_crash_reports();
        // Fail fast on an unusable policy before spawning anything.
        self.kv_policy.validate(self.cfg.seq)?;
        let n = self.workers.max(1);
        let sup_cfg = SupervisorConfig {
            heartbeat: self.heartbeat,
            backoff_base: self.backoff_base,
            backoff_max: self.backoff_max,
            breaker_crashes: self.breaker_crashes,
            breaker_window: self.breaker_window,
        };
        let mut sup = Supervisor::new(n, sup_cfg, self.worker_spawn());
        let mut router = ClusterRouter {
            queue: VecDeque::new(),
            flight: Vec::new(),
            slots_per_worker: self.slots.max(1),
            retry_budget: self.retry_budget,
            deadline: self.deadline,
        };
        let t0 = Instant::now();
        let mut stats = ServeStats::default();
        let mut score_lat: Vec<f64> = Vec::new();
        let mut drain_notify: Vec<Sender<ServeStats>> = Vec::new();
        let mut disconnected = false;
        loop {
            // ---- intake. Poll fast while work is in flight (response
            // polling is pull-based), lazily when idle.
            let block = if !router.flight.is_empty()
                || !router.queue.is_empty()
                || disconnected
                || !drain_notify.is_empty()
            {
                Duration::from_millis(1)
            } else {
                (self.heartbeat / 2).max(Duration::from_millis(1))
            };
            match rx.recv_timeout(block) {
                Ok(r) => self.intake(r, &mut router, &sup, &mut drain_notify, &mut stats),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
            loop {
                match rx.try_recv() {
                    Ok(r) => self.intake(r, &mut router, &sup, &mut drain_notify, &mut stats),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            let draining = !drain_notify.is_empty();
            if (disconnected || draining) && router.queue.is_empty() && router.flight.is_empty()
            {
                break;
            }
            // ---- supervision: reap crashes/hangs, respawn, and replay
            // the dead workers' in-flight requests.
            for w in sup.poll() {
                router.requeue_worker(w, &mut stats);
            }
            // ---- forward finished responses; a disconnected response
            // channel is a worker death the supervisor hasn't reported
            // yet — replay, don't lose the request.
            router.poll_responses(&mut stats, &mut score_lat);
            // ---- evict queued requests whose deadline passed.
            router.evict_expired(&mut stats);
            // ---- terminal no-capacity state: every worker retired,
            // nothing will ever respawn. Answer instead of hanging.
            if sup.all_retired() {
                router.drain_retired(sup.workers(), &mut stats);
            }
            // ---- least-outstanding-work dispatch.
            router.dispatch(&sup);
        }
        // ---- teardown: drop worker senders, collect final stats.
        let report = sup.shutdown(self.heartbeat.max(Duration::from_secs(2)));
        stats.worker_crashes = report.crashes;
        stats.worker_restarts = report.restarts;
        stats.retired_workers = report.retired;
        merge_worker_stats(&mut stats, &report.finished);
        stats.wall_s = t0.elapsed().as_secs_f64();
        stats.p50_latency_ms = percentile(&score_lat, 50.0);
        stats.p95_latency_ms = percentile(&score_lat, 95.0);
        stats.throughput_seq_per_s = stats.served as f64 / stats.wall_s.max(1e-9);
        stats.tokens_per_s = stats.tokens_generated as f64 / stats.wall_s.max(1e-9);
        for tx in drain_notify {
            let _ = tx.send(stats.clone());
        }
        Ok(stats)
    }

    /// The [`WorkerSpawn`](super::supervisor::WorkerSpawn) closure: one
    /// OS thread per incarnation, building its own `Runtime`/`Pipeline`
    /// in-thread, heartbeating through the engine's `tick` hook, and
    /// reporting its exit — clean stats, fatal error, or caught panic —
    /// on the supervisor's event channel.
    fn worker_spawn(&self) -> super::supervisor::WorkerSpawn {
        let store = self.store.clone();
        let cfg = self.cfg.clone();
        let plan = self.plan.clone();
        let factory = self.factory.clone();
        let slots = self.slots.max(1);
        let kv_policy = self.kv_policy;
        // An idle worker blocks for max_wait between heartbeats: keep
        // that well inside the liveness deadline.
        let wait = self.max_wait.min(self.heartbeat / 4).max(Duration::from_millis(1));
        Box::new(move |seed: WorkerSeed| {
            let WorkerSeed { worker, incarnation, requests, beat, epoch, events } = seed;
            let store = store.clone();
            let cfg = cfg.clone();
            let plan = plan.clone();
            let factory = factory.clone();
            std::thread::spawn(move || {
                let tick: Box<dyn Fn()> = Box::new(move || {
                    beat.store(epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
                });
                // The supervisor's crash boundary: a worker panic (the
                // injected `crash` fault, or an organic one) must become
                // a WorkerEvent, never tear down the cluster.
                // curlint: allow(panic) -- supervisor crash boundary: panics become WorkerExit events
                let out = std::panic::catch_unwind(AssertUnwindSafe(|| -> Result<ServeStats> {
                    let rt = factory(worker)?;
                    let pipe = Pipeline { rt: &rt, cfg };
                    let server = GenerationServer {
                        pipe: &pipe,
                        store: &store,
                        plan,
                        max_wait: wait,
                        slots,
                        kv_policy,
                        deadline: None, // requests carry the resolved deadline
                        queue_cap: 0,   // admission is bounded cluster-wide
                        tick: Some(tick),
                    };
                    server.run(requests)
                }));
                let exit = match out {
                    Ok(Ok(stats)) => WorkerExit::Clean(Box::new(stats)),
                    Ok(Err(e)) => WorkerExit::Fatal(format!("{e:#}")),
                    Err(payload) => WorkerExit::Panicked(describe_panic(payload.as_ref())),
                };
                let _ = events.send(WorkerEvent { worker, incarnation, exit });
            })
        })
    }

    /// Cluster-level admission: shed while draining or over the queue
    /// cap, answer immediately when all capacity is retired, otherwise
    /// queue for dispatch.
    fn intake(
        &self,
        r: Request,
        router: &mut ClusterRouter,
        sup: &Supervisor,
        drain_notify: &mut Vec<Sender<ServeStats>>,
        stats: &mut ServeStats,
    ) {
        let depth = router.queue.len();
        let shed = if !drain_notify.is_empty() {
            Some(ServeError::ShuttingDown)
        } else if sup.all_retired() {
            Some(ServeError::AllWorkersRetired { retired: sup.workers() })
        } else if self.queue_cap > 0 && depth >= self.queue_cap {
            Some(ServeError::Overloaded { depth, cap: self.queue_cap })
        } else {
            None
        };
        let job = match r {
            Request::Shutdown(tx) => {
                drain_notify.push(tx);
                return;
            }
            Request::Score(s) => Job::Score(ScoreJob {
                tokens: s.tokens,
                targets: s.targets,
                enqueued: s.enqueued,
                deadline: s.deadline.or(self.deadline),
                client: s.respond,
                attempts: 1,
            }),
            Request::Generate(g) => Job::Gen(GenJob {
                prompt: g.prompt,
                n_new: g.n_new,
                enqueued: g.enqueued,
                deadline: g.deadline.or(self.deadline),
                client: g.respond,
                attempts: 1,
            }),
        };
        match shed {
            Some(e) => {
                stats.rejected += 1;
                job.reply_error(e);
            }
            None => router.queue.push_back(job),
        }
    }
}

/// A queued (or re-queued) request with its replay count. Holds the
/// *client's* response sender; each dispatch pairs the worker with a
/// fresh shim channel so the router observes completion or loss.
struct GenJob {
    prompt: Vec<i32>,
    n_new: usize,
    enqueued: Instant,
    deadline: Option<Duration>,
    client: Sender<GenResponse>,
    /// Dispatch attempts so far (1 = first try).
    attempts: usize,
}

struct ScoreJob {
    tokens: Vec<i32>,
    targets: Vec<i32>,
    enqueued: Instant,
    deadline: Option<Duration>,
    client: Sender<ScoreResponse>,
    attempts: usize,
}

enum Job {
    Gen(GenJob),
    Score(ScoreJob),
}

impl Job {
    fn enqueued(&self) -> Instant {
        match self {
            Job::Gen(j) => j.enqueued,
            Job::Score(j) => j.enqueued,
        }
    }

    fn deadline(&self) -> Option<Duration> {
        match self {
            Job::Gen(j) => j.deadline,
            Job::Score(j) => j.deadline,
        }
    }

    fn reply_error(self, e: ServeError) {
        let latency_ms = self.enqueued().elapsed().as_secs_f64() * 1e3;
        match self {
            Job::Gen(j) => {
                let _ = j.client.send(GenResponse {
                    tokens: Vec::new(),
                    latency_ms,
                    error: Some(e),
                });
            }
            Job::Score(j) => {
                let _ = j.client.send(ScoreResponse {
                    mean_nll: f64::NAN,
                    latency_ms,
                    error: Some(e),
                });
            }
        }
    }
}

/// The shim receiver a dispatched job's worker answers on.
enum Shim {
    Gen(Receiver<GenResponse>),
    Score(Receiver<ScoreResponse>),
}

struct InFlight {
    job: Job,
    shim: Shim,
    worker: usize,
}

/// Dispatch state: the cluster backlog, the in-flight table, and the
/// routing policy (least outstanding work wins, per-worker outstanding
/// bounded at `2 × slots` so a respawned worker picks up load).
struct ClusterRouter {
    queue: VecDeque<Job>,
    flight: Vec<InFlight>,
    slots_per_worker: usize,
    retry_budget: usize,
    deadline: Option<Duration>,
}

impl ClusterRouter {
    fn outstanding(&self, w: usize) -> usize {
        self.flight.iter().filter(|f| f.worker == w).count()
    }

    /// Dispatch queued jobs to live workers, least-outstanding first.
    fn dispatch(&mut self, sup: &Supervisor) {
        while !self.queue.is_empty() {
            let cap = 2 * self.slots_per_worker;
            let Some(w) = sup
                .up()
                .into_iter()
                .map(|w| (self.outstanding(w), w))
                .filter(|&(n, _)| n < cap)
                .min()
                .map(|(_, w)| w)
            else {
                break;
            };
            let Some(tx) = sup.sender(w) else { break };
            let Some(job) = self.queue.pop_front() else { break };
            let (job, req, shim) = Self::wire(job);
            if tx.send(req).is_err() {
                // The worker died between poll and dispatch; put the
                // job back — the next supervision pass owns the death.
                self.queue.push_front(job);
                break;
            }
            self.flight.push(InFlight { job, shim, worker: w });
        }
    }

    /// Pair a job with a fresh shim channel and build the worker-bound
    /// request (the resolved deadline rides along; `enqueued` stays the
    /// client's original instant so latency and deadlines are
    /// end-to-end across replays).
    fn wire(job: Job) -> (Job, Request, Shim) {
        match job {
            Job::Gen(j) => {
                let (stx, srx) = channel();
                let req = Request::Generate(GenRequest {
                    prompt: j.prompt.clone(),
                    n_new: j.n_new,
                    enqueued: j.enqueued,
                    deadline: j.deadline,
                    respond: stx,
                });
                (Job::Gen(j), req, Shim::Gen(srx))
            }
            Job::Score(j) => {
                let (stx, srx) = channel();
                let req = Request::Score(ScoreRequest {
                    tokens: j.tokens.clone(),
                    targets: j.targets.clone(),
                    enqueued: j.enqueued,
                    deadline: j.deadline,
                    respond: stx,
                });
                (Job::Score(j), req, Shim::Score(srx))
            }
        }
    }

    /// Forward every completed response to its client; treat a
    /// disconnected shim (the worker dropped the request's sender
    /// without answering — it died) as a replayable loss.
    fn poll_responses(&mut self, stats: &mut ServeStats, score_lat: &mut Vec<f64>) {
        let mut i = 0;
        while i < self.flight.len() {
            enum Got {
                GenDone(GenResponse),
                ScoreDone(ScoreResponse),
                Wait,
                Lost,
            }
            let got = match &self.flight[i].shim {
                Shim::Gen(rx) => match rx.try_recv() {
                    Ok(r) => Got::GenDone(r),
                    Err(TryRecvError::Empty) => Got::Wait,
                    Err(TryRecvError::Disconnected) => Got::Lost,
                },
                Shim::Score(rx) => match rx.try_recv() {
                    Ok(r) => Got::ScoreDone(r),
                    Err(TryRecvError::Empty) => Got::Wait,
                    Err(TryRecvError::Disconnected) => Got::Lost,
                },
            };
            match got {
                Got::Wait => i += 1,
                Got::Lost => {
                    let inflight = self.flight.swap_remove(i);
                    self.requeue(inflight.job, stats);
                }
                Got::GenDone(resp) => {
                    let InFlight { job: Job::Gen(j), .. } = self.flight.swap_remove(i) else {
                        continue; // shim and job kinds are wired together
                    };
                    if matches!(resp.error, Some(ServeError::Timeout { .. })) {
                        stats.timed_out += 1;
                    }
                    stats.gen_served += 1;
                    stats.tokens_generated += resp.tokens.len();
                    let _ = j.client.send(resp);
                }
                Got::ScoreDone(resp) => {
                    let InFlight { job: Job::Score(j), .. } = self.flight.swap_remove(i) else {
                        continue;
                    };
                    if matches!(resp.error, Some(ServeError::Timeout { .. })) {
                        stats.timed_out += 1;
                    }
                    if resp.error.is_none() {
                        stats.served += 1;
                        score_lat.push(resp.latency_ms);
                    }
                    let _ = j.client.send(resp);
                }
            }
        }
    }

    /// Replay every in-flight request of a dead worker.
    fn requeue_worker(&mut self, w: usize, stats: &mut ServeStats) {
        let mut i = 0;
        while i < self.flight.len() {
            if self.flight[i].worker == w {
                let inflight = self.flight.swap_remove(i);
                self.requeue(inflight.job, stats);
            } else {
                i += 1;
            }
        }
    }

    /// One replay: back to the queue front under the retry budget,
    /// typed [`ServeError::RetriesExhausted`] beyond it. Replays
    /// re-prefill from the prompt on the new worker, so the replayed
    /// stream is bit-identical to an unfaulted run (greedy decode is
    /// deterministic).
    fn requeue(&mut self, mut job: Job, stats: &mut ServeStats) {
        let attempts = match &mut job {
            Job::Gen(j) => {
                j.attempts += 1;
                j.attempts
            }
            Job::Score(j) => {
                j.attempts += 1;
                j.attempts
            }
        };
        if attempts > self.retry_budget.saturating_add(1) {
            match &job {
                Job::Gen(_) => stats.gen_served += 1,
                Job::Score(_) => {}
            }
            job.reply_error(ServeError::RetriesExhausted { attempts: attempts - 1 });
            return;
        }
        stats.retried_requests += 1;
        self.queue.push_front(job);
    }

    /// Evict queued jobs whose end-to-end deadline elapsed (dispatched
    /// jobs are deadline-checked by their worker).
    fn evict_expired(&mut self, stats: &mut ServeStats) {
        let expired: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter_map(|(i, job)| {
                let d = job.deadline().or(self.deadline)?;
                (job.enqueued().elapsed() >= d).then_some(i)
            })
            .collect();
        for i in expired.into_iter().rev() {
            let Some(job) = self.queue.remove(i) else { continue };
            let Some(d) = job.deadline().or(self.deadline) else { continue };
            stats.timed_out += 1;
            if matches!(job, Job::Gen(_)) {
                stats.gen_served += 1;
            }
            job.reply_error(ServeError::Timeout { deadline_ms: d.as_millis() as u64 });
        }
    }

    /// Terminal path: all capacity is retired. Answer everything still
    /// queued with the typed error (in-flight work was already replayed
    /// into the queue when its worker died) — the cluster never hangs.
    fn drain_retired(&mut self, retired: usize, stats: &mut ServeStats) {
        for job in self.queue.drain(..) {
            stats.rejected += 1;
            if matches!(job, Job::Gen(_)) {
                stats.gen_served += 1;
            }
            job.reply_error(ServeError::AllWorkersRetired { retired });
        }
    }
}

/// Render a `catch_unwind` payload: injected crashes by their typed
/// payload, plain panic messages verbatim, anything else generically.
fn describe_panic(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(c) = payload.downcast_ref::<InjectedCrash>() {
        return c.to_string();
    }
    if let Some(s) = payload.downcast_ref::<&str>() {
        return format!("panic: {s}");
    }
    if let Some(s) = payload.downcast_ref::<String>() {
        return format!("panic: {s}");
    }
    "panic with a non-string payload".to_string()
}

/// Merge the engine-level stats of cleanly drained workers into the
/// cluster totals. Request-level counters (`served`, `gen_served`,
/// `tokens_generated`, `rejected`, `timed_out`, retry/crash counters)
/// are owned by the router — the workers' copies would double count —
/// so only machine-level fields merge here. Percentile fields cannot
/// be merged exactly; the per-token ones are token-weighted means
/// across workers (documented approximation).
fn merge_worker_stats(stats: &mut ServeStats, finished: &[ServeStats]) {
    let mut batches = 0usize;
    let mut occ_sum = 0.0f64;
    let mut steps = 0usize;
    let mut active_sum = 0.0f64;
    let mut live_sum = 0.0f64;
    let mut toks = 0usize;
    let mut p50_sum = 0.0f64;
    let mut p95_sum = 0.0f64;
    for s in finished {
        stats.prefills += s.prefills;
        stats.decode_steps += s.decode_steps;
        stats.kv_compactions += s.kv_compactions;
        stats.padded_rows += s.padded_rows;
        stats.slot_failures += s.slot_failures;
        stats.quarantined_slots += s.quarantined_slots;
        stats.degraded_steps += s.degraded_steps;
        batches += s.batches;
        occ_sum += s.mean_batch_occupancy * s.batches as f64;
        steps += s.decode_steps;
        active_sum += s.mean_active_slots * s.decode_steps as f64;
        live_sum += s.kv_live_bytes_mean * s.decode_steps as f64;
        toks += s.tokens_generated;
        p50_sum += s.tok_p50_ms * s.tokens_generated as f64;
        p95_sum += s.tok_p95_ms * s.tokens_generated as f64;
    }
    stats.batches += batches;
    if batches > 0 {
        stats.mean_batch_occupancy = occ_sum / batches as f64;
    }
    if steps > 0 {
        stats.mean_active_slots = active_sum / steps as f64;
        stats.kv_live_bytes_mean = live_sum / steps as f64;
    }
    if toks > 0 {
        stats.tok_p50_ms = p50_sum / toks as f64;
        stats.tok_p95_ms = p95_sum / toks as f64;
    }
}

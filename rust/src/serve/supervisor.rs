//! Worker lifecycle supervision for the serving cluster.
//!
//! The [`Supervisor`] owns N worker *slots*. Each slot runs at most one
//! live *incarnation* — an OS thread executing a
//! [`super::GenerationServer`] loop — and moves through a small state
//! machine:
//!
//! ```text
//!   Up ──crash/hang──▶ Backoff ──delay elapsed──▶ Up (respawn)
//!   Up ──K crashes in the sliding window──▶ Retired   (permanent)
//!   Up ──cluster shutdown──▶ Stopped                  (stats merged)
//! ```
//!
//! Liveness is a heartbeat: every incarnation gets an `Arc<AtomicU64>`
//! it must bump (via the server's `tick` hook) with milliseconds since
//! the supervisor epoch; an `Up` worker whose beat goes stale past the
//! configured deadline is declared hung and torn down. Rust threads
//! cannot be killed, so teardown *abandons* the incarnation: its
//! request sender is dropped (the thread exits once it notices), its
//! in-flight work is replayed elsewhere by the router, and any late
//! exit event from the zombie is ignored by incarnation number.
//!
//! Crash detection is two-layered: the worker thread body wraps its
//! engine in `catch_unwind` (a panic — e.g. an injected
//! [`crate::backend::fault::FaultKind::Crash`] — becomes
//! [`WorkerExit::Panicked`]) and a fatal engine error (`run` returning
//! `Err`, never used for per-request trouble) escalates as
//! [`WorkerExit::Fatal`]. Either way the slot backs off exponentially
//! before respawning, and a circuit breaker retires it permanently
//! after `breaker_crashes` crashes inside `breaker_window` — capacity
//! shrinks instead of crash-looping forever.

use super::{Request, ServeStats};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How one worker incarnation ended.
#[derive(Debug)]
pub enum WorkerExit {
    /// The engine drained cleanly (its request channel disconnected);
    /// the stats are collected for the cluster merge.
    Clean(Box<ServeStats>),
    /// The engine returned a fatal error — an invariant breach, not a
    /// per-request failure (those are typed responses).
    Fatal(String),
    /// The worker thread panicked (injected `crash` fault or organic).
    Panicked(String),
}

/// One worker-exit report on the supervisor's event channel.
#[derive(Debug)]
pub struct WorkerEvent {
    pub worker: usize,
    pub incarnation: u64,
    pub exit: WorkerExit,
}

/// Everything a spawner needs to start one worker incarnation. The
/// thread must bump `beat` (ms since `epoch`) while alive and send
/// exactly one [`WorkerEvent`] carrying `incarnation` when it ends.
pub struct WorkerSeed {
    pub worker: usize,
    pub incarnation: u64,
    pub requests: Receiver<Request>,
    pub beat: Arc<AtomicU64>,
    pub epoch: Instant,
    pub events: Sender<WorkerEvent>,
}

/// Spawns the OS thread for one incarnation. The cluster supplies
/// this; backend handles are not `Send`, so the closure must build the
/// worker's `Runtime` *inside* the thread.
pub type WorkerSpawn = Box<dyn Fn(WorkerSeed) -> std::thread::JoinHandle<()>>;

/// Supervision knobs.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// An `Up` worker whose heartbeat is older than this is declared
    /// hung and torn down. Must comfortably exceed the worker's idle
    /// block (`max_wait`) — the cluster clamps the worker wait to a
    /// quarter of this.
    pub heartbeat: Duration,
    /// First respawn delay; doubles per crash in the sliding window,
    /// capped at `backoff_max`.
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// Circuit breaker: this many crashes inside `breaker_window`
    /// retire the worker permanently.
    pub breaker_crashes: usize,
    pub breaker_window: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            heartbeat: Duration::from_millis(200),
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(500),
            breaker_crashes: 3,
            breaker_window: Duration::from_secs(10),
        }
    }
}

enum Health {
    Up { tx: Sender<Request>, beat: Arc<AtomicU64>, spawned: Instant },
    Backoff { until: Instant },
    Retired,
    /// Shutdown requested: the sender is dropped, the incarnation is
    /// draining (or already gone).
    Stopped,
}

struct WorkerSlot {
    health: Health,
    /// Incarnation number of the latest spawn; exit events from older
    /// (abandoned) incarnations are ignored.
    incarnation: u64,
    /// Crash timestamps inside the breaker's sliding window.
    crashes: VecDeque<Instant>,
}

/// The lifecycle manager: spawns incarnations, watches heartbeats,
/// turns exits into backoff/retirement, and collects clean-exit stats.
/// Request routing lives in [`super::cluster`]; the supervisor only
/// says *which* workers are up and *when* one died.
pub struct Supervisor {
    cfg: SupervisorConfig,
    spawn: WorkerSpawn,
    slots: Vec<WorkerSlot>,
    events_tx: Sender<WorkerEvent>,
    events_rx: Receiver<WorkerEvent>,
    epoch: Instant,
    /// Stats of incarnations that drained cleanly.
    pub finished: Vec<ServeStats>,
    /// Incarnation deaths: panics, fatal errors, and missed heartbeats.
    pub crashes: usize,
    /// Respawns after backoff (the initial spawns don't count).
    pub restarts: usize,
    /// Last crash detail per worker (observability).
    pub last_fault: Vec<Option<String>>,
}

impl Supervisor {
    /// Spawn `n` workers (incarnation 1 each) and start supervising.
    pub fn new(n: usize, cfg: SupervisorConfig, spawn: WorkerSpawn) -> Supervisor {
        let (events_tx, events_rx) = channel();
        let mut sup = Supervisor {
            cfg,
            spawn,
            slots: Vec::new(),
            events_tx,
            events_rx,
            epoch: Instant::now(),
            finished: Vec::new(),
            crashes: 0,
            restarts: 0,
            last_fault: vec![None; n],
        };
        for w in 0..n {
            sup.slots.push(WorkerSlot {
                health: Health::Backoff { until: sup.epoch },
                incarnation: 0,
                crashes: VecDeque::new(),
            });
            sup.respawn(w);
        }
        sup
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn respawn(&mut self, w: usize) {
        let now = self.now_ms();
        let slot = &mut self.slots[w];
        slot.incarnation += 1;
        let beat = Arc::new(AtomicU64::new(now));
        let (tx, rx) = channel();
        let seed = WorkerSeed {
            worker: w,
            incarnation: slot.incarnation,
            requests: rx,
            beat: beat.clone(),
            epoch: self.epoch,
            events: self.events_tx.clone(),
        };
        slot.health = Health::Up { tx, beat, spawned: Instant::now() };
        // The handle is dropped on purpose: incarnations are reaped
        // through the event channel (a hung one can never be joined).
        let _ = (self.spawn)(seed);
    }

    /// The request sender of worker `w`, if it is up.
    pub fn sender(&self, w: usize) -> Option<&Sender<Request>> {
        match &self.slots[w].health {
            Health::Up { tx, .. } => Some(tx),
            _ => None,
        }
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Worker ids currently up (spawned and not known-dead).
    pub fn up(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&w| self.sender(w).is_some()).collect()
    }

    pub fn retired(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s.health, Health::Retired)).count()
    }

    /// True once every worker is retired — the cluster's terminal
    /// no-capacity state (nothing is backing off toward a respawn).
    pub fn all_retired(&self) -> bool {
        self.slots.iter().all(|s| matches!(s.health, Health::Retired))
    }

    /// One supervision pass: reap exit events, declare hung workers
    /// dead, respawn slots whose backoff elapsed. Returns the workers
    /// whose live incarnation died in this pass — the router must
    /// replay their in-flight requests.
    pub fn poll(&mut self) -> Vec<usize> {
        let mut died = Vec::new();
        while let Ok(ev) = self.events_rx.try_recv() {
            let slot = &mut self.slots[ev.worker];
            if ev.incarnation != slot.incarnation {
                continue; // zombie: an incarnation abandoned after a hang
            }
            match ev.exit {
                WorkerExit::Clean(stats) => {
                    // Clean exits only happen after a teardown dropped
                    // the sender; a slot no longer Up was abandoned as
                    // hung and its work replayed — don't let the zombie
                    // revive it or double-count its stats.
                    if matches!(slot.health, Health::Up { .. }) {
                        self.finished.push(*stats);
                        slot.health = Health::Stopped;
                    }
                }
                WorkerExit::Fatal(detail) | WorkerExit::Panicked(detail) => {
                    if matches!(slot.health, Health::Up { .. }) {
                        self.last_fault[ev.worker] = Some(detail);
                        self.note_crash(ev.worker);
                        died.push(ev.worker);
                    }
                }
            }
        }
        // Heartbeat sweep: an Up worker whose beat is stale past the
        // deadline is hung — abandon it and replay its work.
        let now = self.now_ms();
        for w in 0..self.slots.len() {
            let hung = match &self.slots[w].health {
                Health::Up { beat, spawned, .. } => {
                    spawned.elapsed() > self.cfg.heartbeat
                        && now.saturating_sub(beat.load(Ordering::Relaxed))
                            > self.cfg.heartbeat.as_millis() as u64
                }
                _ => false,
            };
            if hung {
                self.last_fault[w] =
                    Some(format!("missed heartbeat deadline of {:?}", self.cfg.heartbeat));
                self.note_crash(w);
                died.push(w);
            }
        }
        // Respawns whose backoff elapsed.
        for w in 0..self.slots.len() {
            if matches!(&self.slots[w].health, Health::Backoff { until } if *until <= Instant::now())
            {
                self.restarts += 1;
                self.respawn(w);
            }
        }
        died
    }

    /// Account one incarnation death: slide the breaker window, retire
    /// at the threshold, otherwise schedule an exponential-backoff
    /// respawn. Dropping the `Up` sender here is the teardown — the
    /// (possibly still running) thread exits once it notices.
    fn note_crash(&mut self, w: usize) {
        self.crashes += 1;
        let window = self.cfg.breaker_window;
        let slot = &mut self.slots[w];
        let now = Instant::now();
        slot.crashes.push_back(now);
        while slot.crashes.front().is_some_and(|&t| now.duration_since(t) > window) {
            slot.crashes.pop_front();
        }
        if slot.crashes.len() >= self.cfg.breaker_crashes.max(1) {
            slot.health = Health::Retired;
        } else {
            let exp = (slot.crashes.len().saturating_sub(1)).min(16) as u32;
            let delay = self
                .cfg
                .backoff_base
                .saturating_mul(2u32.saturating_pow(exp))
                .min(self.cfg.backoff_max);
            slot.health = Health::Backoff { until: now + delay };
        }
    }

    /// Tear the cluster down: drop every live sender, then wait up to
    /// `timeout` for the draining incarnations to report their final
    /// stats (a hung worker that never reports is simply abandoned).
    pub fn shutdown(mut self, timeout: Duration) -> SupervisorReport {
        let mut awaiting: Vec<Option<u64>> = vec![None; self.slots.len()];
        for (w, slot) in self.slots.iter_mut().enumerate() {
            if matches!(slot.health, Health::Up { .. }) {
                awaiting[w] = Some(slot.incarnation);
                slot.health = Health::Stopped; // drops the sender
            }
        }
        let deadline = Instant::now() + timeout;
        let mut open = awaiting.iter().filter(|a| a.is_some()).count();
        while open > 0 {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                break;
            };
            let Ok(ev) = self.events_rx.recv_timeout(left) else { break };
            if awaiting[ev.worker] != Some(ev.incarnation) {
                continue;
            }
            awaiting[ev.worker] = None;
            open -= 1;
            match ev.exit {
                WorkerExit::Clean(stats) => self.finished.push(*stats),
                WorkerExit::Fatal(detail) | WorkerExit::Panicked(detail) => {
                    // Full crash accounting (breaker window included):
                    // a final crash racing the drain must still count
                    // toward retirement, or `retired` under-reports.
                    self.last_fault[ev.worker] = Some(detail);
                    self.note_crash(ev.worker);
                }
            }
        }
        SupervisorReport {
            finished: self.finished,
            crashes: self.crashes,
            restarts: self.restarts,
            retired: self
                .slots
                .iter()
                .filter(|s| matches!(s.health, Health::Retired))
                .count(),
            last_fault: self.last_fault,
        }
    }
}

/// What supervision saw over one cluster run.
#[derive(Debug, Default)]
// curlint: allow(dead-pub) -- the return type of Supervisor::shutdown; callers reach it through that method without naming the type
pub struct SupervisorReport {
    /// Final stats of every cleanly drained incarnation.
    pub finished: Vec<ServeStats>,
    pub crashes: usize,
    pub restarts: usize,
    pub retired: usize,
    pub last_fault: Vec<Option<String>>,
}

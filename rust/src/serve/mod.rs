//! Continuous-batching model server.
//!
//! A vLLM-style front for the compressed/original model variants with
//! two request kinds on one queue:
//!
//! * **Score** — classic batched evaluation: full sequences are grouped
//!   into model-batch-sized NLL calls with a wait-time cap. On backends
//!   that accept variable shapes (native) a partial batch is submitted
//!   at its true occupancy — no pad rows, no wasted compute; fixed-shape
//!   backends (pjrt) still pad and the waste is reported.
//! * **Generate** — greedy decoding over per-request KV-cache slots with
//!   **continuous batching**: a new request is admitted into any free
//!   slot mid-flight (one prefill, ever — the ring buffer rotates the
//!   sliding window with no recompute), every decode step is one fused
//!   single-position layer pass across all active slots
//!   ([`crate::backend::Backend::layer_decode_batch`]), the LM head runs
//!   against a pre-packed weight buffer, and each slot retires
//!   independently the moment its request completes. Slots honor a
//!   [`KvPolicy`]: the exact sliding-window ring, or CUR-compressed
//!   lanes (`--kv-policy cur:<keep>` on the CLI) that are compacted by
//!   value-guided position selection whenever they fill —
//!   [`ServeStats::kv_compactions`] and
//!   [`ServeStats::kv_live_bytes_mean`] report the effect.
//!
//! Backend handles are not `Send` (PJRT's xla handles, the native op
//! counter), so the server runs on the *calling* thread and clients are
//! spawned. The server exits when the request channel disconnects and
//! all queued work has drained — drop the last `Sender` to stop it —
//! or when a [`Request::Shutdown`] drains it gracefully.
//!
//! # Failure semantics
//!
//! The server never aborts on per-request trouble; every outcome is a
//! typed [`ServeError`] on the response:
//!
//! * **Request-level**: malformed input (empty prompt, out-of-vocab
//!   token, wrong scoring lengths) → [`ServeError::Rejected`]; a
//!   backend failure or non-finite logits confined to one request →
//!   [`ServeError::Failed`]; an elapsed deadline (queued or mid-decode)
//!   → [`ServeError::Timeout`] with any partial tokens; a full backlog
//!   at enqueue → [`ServeError::Overloaded`].
//! * **Slot-level**: a failed fused decode step is rolled back
//!   ([`KvCache::rollback_token`]) and re-run one slot at a time, so
//!   only the faulty slot's request fails; [`QUARANTINE_AFTER`]
//!   consecutive failures quarantine the slot (capacity shrinks,
//!   [`ServeStats::quarantined_slots`]).
//! * **Server-level**: [`Request::Shutdown`] stops admission (later
//!   requests get [`ServeError::ShuttingDown`]), finishes in-flight
//!   work, and sends the final [`ServeStats`] to the shutdown sender.
//!   Under memory/queue pressure a `cur` KV policy degrades (halves
//!   `keep`, down to [`DEGRADE_MAX_LEVEL`] steps) and restores when
//!   pressure clears — [`ServeStats::degraded_steps`] counts the trips.

use crate::backend::{Backend, KvCache, KvPolicy, PackedHead};
use crate::data::{Corpus, CorpusKind, Vocab};
use crate::pipeline::{greedy_token, LayerPlan, Pipeline};
use crate::tensor::{Tensor, TensorStore};
use crate::util::stats::percentile;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

pub mod cluster;
pub mod supervisor;

pub use cluster::{ClusterServer, WorkerRuntime};
pub use supervisor::{Supervisor, SupervisorConfig};

/// Why the server declined or failed a request. Every response carries
/// `Option<ServeError>` — `None` is success; anything else is typed so
/// clients can branch on the cause instead of parsing strings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Shed at enqueue: the backlog was at [`GenerationServer::queue_cap`].
    Overloaded { depth: usize, cap: usize },
    /// The request's deadline elapsed before it completed. A generation
    /// response still carries any tokens decoded before eviction.
    Timeout { deadline_ms: u64 },
    /// Malformed request (empty prompt, out-of-vocab token, wrong
    /// scoring lengths) — rejected before touching the backend.
    Rejected { reason: String },
    /// The backend failed this request (after any per-slot retry); the
    /// server kept serving everything else.
    Failed { detail: String },
    /// Received after a [`Request::Shutdown`] was accepted.
    ShuttingDown,
    /// Cluster only: every worker was retired by the circuit breaker —
    /// queued and later requests are answered with this instead of
    /// hanging on capacity that is permanently gone.
    AllWorkersRetired { retired: usize },
    /// Cluster only: the request was replayed after worker deaths until
    /// its retry budget ran out (`attempts` includes the first try).
    RetriesExhausted { attempts: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { depth, cap } => {
                write!(f, "overloaded: backlog {depth} at cap {cap}")
            }
            ServeError::Timeout { deadline_ms } => {
                write!(f, "deadline of {deadline_ms} ms elapsed")
            }
            ServeError::Rejected { reason } => write!(f, "rejected: {reason}"),
            ServeError::Failed { detail } => write!(f, "failed: {detail}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::AllWorkersRetired { retired } => {
                write!(f, "all {retired} cluster workers retired by the circuit breaker")
            }
            ServeError::RetriesExhausted { attempts } => {
                write!(f, "retry budget exhausted after {attempts} attempts on dying workers")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// A server-loop invariant that failed to hold — always an engine bug,
/// never a client error. Carried as the typed payload of the error that
/// aborts `run`, so supervisors can downcast and treat it as a crash
/// rather than a request-level failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct EngineInvariant(pub String);

impl std::fmt::Display for EngineInvariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine invariant violated: {}", self.0)
    }
}

impl std::error::Error for EngineInvariant {}

/// One scoring request: a full sequence (tokens + next-token targets).
pub struct ScoreRequest {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub enqueued: Instant,
    /// Per-request deadline; `None` falls back to the server default.
    pub deadline: Option<Duration>,
    pub respond: Sender<ScoreResponse>,
}

#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub mean_nll: f64,
    pub latency_ms: f64,
    /// `Some` when the request was declined or failed; `mean_nll` is
    /// NaN then. The server keeps serving.
    pub error: Option<ServeError>,
}

/// One generation request: a prompt to continue by `n_new` greedy
/// tokens. Token ids are identical to a standalone
/// [`Pipeline::generate_greedy`] / `generate_greedy_uncached` run on the
/// same prompt, regardless of what else shares the batch (tested).
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub n_new: usize,
    pub enqueued: Instant,
    /// Per-request deadline; `None` falls back to the server default.
    pub deadline: Option<Duration>,
    pub respond: Sender<GenResponse>,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub tokens: Vec<i32>,
    pub latency_ms: f64,
    /// `Some` when the server declined or could not finish this request;
    /// `tokens` holds whatever was decoded before the failure (empty on
    /// rejection). The server keeps serving other traffic either way.
    pub error: Option<ServeError>,
}

/// A request on the server's single intake queue.
pub enum Request {
    Score(ScoreRequest),
    Generate(GenRequest),
    /// Graceful drain: stop admitting, finish in-flight and queued
    /// work, then send the final [`ServeStats`] and exit. Requests that
    /// arrive after this one get [`ServeError::ShuttingDown`].
    Shutdown(Sender<ServeStats>),
}

/// Server-side metrics over one run.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Scoring requests answered.
    pub served: usize,
    pub batches: usize,
    pub mean_batch_occupancy: f64,
    /// Rows scored only to pad partial batches to the model batch size —
    /// wasted compute the occupancy numbers must own up to. Always 0 on
    /// variable-shape backends (native), which submit true occupancy.
    pub padded_rows: usize,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub throughput_seq_per_s: f64,
    /// Generation requests completed.
    pub gen_served: usize,
    /// Prompt prefills run — exactly one per admitted generation
    /// request, even when decoding runs far past the window-rotation
    /// boundary (the ring buffer never re-prefills).
    pub prefills: usize,
    pub tokens_generated: usize,
    /// Fused decode steps executed (each covers all active slots).
    pub decode_steps: usize,
    /// Mean number of active slots per decode step (slot occupancy).
    pub mean_active_slots: f64,
    pub tokens_per_s: f64,
    /// Per-token latency percentiles as a client observes them: the
    /// prefill duration for a request's first token, then the
    /// wall-clock gap between consecutive emissions — which includes
    /// any scoring batches or admissions interleaved between decode
    /// steps, not just the decode compute.
    pub tok_p50_ms: f64,
    pub tok_p95_ms: f64,
    /// KV-cache compactions run ([`crate::backend::Backend::compress_kv_slot`]).
    /// Always 0 under [`KvPolicy::Exact`].
    pub kv_compactions: usize,
    /// Mean bytes of K/V holding cached positions
    /// ([`KvCache::live_bytes`]), sampled after every decode step. Under
    /// a `cur` policy this sits below the exact-cache bound
    /// ([`KvCache::bytes`]) once lanes start compacting; 0 when no
    /// generation ran.
    pub kv_live_bytes_mean: f64,
    /// Requests shed at enqueue ([`ServeError::Overloaded`] /
    /// [`ServeError::ShuttingDown`]) — never admitted, not in
    /// `served`/`gen_served`.
    pub rejected: usize,
    /// Requests evicted with [`ServeError::Timeout`] — queued or
    /// mid-decode (the latter keep their partial tokens).
    pub timed_out: usize,
    /// Per-request backend failures absorbed ([`ServeError::Failed`]
    /// responses from slot isolation — the server kept serving).
    pub slot_failures: usize,
    /// Generation slots quarantined after [`QUARANTINE_AFTER`]
    /// consecutive failures (capacity shrank by this many lanes).
    pub quarantined_slots: usize,
    /// Times the degraded-mode controller stepped the `cur` KV `keep`
    /// ratio down under memory/queue pressure.
    pub degraded_steps: usize,
    /// Cluster only: worker incarnations that died — panic (e.g. an
    /// injected `crash` fault), fatal engine error, or missed
    /// heartbeat. Always 0 on a single engine.
    pub worker_crashes: usize,
    /// Cluster only: workers respawned after a crash (each waited out
    /// its exponential backoff first).
    pub worker_restarts: usize,
    /// Cluster only: requests re-queued to a healthy worker after
    /// their worker died mid-flight (counted per replay).
    pub retried_requests: usize,
    /// Cluster only: workers permanently retired by the circuit
    /// breaker (K crashes inside the sliding window).
    pub retired_workers: usize,
    pub wall_s: f64,
}

/// One in-flight generation: the request plus its decode state. The
/// KV-cache slot index is the position in the server's slot table.
struct GenSlot {
    req: GenRequest,
    generated: Vec<i32>,
    last: i32,
    /// When this slot last emitted a token (per-token latency base).
    last_emit: Instant,
    /// Resolved deadline (request's own, else the server default).
    deadline: Option<Duration>,
}

/// Consecutive per-slot request failures before the slot is
/// quarantined (capacity shrinks instead of burning every admission on
/// a lane the backend keeps failing).
pub const QUARANTINE_AFTER: usize = 3;
/// Max degraded-mode steps; each halves the `cur` KV `keep` ratio.
pub(crate) const DEGRADE_MAX_LEVEL: u32 = 3;
/// Live-KV fraction (of the allocation) above which — or a backlog at
/// ≥3/4 of `queue_cap` — degraded mode steps `keep` down.
pub(crate) const DEGRADE_HIGH_WATER: f64 = 0.85;
/// Live-KV fraction below which (with a cooled backlog) degraded mode
/// steps back toward the configured policy.
pub(crate) const DEGRADE_LOW_WATER: f64 = 0.60;

/// The server. `slots` bounds concurrent generations (the KV-cache
/// footprint: `n_layers × 2 × slots·seq·d_model × 4` bytes — see
/// [`KvCache`] for the full memory math); scoring batches are bounded
/// by the model config's batch size.
pub struct GenerationServer<'p> {
    /// The per-layer execution pipeline (model config + backend).
    pub pipe: &'p Pipeline<'p>,
    /// Weights served (original or CURed — any [`LayerPlan`] mix).
    pub store: &'p TensorStore,
    /// Per-layer dense/cured execution plan.
    pub plan: LayerPlan,
    /// Max time to wait before flushing a partial scoring batch.
    pub max_wait: Duration,
    /// Concurrent generation slots.
    pub slots: usize,
    /// KV-cache eviction policy for the generation slots:
    /// [`KvPolicy::Exact`] (the sliding-window ring) or
    /// [`KvPolicy::Cur`] (CUR-compressed lanes; full lanes are compacted
    /// transparently inside [`Pipeline::decode_step`], and
    /// [`ServeStats::kv_compactions`] / [`ServeStats::kv_live_bytes_mean`]
    /// report the effect). Scoring traffic is unaffected.
    pub kv_policy: KvPolicy,
    /// Default per-request deadline (admission *and* every decode
    /// iteration check it; a request's own `deadline` overrides).
    /// `None` means no deadline.
    pub deadline: Option<Duration>,
    /// Max queued-but-unadmitted requests (scores + generations
    /// combined) before enqueue sheds with [`ServeError::Overloaded`].
    /// `0` means unbounded.
    pub queue_cap: usize,
    /// Liveness hook, called once per server-loop iteration (so at
    /// least once per decode step and at least once per `max_wait` when
    /// idle). The cluster supervisor hangs its heartbeat here; `None`
    /// is a no-op for standalone servers.
    pub tick: Option<Box<dyn Fn()>>,
}

impl<'p> GenerationServer<'p> {
    /// Serve until the request channel disconnects and all accepted
    /// work has drained. Runs on the calling thread.
    pub fn run(&self, rx: Receiver<Request>) -> Result<ServeStats> {
        let cfg = &self.pipe.cfg;
        // Reject an unusable policy before accepting traffic — the
        // protected set must leave room to evict something.
        self.kv_policy.validate(cfg.seq)?;
        let n_slots = self.slots.max(1);
        let mut stats = ServeStats::default();
        let mut score_lat: Vec<f64> = Vec::new();
        let mut tok_lat: Vec<f64> = Vec::new();
        let mut slot_steps = 0usize;
        let mut kv_live_accum = 0.0f64;
        let t0 = Instant::now();
        let mut pending: Vec<ScoreRequest> = Vec::new();
        let mut queue: VecDeque<GenRequest> = VecDeque::new();
        let mut active: Vec<Option<GenSlot>> = (0..n_slots).map(|_| None).collect();
        let mut n_active = 0usize;
        // Robustness state: consecutive per-slot failures, quarantine
        // flags, the degraded-mode level, and graceful-drain senders.
        let mut fail_streak = vec![0usize; n_slots];
        let mut quarantined = vec![false; n_slots];
        let mut degrade_level: u32 = 0;
        let mut drain_notify: Vec<Sender<ServeStats>> = Vec::new();
        // Generation state, built lazily on the first Generate request.
        let mut kv: Option<KvCache> = None;
        let mut packed: Option<PackedHead> = None;
        let mut disconnected = false;
        loop {
            // ---- heartbeat first: a loop that still turns is alive,
            // whatever the queues hold.
            if let Some(beat) = &self.tick {
                beat();
            }
            // ---- intake. Block only as long as no work would stall:
            // not at all while decode slots are active or admissions/
            // flushes are due, until the oldest score's deadline while a
            // partial batch ages, for max_wait when fully idle.
            let block = if n_active > 0
                || !queue.is_empty()
                || disconnected
                || !drain_notify.is_empty()
                || pending.len() >= cfg.batch
            {
                Duration::ZERO
            } else if let Some(r) = pending.first() {
                // Wake for the flush-age cap or the earliest pending
                // score deadline, whichever lands first.
                let mut b = self.max_wait.saturating_sub(r.enqueued.elapsed());
                for s in &pending {
                    if let Some(d) = s.deadline.or(self.deadline) {
                        b = b.min(d.saturating_sub(s.enqueued.elapsed()));
                    }
                }
                b
            } else {
                self.max_wait
            };
            if block > Duration::ZERO {
                match rx.recv_timeout(block) {
                    Ok(r) => {
                        self.enqueue(r, &mut pending, &mut queue, &mut drain_notify, &mut stats)
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => disconnected = true,
                }
            }
            loop {
                match rx.try_recv() {
                    Ok(r) => {
                        self.enqueue(r, &mut pending, &mut queue, &mut drain_notify, &mut stats)
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            let draining = !drain_notify.is_empty();
            if (disconnected || draining)
                && n_active == 0
                && pending.is_empty()
                && queue.is_empty()
            {
                break;
            }
            // ---- evict queued generations whose deadline passed.
            queue.retain(|g| {
                let Some(ms) = Self::expired(g.enqueued, g.deadline.or(self.deadline)) else {
                    return true;
                };
                let _ = g.respond.send(GenResponse {
                    tokens: Vec::new(),
                    latency_ms: g.enqueued.elapsed().as_secs_f64() * 1e3,
                    error: Some(ServeError::Timeout { deadline_ms: ms }),
                });
                stats.timed_out += 1;
                stats.gen_served += 1;
                false
            });
            // ---- admit generation requests into free, healthy slots,
            // mid-flight (quarantined lanes are skipped — capacity has
            // shrunk by that many slots).
            loop {
                let usable = quarantined.iter().filter(|&&q| !q).count();
                if usable == 0 || n_active >= usable {
                    break;
                }
                let Some(req) = queue.pop_front() else { break };
                let deadline = req.deadline.or(self.deadline);
                if req.n_new == 0 {
                    // Zero tokens requested: trivially complete.
                    let _ = req.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: None,
                    });
                    stats.gen_served += 1;
                    continue;
                }
                if req.prompt.is_empty() {
                    // Invalid, not empty-success: there is nothing to
                    // condition on (every pipeline entry point rejects
                    // an empty prompt too).
                    let _ = req.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(ServeError::Rejected { reason: "empty prompt".to_string() }),
                    });
                    stats.gen_served += 1;
                    continue;
                }
                // Validate before touching a slot: a bad request must
                // never charge a lane's failure streak.
                if let Some(&t) =
                    req.prompt.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab)
                {
                    let _ = req.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(ServeError::Rejected {
                            reason: format!(
                                "prompt token {t} outside the vocabulary 0..{}",
                                cfg.vocab
                            ),
                        }),
                    });
                    stats.gen_served += 1;
                    continue;
                }
                // A scoring-only backend answers generation requests
                // with an error instead of aborting the server — other
                // traffic (and already-admitted work) keeps flowing.
                if kv.is_none() && !self.pipe.rt.backend().supports_kv_decode() {
                    let _ = req.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(ServeError::Failed {
                            detail: format!(
                                "generation needs a KV-decode backend \
                                 (backend '{}' is scoring-only)",
                                self.pipe.rt.backend().name()
                            ),
                        }),
                    });
                    stats.gen_served += 1;
                    continue;
                }
                if kv.is_none() {
                    kv = Some(KvCache::with_policy(
                        cfg.n_layers,
                        n_slots,
                        cfg.seq,
                        cfg.d_model,
                        self.kv_policy,
                    ));
                    packed = self.pipe.pack_head(self.store)?;
                }
                let slot = active
                    .iter()
                    .enumerate()
                    .position(|(i, s)| s.is_none() && !quarantined[i])
                    .ok_or_else(|| {
                        anyhow!(EngineInvariant(
                            "no free generation slot despite n_active < usable".into()
                        ))
                    })?;
                let kvm = kv
                    .as_mut()
                    .ok_or_else(|| anyhow!(EngineInvariant("kv cache missing at admission".into())))?;
                let tp = Instant::now();
                // A backend fault during prefill fails this request (and
                // charges the lane's streak) — it never takes down the
                // server or the other in-flight requests.
                let first = match self.pipe.prefill_slot(
                    self.store,
                    &self.plan,
                    kvm,
                    slot,
                    &req.prompt,
                    packed.as_ref(),
                ) {
                    Ok(t) => t,
                    Err(e) => {
                        kvm.reset_slot(slot);
                        let _ = req.respond.send(GenResponse {
                            tokens: Vec::new(),
                            latency_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
                            error: Some(ServeError::Failed { detail: format!("{e:#}") }),
                        });
                        stats.gen_served += 1;
                        stats.slot_failures += 1;
                        fail_streak[slot] += 1;
                        if fail_streak[slot] >= QUARANTINE_AFTER && !quarantined[slot] {
                            quarantined[slot] = true;
                            stats.quarantined_slots += 1;
                        }
                        continue;
                    }
                };
                stats.prefills += 1;
                stats.tokens_generated += 1;
                tok_lat.push(tp.elapsed().as_secs_f64() * 1e3);
                let gs = GenSlot {
                    req,
                    generated: vec![first],
                    last: first,
                    last_emit: Instant::now(),
                    deadline,
                };
                if gs.generated.len() >= gs.req.n_new {
                    Self::retire(gs, &mut stats);
                } else {
                    active[slot] = Some(gs);
                    n_active += 1;
                }
            }
            // With every lane quarantined nothing can ever decode —
            // answer queued generations instead of letting them hang.
            if !queue.is_empty() && quarantined.iter().all(|&q| q) {
                for g in queue.drain(..) {
                    let _ = g.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: g.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(ServeError::Failed {
                            detail: "all generation slots quarantined".to_string(),
                        }),
                    });
                    stats.gen_served += 1;
                }
            }
            // ---- time out pending scores past their deadline.
            pending.retain(|r| {
                let Some(ms) = Self::expired(r.enqueued, r.deadline.or(self.deadline)) else {
                    return true;
                };
                let _ = r.respond.send(ScoreResponse {
                    mean_nll: f64::NAN,
                    latency_ms: r.enqueued.elapsed().as_secs_f64() * 1e3,
                    error: Some(ServeError::Timeout { deadline_ms: ms }),
                });
                stats.timed_out += 1;
                false
            });
            // ---- flush a scoring batch when full, aged, or input done.
            let flush = !pending.is_empty()
                && (pending.len() >= cfg.batch
                    || disconnected
                    || draining
                    || pending[0].enqueued.elapsed() >= self.max_wait);
            if flush {
                self.score_batch(&mut pending, &mut stats, &mut score_lat)?;
            }
            // ---- evict active slots whose deadline passed; the client
            // gets whatever tokens were decoded before the cutoff.
            if n_active > 0 {
                for slot in 0..n_slots {
                    let hit = match &active[slot] {
                        Some(gs) => Self::expired(gs.req.enqueued, gs.deadline),
                        None => None,
                    };
                    let Some(ms) = hit else { continue };
                    let Some(gs) = active[slot].take() else { continue };
                    n_active -= 1;
                    if let Some(kvm) = kv.as_mut() {
                        kvm.reset_slot(slot);
                    }
                    let _ = gs.req.respond.send(GenResponse {
                        tokens: gs.generated,
                        latency_ms: gs.req.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(ServeError::Timeout { deadline_ms: ms }),
                    });
                    stats.timed_out += 1;
                    stats.gen_served += 1;
                }
            }
            // ---- one fused decode step across all active slots, with
            // per-slot fault isolation.
            if n_active > 0 {
                let kvm = kv.as_mut().ok_or_else(|| {
                    anyhow!(EngineInvariant("kv cache missing with active slots".into()))
                })?;
                let mut slot_ids = Vec::with_capacity(n_active);
                let mut last = Vec::with_capacity(n_active);
                for (i, s) in active.iter().enumerate() {
                    if let Some(gs) = s {
                        slot_ids.push(i);
                        last.push(gs.last);
                    }
                }
                // Full CUR lanes must compact before the layer pass; a
                // compaction failure costs only that slot's request.
                let mut i = 0;
                while i < slot_ids.len() {
                    match self.pipe.compact_slot(kvm, slot_ids[i]) {
                        Ok(_) => i += 1,
                        Err(e) => {
                            Self::fail_slot(
                                slot_ids[i],
                                &mut active,
                                &mut n_active,
                                kvm,
                                &mut stats,
                                &mut fail_streak,
                                &mut quarantined,
                                &e,
                            );
                            slot_ids.remove(i);
                            last.remove(i);
                        }
                    }
                }
                // Hidden pass. A fused failure may have pushed partial
                // position-map entries for every slot in the batch, so
                // all are rolled back and each slot re-runs alone — the
                // kernels emit identical rows at any batch shape, so
                // survivors stay bit-exact and only the faulty slot's
                // request fails.
                let d = cfg.d_model;
                let mut hid: Vec<(usize, Vec<f32>)> = Vec::with_capacity(slot_ids.len());
                if !slot_ids.is_empty() {
                    match self.pipe.decode_hidden(self.store, &self.plan, kvm, &slot_ids, &last)
                    {
                        Ok(x) => {
                            let data = x.f32s()?;
                            for (i, &slot) in slot_ids.iter().enumerate() {
                                hid.push((slot, data[i * d..(i + 1) * d].to_vec()));
                            }
                        }
                        Err(_) => {
                            for &slot in &slot_ids {
                                kvm.rollback_token(slot);
                            }
                            for (&slot, &lt) in slot_ids.iter().zip(&last) {
                                match self.pipe.decode_hidden(
                                    self.store,
                                    &self.plan,
                                    kvm,
                                    &[slot],
                                    &[lt],
                                ) {
                                    Ok(x) => hid.push((slot, x.f32s()?.to_vec())),
                                    Err(e) => Self::fail_slot(
                                        slot,
                                        &mut active,
                                        &mut n_active,
                                        kvm,
                                        &mut stats,
                                        &mut fail_streak,
                                        &mut quarantined,
                                        &e,
                                    ),
                                }
                            }
                        }
                    }
                }
                // Head + greedy pick. A fused head failure retries one
                // row at a time; non-finite logits (NaN/Inf corruption)
                // fail only the poisoned slot — token 0 is never
                // silently emitted.
                let v = cfg.vocab;
                let mut picked: Vec<(usize, Result<i32>)> = Vec::with_capacity(hid.len());
                if !hid.is_empty() {
                    let mut flat = Vec::with_capacity(hid.len() * d);
                    for (_, row) in &hid {
                        flat.extend_from_slice(row);
                    }
                    let xt = Tensor::from_f32(&[hid.len(), 1, d], flat);
                    match self.pipe.head_rows(self.store, &xt, packed.as_ref()) {
                        Ok(logits) => {
                            let data = logits.f32s()?;
                            for (i, (slot, _)) in hid.iter().enumerate() {
                                picked.push((*slot, greedy_token(&data[i * v..(i + 1) * v])));
                            }
                        }
                        Err(_) => {
                            for (slot, row) in &hid {
                                let xt1 = Tensor::from_f32(&[1, 1, d], row.clone());
                                let r = self
                                    .pipe
                                    .head_rows(self.store, &xt1, packed.as_ref())
                                    .and_then(|lg| greedy_token(&lg.f32s()?[..v]));
                                picked.push((*slot, r));
                            }
                        }
                    }
                }
                if !slot_ids.is_empty() {
                    stats.decode_steps += 1;
                    slot_steps += slot_ids.len();
                    kv_live_accum += kvm.live_bytes() as f64;
                }
                let now = Instant::now();
                let mut advanced: Vec<usize> = Vec::with_capacity(picked.len());
                let mut emitted: Vec<(usize, i32)> = Vec::with_capacity(picked.len());
                for (slot, r) in picked {
                    match r {
                        Ok(t) => {
                            advanced.push(slot);
                            emitted.push((slot, t));
                        }
                        Err(e) => Self::fail_slot(
                            slot,
                            &mut active,
                            &mut n_active,
                            kvm,
                            &mut stats,
                            &mut fail_streak,
                            &mut quarantined,
                            &e,
                        ),
                    }
                }
                // Only survivors advance — failed slots were fully
                // reset, so the step never half-commits.
                kvm.advance(&advanced);
                for (slot, tok) in emitted {
                    fail_streak[slot] = 0;
                    let done = {
                        let gs = active[slot].as_mut().ok_or_else(|| {
                            anyhow!(EngineInvariant(format!(
                                "decode step touched an empty slot {slot}"
                            )))
                        })?;
                        gs.generated.push(tok);
                        gs.last = tok;
                        // What the client sees between two tokens: the
                        // decode step plus anything interleaved since
                        // this slot's previous emission (scoring
                        // batches, admissions of other requests).
                        tok_lat.push(now.duration_since(gs.last_emit).as_secs_f64() * 1e3);
                        gs.last_emit = now;
                        gs.generated.len() >= gs.req.n_new
                    };
                    stats.tokens_generated += 1;
                    if done {
                        let gs = active[slot].take().ok_or_else(|| {
                            anyhow!(EngineInvariant(format!("finished slot {slot} already empty")))
                        })?;
                        n_active -= 1;
                        // Release the lane immediately so live-KV stats
                        // count only in-flight requests (admission would
                        // reset it anyway).
                        kvm.reset_slot(slot);
                        Self::retire(gs, &mut stats);
                    }
                }
            }
            // ---- degraded mode: under memory or queue pressure a cur
            // policy halves its keep ratio (down to DEGRADE_MAX_LEVEL
            // steps) and walks back up once pressure clears.
            if let KvPolicy::Cur { keep, sinks, recent } = self.kv_policy {
                if let Some(kvm) = kv.as_mut() {
                    let live = kvm.live_bytes() as f64 / kvm.bytes().max(1) as f64;
                    let backlog = queue.len() + pending.len();
                    let queue_hot = self.queue_cap > 0 && backlog * 4 >= self.queue_cap * 3;
                    let queue_cool = self.queue_cap == 0 || backlog * 2 <= self.queue_cap;
                    if (live >= DEGRADE_HIGH_WATER || queue_hot)
                        && degrade_level < DEGRADE_MAX_LEVEL
                    {
                        degrade_level += 1;
                        stats.degraded_steps += 1;
                        kvm.policy = Self::degraded_policy(keep, sinks, recent, degrade_level);
                    } else if live <= DEGRADE_LOW_WATER && queue_cool && degrade_level > 0 {
                        degrade_level -= 1;
                        kvm.policy = Self::degraded_policy(keep, sinks, recent, degrade_level);
                    }
                }
            }
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        if stats.batches > 0 {
            stats.mean_batch_occupancy /= stats.batches as f64;
        }
        if stats.decode_steps > 0 {
            stats.mean_active_slots = slot_steps as f64 / stats.decode_steps as f64;
            stats.kv_live_bytes_mean = kv_live_accum / stats.decode_steps as f64;
        }
        if let Some(kvm) = &kv {
            stats.kv_compactions = kvm.compactions;
        }
        stats.p50_latency_ms = percentile(&score_lat, 50.0);
        stats.p95_latency_ms = percentile(&score_lat, 95.0);
        stats.tok_p50_ms = percentile(&tok_lat, 50.0);
        stats.tok_p95_ms = percentile(&tok_lat, 95.0);
        stats.throughput_seq_per_s = stats.served as f64 / stats.wall_s.max(1e-9);
        stats.tokens_per_s = stats.tokens_generated as f64 / stats.wall_s.max(1e-9);
        // A graceful drain always ends in a stats report, whatever
        // happened on the way down.
        for tx in drain_notify {
            let _ = tx.send(stats.clone());
        }
        Ok(stats)
    }

    /// Intake with admission control: once a drain began every new
    /// request is answered [`ServeError::ShuttingDown`], and with a
    /// `queue_cap` a full backlog sheds with [`ServeError::Overloaded`]
    /// — both immediately, bumping [`ServeStats::rejected`].
    fn enqueue(
        &self,
        r: Request,
        pending: &mut Vec<ScoreRequest>,
        queue: &mut VecDeque<GenRequest>,
        drain_notify: &mut Vec<Sender<ServeStats>>,
        stats: &mut ServeStats,
    ) {
        let backlog = pending.len() + queue.len();
        let shed = if !drain_notify.is_empty() {
            Some(ServeError::ShuttingDown)
        } else if self.queue_cap > 0 && backlog >= self.queue_cap {
            Some(ServeError::Overloaded { depth: backlog, cap: self.queue_cap })
        } else {
            None
        };
        match r {
            Request::Shutdown(tx) => drain_notify.push(tx),
            Request::Score(s) => match shed {
                Some(e) => {
                    let _ = s.respond.send(ScoreResponse {
                        mean_nll: f64::NAN,
                        latency_ms: s.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(e),
                    });
                    stats.rejected += 1;
                }
                None => pending.push(s),
            },
            Request::Generate(g) => match shed {
                Some(e) => {
                    let _ = g.respond.send(GenResponse {
                        tokens: Vec::new(),
                        latency_ms: g.enqueued.elapsed().as_secs_f64() * 1e3,
                        error: Some(e),
                    });
                    stats.rejected += 1;
                }
                None => queue.push_back(g),
            },
        }
    }

    /// `Some(deadline_ms)` when `deadline` has elapsed since `enqueued`.
    fn expired(enqueued: Instant, deadline: Option<Duration>) -> Option<u64> {
        deadline.filter(|d| enqueued.elapsed() >= *d).map(|d| d.as_millis() as u64)
    }

    /// The `cur` policy at degraded-mode `level`: each level halves the
    /// configured keep ratio, floored at 0.05 (the protected sinks and
    /// recent positions always survive compaction regardless).
    fn degraded_policy(keep: f32, sinks: usize, recent: usize, level: u32) -> KvPolicy {
        KvPolicy::Cur { keep: (keep * 0.5f32.powi(level as i32)).max(0.05), sinks, recent }
    }

    /// Fail one in-flight generation: answer the client with a typed
    /// [`ServeError::Failed`] (keeping any tokens decoded so far), free
    /// the lane, and charge the slot's failure streak — at
    /// [`QUARANTINE_AFTER`] consecutive failures the lane is
    /// quarantined and serving capacity shrinks.
    #[allow(clippy::too_many_arguments)]
    fn fail_slot(
        slot: usize,
        active: &mut [Option<GenSlot>],
        n_active: &mut usize,
        kvm: &mut KvCache,
        stats: &mut ServeStats,
        fail_streak: &mut [usize],
        quarantined: &mut [bool],
        err: &anyhow::Error,
    ) {
        let Some(gs) = active[slot].take() else { return };
        *n_active -= 1;
        kvm.reset_slot(slot);
        let _ = gs.req.respond.send(GenResponse {
            tokens: gs.generated,
            latency_ms: gs.req.enqueued.elapsed().as_secs_f64() * 1e3,
            error: Some(ServeError::Failed { detail: format!("{err:#}") }),
        });
        stats.gen_served += 1;
        stats.slot_failures += 1;
        fail_streak[slot] += 1;
        if fail_streak[slot] >= QUARANTINE_AFTER && !quarantined[slot] {
            quarantined[slot] = true;
            stats.quarantined_slots += 1;
        }
    }

    fn retire(gs: GenSlot, stats: &mut ServeStats) {
        let latency_ms = gs.req.enqueued.elapsed().as_secs_f64() * 1e3;
        let _ = gs
            .req
            .respond
            .send(GenResponse { tokens: gs.generated, latency_ms, error: None });
        stats.gen_served += 1;
    }

    /// Score one batch off the pending queue. Variable-shape backends
    /// (native) run exactly the occupied rows; fixed-shape backends pad
    /// by repeating the last request and the waste is accounted.
    fn score_batch(
        &self,
        pending: &mut Vec<ScoreRequest>,
        stats: &mut ServeStats,
        latencies: &mut Vec<f64>,
    ) -> Result<()> {
        let cfg = &self.pipe.cfg;
        let (b, s) = (cfg.batch, cfg.seq);
        // Answer malformed requests individually (wrong sequence
        // length would panic Tensor::from_i32 below and take the whole
        // server down with it).
        pending.retain(|r| {
            let ok = r.tokens.len() == s && r.targets.len() == s;
            if !ok {
                let _ = r.respond.send(ScoreResponse {
                    mean_nll: f64::NAN,
                    latency_ms: r.enqueued.elapsed().as_secs_f64() * 1e3,
                    error: Some(ServeError::Rejected {
                        reason: format!(
                            "scoring needs tokens/targets of length {s}, got {}/{}",
                            r.tokens.len(),
                            r.targets.len()
                        ),
                    }),
                });
            }
            ok
        });
        if pending.is_empty() {
            return Ok(());
        }
        let occupancy = pending.len().min(b);
        let rows = if self.pipe.rt.backend().fixed_shape() { b } else { occupancy };
        let mut toks = Vec::with_capacity(rows * s);
        let mut tgts = Vec::with_capacity(rows * s);
        for i in 0..rows {
            let r = &pending[i.min(occupancy - 1)];
            toks.extend_from_slice(&r.tokens);
            tgts.extend_from_slice(&r.targets);
        }
        let tokens = Tensor::from_i32(&[rows, s], toks);
        let targets = Tensor::from_i32(&[rows, s], tgts);
        let means: Vec<Result<f64>> =
            match self.pipe.nll(self.store, &self.plan, &tokens, &targets) {
                Ok(nll) => {
                    let nll_data = nll.f32s()?;
                    (0..occupancy)
                        .map(|i| {
                            let row = &nll_data[i * s..(i + 1) * s];
                            Ok(row.iter().map(|&x| x as f64).sum::<f64>() / s as f64)
                        })
                        .collect()
                }
                // The fused batch call failed: re-score each request
                // alone so only the one(s) the backend actually fails
                // lose their response.
                Err(_) => (0..occupancy).map(|i| self.score_one(&pending[i])).collect(),
            };
        for (req, mean) in pending.drain(..occupancy).zip(means) {
            let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            match mean {
                Ok(m) if m.is_finite() => {
                    latencies.push(latency_ms);
                    let _ = req
                        .respond
                        .send(ScoreResponse { mean_nll: m, latency_ms, error: None });
                    stats.served += 1;
                }
                // A non-finite mean (NaN/Inf corruption in the NLL row)
                // is a typed failure, never a silent garbage score.
                Ok(m) => {
                    let _ = req.respond.send(ScoreResponse {
                        mean_nll: f64::NAN,
                        latency_ms,
                        error: Some(ServeError::Failed {
                            detail: format!("non-finite mean NLL {m}"),
                        }),
                    });
                }
                Err(e) => {
                    let _ = req.respond.send(ScoreResponse {
                        mean_nll: f64::NAN,
                        latency_ms,
                        error: Some(ServeError::Failed { detail: format!("{e:#}") }),
                    });
                }
            }
        }
        stats.batches += 1;
        stats.mean_batch_occupancy += occupancy as f64;
        stats.padded_rows += rows - occupancy;
        Ok(())
    }

    /// Score a single request — the per-request retry path of
    /// [`GenerationServer::score_batch`]'s fused-failure branch.
    fn score_one(&self, req: &ScoreRequest) -> Result<f64> {
        let cfg = &self.pipe.cfg;
        let s = cfg.seq;
        let rows = if self.pipe.rt.backend().fixed_shape() { cfg.batch } else { 1 };
        let mut toks = Vec::with_capacity(rows * s);
        let mut tgts = Vec::with_capacity(rows * s);
        for _ in 0..rows {
            toks.extend_from_slice(&req.tokens);
            tgts.extend_from_slice(&req.targets);
        }
        let tokens = Tensor::from_i32(&[rows, s], toks);
        let targets = Tensor::from_i32(&[rows, s], tgts);
        let nll = self.pipe.nll(self.store, &self.plan, &tokens, &targets)?;
        let row = &nll.f32s()?[..s];
        Ok(row.iter().map(|&x| x as f64).sum::<f64>() / s as f64)
    }
}

/// Shared client-thread scaffold: `n_clients` detached threads, each
/// with its own corpus stream seeded `seed_base + client`, submitting
/// `per_client` requests built by `build` through `tx` with `think_ms`
/// spacing. Returns the per-client response receivers (client threads
/// detach and exit, and their `Sender` clones drop with them).
fn spawn_request_clients<R, F>(
    tx: &Sender<Request>,
    vocab: &Vocab,
    kind: CorpusKind,
    seed_base: u64,
    n_clients: usize,
    per_client: usize,
    think_ms: u64,
    build: F,
) -> Vec<Receiver<R>>
where
    R: Send + 'static,
    F: Fn(&mut Corpus, &Vocab, Sender<R>) -> Request + Clone + Send + 'static,
{
    let mut resp_rxs = Vec::new();
    for c in 0..n_clients {
        let (rtx, rrx) = channel::<R>();
        resp_rxs.push(rrx);
        let tx = tx.clone();
        let vocab = vocab.clone();
        let build = build.clone();
        std::thread::spawn(move || {
            let mut corpus = Corpus::new(kind, seed_base + c as u64);
            for _ in 0..per_client {
                if tx.send(build(&mut corpus, &vocab, rtx.clone())).is_err() {
                    return;
                }
                if think_ms > 0 {
                    std::thread::sleep(Duration::from_millis(think_ms));
                }
            }
        });
    }
    resp_rxs
}

/// Spawn `n_clients` threads each submitting `per_client` corpus-drawn
/// scoring requests through `tx` with `think_ms` spacing.
pub fn spawn_score_clients(
    tx: &Sender<Request>,
    vocab: &Vocab,
    kind: CorpusKind,
    seq: usize,
    n_clients: usize,
    per_client: usize,
    think_ms: u64,
) -> Vec<Receiver<ScoreResponse>> {
    spawn_request_clients(tx, vocab, kind, 9000, n_clients, per_client, think_ms, move |corpus, vocab, respond| {
        let s = corpus.sequence(vocab, seq + 1);
        Request::Score(ScoreRequest {
            tokens: s[..seq].to_vec(),
            targets: s[1..seq + 1].to_vec(),
            enqueued: Instant::now(),
            deadline: None,
            respond,
        })
    })
}

/// Spawn `n_clients` threads each submitting `per_client` generation
/// requests (`prompt_len` corpus tokens, `n_new` tokens to decode).
pub fn spawn_gen_clients(
    tx: &Sender<Request>,
    vocab: &Vocab,
    kind: CorpusKind,
    prompt_len: usize,
    n_new: usize,
    n_clients: usize,
    per_client: usize,
    think_ms: u64,
) -> Vec<Receiver<GenResponse>> {
    spawn_request_clients(tx, vocab, kind, 7000, n_clients, per_client, think_ms, move |corpus, vocab, respond| {
        Request::Generate(GenRequest {
            prompt: corpus.sequence(vocab, prompt_len),
            n_new,
            enqueued: Instant::now(),
            deadline: None,
            respond,
        })
    })
}

/// Scoring-only convenience: a fresh channel with `n_clients` scoring
/// clients on it. The originating `Sender` is dropped before returning,
/// so the receiver disconnects — and the server exits — exactly when
/// the last client thread finishes.
#[cfg(test)]
fn spawn_clients(
    vocab: &Vocab,
    kind: CorpusKind,
    seq: usize,
    n_clients: usize,
    per_client: usize,
    think_ms: u64,
) -> (Receiver<Request>, Vec<Receiver<ScoreResponse>>) {
    let (tx, rx) = channel::<Request>();
    let resp_rxs = spawn_score_clients(&tx, vocab, kind, seq, n_clients, per_client, think_ms);
    drop(tx);
    (rx, resp_rxs)
}

/// Per-request outcomes of a client fleet, split by typed
/// [`ServeError`] — so callers of [`spawn_score_clients`] /
/// [`spawn_gen_clients`] can count retries, timeouts and shed requests
/// instead of only reading the successful payloads.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
// curlint: allow(dead-pub) -- the result type of the pub client-fleet helpers here; harness code destructures it through them without naming it
pub struct ClientTally {
    pub ok: usize,
    pub overloaded: usize,
    pub timed_out: usize,
    pub rejected: usize,
    pub failed: usize,
    pub shutting_down: usize,
    pub all_retired: usize,
    pub retries_exhausted: usize,
}

impl ClientTally {
    pub fn count(&mut self, error: &Option<ServeError>) {
        match error {
            None => self.ok += 1,
            Some(ServeError::Overloaded { .. }) => self.overloaded += 1,
            Some(ServeError::Timeout { .. }) => self.timed_out += 1,
            Some(ServeError::Rejected { .. }) => self.rejected += 1,
            Some(ServeError::Failed { .. }) => self.failed += 1,
            Some(ServeError::ShuttingDown) => self.shutting_down += 1,
            Some(ServeError::AllWorkersRetired { .. }) => self.all_retired += 1,
            Some(ServeError::RetriesExhausted { .. }) => self.retries_exhausted += 1,
        }
    }

    /// All responses seen, whatever the outcome.
    pub fn total(&self) -> usize {
        self.ok
            + self.overloaded
            + self.timed_out
            + self.rejected
            + self.failed
            + self.shutting_down
            + self.all_retired
            + self.retries_exhausted
    }

    /// Responses that carried any error.
    pub fn errored(&self) -> usize {
        self.total() - self.ok
    }
}

impl std::fmt::Display for ClientTally {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ok={}", self.ok)?;
        for (n, label) in [
            (self.overloaded, "overloaded"),
            (self.timed_out, "timeout"),
            (self.rejected, "rejected"),
            (self.failed, "failed"),
            (self.shutting_down, "shutting-down"),
            (self.all_retired, "all-retired"),
            (self.retries_exhausted, "retries-exhausted"),
        ] {
            if n > 0 {
                write!(f, " {label}={n}")?;
            }
        }
        Ok(())
    }
}

/// Drain every generation response from a client fleet (call after the
/// server run returns, when all response senders have dropped) and
/// tally the outcomes. Uses `try_iter`, which is equivalent to a
/// blocking drain once the senders are gone — and degrades to a short
/// read instead of a hang if a caller breaks that contract.
pub fn drain_gen_responses(rxs: &[Receiver<GenResponse>]) -> (Vec<GenResponse>, ClientTally) {
    let mut out = Vec::new();
    let mut tally = ClientTally::default();
    for rx in rxs {
        for resp in rx.try_iter() {
            tally.count(&resp.error);
            out.push(resp);
        }
    }
    (out, tally)
}

/// Scoring twin of [`drain_gen_responses`].
pub fn drain_score_responses(
    rxs: &[Receiver<ScoreResponse>],
) -> (Vec<ScoreResponse>, ClientTally) {
    let mut out = Vec::new();
    let mut tally = ClientTally::default();
    for rx in rxs {
        for resp in rx.try_iter() {
            tally.count(&resp.error);
            out.push(resp);
        }
    }
    (out, tally)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::Pipeline;

    fn mini_setup() -> (crate::runtime::Runtime, crate::model::ModelConfig, TensorStore) {
        let rt = crate::runtime::Runtime::native();
        let cfg = crate::model::ModelConfig::from_manifest(rt.manifest(), "mini").unwrap();
        let mut rng = crate::util::Rng::new(31, 0);
        let store = cfg.init_dense(&mut rng);
        (rt, cfg, store)
    }

    #[test]
    fn native_scoring_submits_true_occupancy() {
        // 3 requests on batch=2: the native backend accepts variable
        // shapes, so the odd request runs as a 1-row batch — zero pad
        // rows — and the server exits on client disconnect without
        // being told an expected count.
        let (rt, cfg, store) = mini_setup();
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let vocab = Vocab::build();
        let (rx, resps) = spawn_clients(&vocab, CorpusKind::SynthC4, cfg.seq, 3, 1, 0);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: LayerPlan::all_dense(&cfg),
            max_wait: Duration::from_millis(20),
            slots: 1,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx).unwrap();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.padded_rows, 0, "variable-shape backend must not pad");
        assert!(stats.batches >= 2, "3 requests cannot fit one batch of {}", cfg.batch);
        for r in resps {
            let resp = r.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(resp.mean_nll.is_finite());
        }
    }

    #[test]
    fn client_threads_produce_requests() {
        let vocab = Vocab::build();
        let (rx, _resp) = spawn_clients(&vocab, CorpusKind::SynthC4, 16, 2, 3, 0);
        let mut n = 0;
        // The channel disconnects by itself once both clients finish —
        // iteration ends without a count or a timeout race.
        while let Ok(req) = rx.recv_timeout(Duration::from_secs(5)) {
            let Request::Score(req) = req else { panic!("scoring clients sent gen") };
            assert_eq!(req.tokens.len(), 16);
            assert_eq!(req.targets.len(), 16);
            n += 1;
        }
        assert_eq!(n, 6);
    }

    #[test]
    fn continuous_batching_matches_uncached_reference() {
        // Five requests with ragged prompts onto three slots, decoding
        // well past the window-rotation boundary (prompt + n_new >
        // seq): every response must be token-identical to a standalone
        // cache-free reference run of its own prompt, each request must
        // have been prefilled exactly once (rotation never re-prefills),
        // and the slots must actually have overlapped.
        let (rt, cfg, store) = mini_setup();
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let plan = LayerPlan::all_dense(&cfg);
        let n_new = cfg.seq + 4;
        let prompts: Vec<Vec<i32>> = vec![
            vec![1, 5, 9],
            vec![2, 3, 4, 7, 8],
            vec![1, 2],
            vec![9, 8, 7, 6, 5, 4, 3],
            vec![1, 30, 60],
        ];
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let mut resp_rxs = Vec::new();
        for p in &prompts {
            let (rtx, rrx) = std::sync::mpsc::channel::<GenResponse>();
            resp_rxs.push(rrx);
            tx.send(Request::Generate(GenRequest {
                prompt: p.clone(),
                n_new,
                enqueued: Instant::now(),
                deadline: None,
                respond: rtx,
            }))
            .unwrap();
        }
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: plan.clone(),
            max_wait: Duration::from_millis(10),
            slots: 3,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx).unwrap();
        assert_eq!(stats.gen_served, prompts.len());
        assert_eq!(stats.prefills, prompts.len(), "exactly one prefill per request");
        assert_eq!(stats.tokens_generated, prompts.len() * n_new);
        assert!(
            stats.mean_active_slots > 1.0,
            "slots never overlapped (mean {})",
            stats.mean_active_slots
        );
        for (p, rrx) in prompts.iter().zip(resp_rxs) {
            let resp = rrx.recv_timeout(Duration::from_secs(30)).unwrap();
            let want = pipe
                .generate_greedy_uncached(&store, &plan, &[p.clone()], n_new)
                .unwrap();
            assert_eq!(resp.tokens, want[0], "continuous batching diverged for {p:?}");
            assert!(resp.latency_ms >= 0.0);
        }
    }

    #[test]
    fn cur_kv_policy_serves_mixed_traffic_with_smaller_cache() {
        // Compressed KV cache end-to-end: generation requests decoding
        // well past the window under cur:0.5 — lanes compact
        // (kv_compactions > 0), the mean live cache stays below the
        // exact-cache bound, and every request still completes with the
        // full token count, with scoring traffic interleaved on the
        // same queue.
        let (rt, cfg, store) = mini_setup();
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let vocab = Vocab::build();
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let n_new = 2 * cfg.seq; // well past the high-water mark
        let score_resps =
            spawn_score_clients(&tx, &vocab, CorpusKind::SynthC4, cfg.seq, 1, 2, 1);
        let gen_resps =
            spawn_gen_clients(&tx, &vocab, CorpusKind::SynthC4, 6, n_new, 2, 1, 1);
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: LayerPlan::all_dense(&cfg),
            max_wait: Duration::from_millis(10),
            slots: 2,
            kv_policy: KvPolicy::Cur { keep: 0.5, sinks: 2, recent: 4 },
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx).unwrap();
        assert_eq!(stats.gen_served, 2);
        assert_eq!(stats.served, 2);
        assert_eq!(stats.prefills, 2, "compaction must never re-prefill");
        assert!(stats.kv_compactions > 0, "lanes never compacted");
        let exact_bound =
            (2 * KvCache::exact_slot_bound(cfg.n_layers, cfg.seq, cfg.d_model)) as f64;
        assert!(
            stats.kv_live_bytes_mean > 0.0 && stats.kv_live_bytes_mean < exact_bound,
            "mean live KV {} not below the exact bound {exact_bound}",
            stats.kv_live_bytes_mean
        );
        for r in gen_resps {
            let resp = r.recv_timeout(Duration::from_secs(30)).unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.tokens.len(), n_new);
        }
        for r in score_resps {
            while let Ok(resp) = r.recv_timeout(Duration::from_secs(5)) {
                assert!(resp.mean_nll.is_finite());
            }
        }
    }

    /// Native math behind the trait's *defaults*: `fixed_shape` stays
    /// true and `supports_kv_decode` stays false, standing in for an
    /// AOT artifact backend — drives the pad branch of `score_batch`
    /// and the scoring-only generation error path, which CI otherwise
    /// never exercises against the server.
    struct FixedShapeNative(crate::backend::native::NativeBackend);

    impl crate::backend::Backend for FixedShapeNative {
        fn name(&self) -> &'static str {
            "fixed-native"
        }
        fn manifest(&self) -> &crate::util::Json {
            self.0.manifest()
        }
        fn exec_count(&self) -> u64 {
            self.0.exec_count()
        }
        fn embed(
            &self,
            cfg: &crate::model::ModelConfig,
            emb: &Tensor,
            tokens: &Tensor,
        ) -> anyhow::Result<Tensor> {
            self.0.embed(cfg, emb, tokens)
        }
        fn layer_forward(
            &self,
            cfg: &crate::model::ModelConfig,
            p: &crate::backend::LayerParams,
            x: &Tensor,
        ) -> anyhow::Result<Tensor> {
            self.0.layer_forward(cfg, p, x)
        }
        fn layer_forward_calib(
            &self,
            cfg: &crate::model::ModelConfig,
            p: &crate::backend::LayerParams,
            x: &Tensor,
        ) -> anyhow::Result<crate::backend::CalibOut> {
            self.0.layer_forward_calib(cfg, p, x)
        }
        fn head_logits(
            &self,
            cfg: &crate::model::ModelConfig,
            x: &Tensor,
            ln_f: &Tensor,
            emb: &Tensor,
        ) -> anyhow::Result<Tensor> {
            self.0.head_logits(cfg, x, ln_f, emb)
        }
        fn head_nll(
            &self,
            cfg: &crate::model::ModelConfig,
            x: &Tensor,
            ln_f: &Tensor,
            emb: &Tensor,
            targets: &Tensor,
        ) -> anyhow::Result<Tensor> {
            self.0.head_nll(cfg, x, ln_f, emb, targets)
        }
        fn train_step(
            &self,
            cfg: &crate::model::ModelConfig,
            store: &mut TensorStore,
            opt: &mut TensorStore,
            tokens: &Tensor,
            targets: &Tensor,
            lr: f32,
            t: f32,
        ) -> anyhow::Result<f64> {
            self.0.train_step(cfg, store, opt, tokens, targets, lr, t)
        }
        fn heal_step(
            &self,
            cfg: &crate::model::ModelConfig,
            student: &mut TensorStore,
            opt: &mut TensorStore,
            layer: usize,
            x: &Tensor,
            y_teacher: &Tensor,
            lr: f32,
            t: f32,
        ) -> anyhow::Result<crate::backend::HealOut> {
            self.0.heal_step(cfg, student, opt, layer, x, y_teacher, lr, t)
        }
    }

    #[test]
    fn fixed_shape_backend_pads_and_rejects_generation() {
        // Fixed-shape scoring must pad partial batches (and own up to
        // the waste), extract only real rows — each response's NLL
        // equals an independent native run of that sequence — and a
        // Generate request must come back as an error response, not a
        // server abort.
        let rt = crate::runtime::Runtime::from_backend(Box::new(FixedShapeNative(
            crate::backend::native::NativeBackend::new(),
        )));
        let cfg = crate::model::ModelConfig::from_manifest(rt.manifest(), "mini").unwrap();
        let mut rng = crate::util::Rng::new(31, 0);
        let store = cfg.init_dense(&mut rng);
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let vocab = Vocab::build();
        let mut corpus = Corpus::new(CorpusKind::SynthC4, 500);
        let n_req = 3usize; // odd on batch=2: forces one pad row
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let mut seqs = Vec::new();
        let mut score_rxs = Vec::new();
        for _ in 0..n_req {
            let s = corpus.sequence(&vocab, cfg.seq + 1);
            let (rtx, rrx) = std::sync::mpsc::channel::<ScoreResponse>();
            tx.send(Request::Score(ScoreRequest {
                tokens: s[..cfg.seq].to_vec(),
                targets: s[1..cfg.seq + 1].to_vec(),
                enqueued: Instant::now(),
                deadline: None,
                respond: rtx,
            }))
            .unwrap();
            seqs.push(s);
            score_rxs.push(rrx);
        }
        let (gtx, grx) = std::sync::mpsc::channel::<GenResponse>();
        tx.send(Request::Generate(GenRequest {
            prompt: vec![1, 2, 3],
            n_new: 4,
            enqueued: Instant::now(),
            deadline: None,
            respond: gtx,
        }))
        .unwrap();
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: LayerPlan::all_dense(&cfg),
            max_wait: Duration::from_millis(10),
            slots: 2,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx).unwrap();
        assert_eq!(stats.served, n_req);
        assert_eq!(
            stats.padded_rows,
            stats.batches * cfg.batch - n_req,
            "fixed-shape pad accounting"
        );
        assert!(stats.padded_rows >= 1, "3 requests on batch=2 must pad");
        assert_eq!(stats.gen_served, 1);
        assert_eq!(stats.prefills, 0, "scoring-only backend must never prefill");
        let gen = grx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(gen.tokens.is_empty());
        assert!(gen.error.is_some(), "generation on a non-KV backend must error");
        // Pad extraction correctness: each real row's NLL matches an
        // independent single-row native run of the same sequence.
        let native_rt = crate::runtime::Runtime::native();
        let native_pipe = Pipeline { rt: &native_rt, cfg: cfg.clone() };
        let plan = LayerPlan::all_dense(&cfg);
        for (s, rrx) in seqs.iter().zip(score_rxs) {
            let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
            let tokens = Tensor::from_i32(&[1, cfg.seq], s[..cfg.seq].to_vec());
            let targets = Tensor::from_i32(&[1, cfg.seq], s[1..cfg.seq + 1].to_vec());
            let nll = native_pipe.nll(&store, &plan, &tokens, &targets).unwrap();
            let want = nll.f32s().unwrap().iter().map(|&x| x as f64).sum::<f64>()
                / cfg.seq as f64;
            assert!(
                (resp.mean_nll - want).abs() < 1e-5 * (1.0 + want.abs()),
                "padded-batch NLL diverged: {} vs {want}",
                resp.mean_nll
            );
        }
    }

    #[test]
    fn mixed_score_and_generate_traffic() {
        let (rt, cfg, store) = mini_setup();
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let vocab = Vocab::build();
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        let score_resps =
            spawn_score_clients(&tx, &vocab, CorpusKind::SynthC4, cfg.seq, 2, 2, 1);
        let gen_resps =
            spawn_gen_clients(&tx, &vocab, CorpusKind::SynthC4, 6, 8, 2, 1, 1);
        drop(tx);
        let server = GenerationServer {
            pipe: &pipe,
            store: &store,
            plan: LayerPlan::all_dense(&cfg),
            max_wait: Duration::from_millis(15),
            slots: 2,
            kv_policy: KvPolicy::Exact,
            deadline: None,
            queue_cap: 0,
            tick: None,
        };
        let stats = server.run(rx).unwrap();
        assert_eq!(stats.served, 4);
        assert_eq!(stats.gen_served, 2);
        assert_eq!(stats.tokens_generated, 2 * 8);
        for r in score_resps {
            while let Ok(resp) = r.recv_timeout(Duration::from_secs(5)) {
                assert!(resp.mean_nll.is_finite());
            }
        }
        for r in gen_resps {
            let resp = r.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(resp.tokens.len(), 8);
        }
    }
}

//! Threaded batching evaluation server.
//!
//! A vLLM-router-style front for the compressed/original model variants:
//! client threads submit single-sequence scoring requests; the server
//! (which owns the runtime — backend handles are not `Send` (PJRT's xla
//! handles, the native backend's op counter), so the server runs on the
//! *calling* thread and clients are spawned) groups them into
//! model-batch-sized backend calls with a wait-time cap, and reports
//! latency/throughput/occupancy statistics. The native backend fans each
//! batched matmul across cores, so batching still buys throughput.

use crate::data::{Corpus, CorpusKind, Vocab};
use crate::pipeline::{LayerPlan, Pipeline};
use crate::tensor::{Tensor, TensorStore};
use crate::util::stats::percentile;
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One scoring request: a full sequence (tokens + next-token targets).
pub struct Request {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub enqueued: Instant,
    pub respond: Sender<Response>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub mean_nll: f64,
    pub latency_ms: f64,
}

/// Server-side metrics over one run.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: usize,
    pub batches: usize,
    pub mean_batch_occupancy: f64,
    /// Rows scored only to pad partial batches to the model batch size —
    /// wasted compute the occupancy numbers must own up to.
    pub padded_rows: usize,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub throughput_seq_per_s: f64,
    pub wall_s: f64,
}

pub struct BatchingServer<'p> {
    pub pipe: &'p Pipeline<'p>,
    pub store: &'p TensorStore,
    pub plan: LayerPlan,
    /// Max time to wait for a full batch before flushing a partial one.
    pub max_wait: Duration,
}

impl<'p> BatchingServer<'p> {
    /// Serve until `n_expected` requests have been answered (or the
    /// channel closes). Runs on the calling thread.
    pub fn run(&self, rx: Receiver<Request>, n_expected: usize) -> Result<ServeStats> {
        let cfg = &self.pipe.cfg;
        let (b, s) = (cfg.batch, cfg.seq);
        let mut latencies = Vec::new();
        let mut stats = ServeStats::default();
        let t0 = Instant::now();
        let mut pending: Vec<Request> = Vec::new();
        while stats.served < n_expected {
            // Fill a batch (bounded wait).
            let deadline = Instant::now() + self.max_wait;
            while pending.len() < b {
                let now = Instant::now();
                if now >= deadline && !pending.is_empty() {
                    break;
                }
                let timeout = deadline.saturating_duration_since(now).max(Duration::from_millis(1));
                match rx.recv_timeout(timeout) {
                    Ok(req) => pending.push(req),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if !pending.is_empty() {
                            break;
                        }
                        if stats.served >= n_expected {
                            break;
                        }
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            if pending.is_empty() {
                break;
            }
            let occupancy = pending.len().min(b);
            // Pad a partial batch by repeating the last pending request;
            // pad rows are counted as waste and never extracted below.
            let mut toks = Vec::with_capacity(b * s);
            let mut tgts = Vec::with_capacity(b * s);
            for i in 0..b {
                let r = &pending[i.min(pending.len() - 1)];
                toks.extend_from_slice(&r.tokens);
                tgts.extend_from_slice(&r.targets);
            }
            let tokens = Tensor::from_i32(&[b, s], toks);
            let targets = Tensor::from_i32(&[b, s], tgts);
            let nll = self.pipe.nll(self.store, &self.plan, &tokens, &targets)?;
            let nll_data = nll.f32s()?;
            // Response extraction touches only the real rows; rows
            // occupancy..b were pad duplicates.
            for (i, req) in pending.drain(..).take(occupancy).enumerate() {
                let row = &nll_data[i * s..(i + 1) * s];
                let mean = row.iter().map(|&x| x as f64).sum::<f64>() / s as f64;
                let latency_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
                latencies.push(latency_ms);
                let _ = req.respond.send(Response { mean_nll: mean, latency_ms });
                stats.served += 1;
            }
            stats.batches += 1;
            stats.mean_batch_occupancy += occupancy as f64;
            stats.padded_rows += b - occupancy;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        if stats.batches > 0 {
            stats.mean_batch_occupancy /= stats.batches as f64;
        }
        stats.p50_latency_ms = percentile(&latencies, 50.0);
        stats.p95_latency_ms = percentile(&latencies, 95.0);
        stats.throughput_seq_per_s = stats.served as f64 / stats.wall_s.max(1e-9);
        Ok(stats)
    }
}

/// Spawn `n_clients` threads each submitting `per_client` corpus-drawn
/// requests with `think_ms` spacing; returns the request receiver plus
/// the response receivers (client threads detach and exit on their own).
pub fn spawn_clients(
    vocab: &Vocab,
    kind: CorpusKind,
    seq: usize,
    n_clients: usize,
    per_client: usize,
    think_ms: u64,
) -> (Receiver<Request>, Vec<Receiver<Response>>) {
    let (tx, rx) = channel::<Request>();
    let mut resp_rxs = Vec::new();
    for c in 0..n_clients {
        let (rtx, rrx) = channel::<Response>();
        resp_rxs.push(rrx);
        let tx = tx.clone();
        let vocab = vocab.clone();
        std::thread::spawn(move || {
            let mut corpus = Corpus::new(kind, 9000 + c as u64);
            for _ in 0..per_client {
                let s = corpus.sequence(&vocab, seq + 1);
                let req = Request {
                    tokens: s[..seq].to_vec(),
                    targets: s[1..seq + 1].to_vec(),
                    enqueued: Instant::now(),
                    respond: rtx.clone(),
                };
                if tx.send(req).is_err() {
                    return;
                }
                if think_ms > 0 {
                    std::thread::sleep(Duration::from_millis(think_ms));
                }
            }
        });
    }
    (rx, resp_rxs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_reports_pad_waste() {
        let rt = crate::runtime::Runtime::native();
        let cfg = crate::model::ModelConfig::from_manifest(rt.manifest(), "mini").unwrap();
        let mut rng = crate::util::Rng::new(31, 0);
        let store = cfg.init_dense(&mut rng);
        let pipe = Pipeline { rt: &rt, cfg: cfg.clone() };
        let vocab = Vocab::build();
        let (rx, _resps) = spawn_clients(&vocab, CorpusKind::SynthC4, cfg.seq, 3, 1, 0);
        let server = BatchingServer {
            pipe: &pipe,
            store: &store,
            plan: LayerPlan::all_dense(&cfg),
            max_wait: Duration::from_millis(20),
        };
        let stats = server.run(rx, 3).unwrap();
        assert_eq!(stats.served, 3);
        // Every batch is cfg.batch rows; whatever was not a real request
        // was a pad duplicate and must be reported as waste.
        assert_eq!(stats.padded_rows, stats.batches * cfg.batch - stats.served);
        assert!(stats.padded_rows >= 1, "3 requests on batch=2 must pad at least one row");
    }

    #[test]
    fn client_threads_produce_requests() {
        let vocab = Vocab::build();
        let (rx, _resp) = spawn_clients(&vocab, CorpusKind::SynthC4, 16, 2, 3, 0);
        let mut n = 0;
        while let Ok(req) = rx.recv_timeout(Duration::from_secs(5)) {
            assert_eq!(req.tokens.len(), 16);
            assert_eq!(req.targets.len(), 16);
            n += 1;
            if n == 6 {
                break;
            }
        }
        assert_eq!(n, 6);
    }
}

//! Host-side tensors and the on-disk tensor store.
//!
//! `Tensor` is the host currency of the coordinator: row-major f32 or i32
//! data plus a shape. The store persists named tensors (model weights,
//! optimizer state, CUR factors) as one little-endian binary blob per
//! tensor plus a JSON index — Python never touches these files; weights
//! are born and live on the Rust side.

use crate::util::{Json, JsonObj};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn tag(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn from_tag(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype tag {other}"),
        }
    }
}

/// Row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: Data::F32(vec![0.0; shape.iter().product()]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        // curlint: allow(hot-path-purity) -- copies the <=4-element shape slice; the data buffer itself is moved, not copied
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor { shape: vec![], data: Data::F32(vec![x]) }
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32s(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn f32s_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn i32s(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Frobenius norm (f32 tensors).
    pub fn fro_norm(&self) -> f64 {
        match &self.data {
            Data::F32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
            Data::I32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * 4
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.len() * 4);
        match &self.data {
            Data::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        out
    }

    fn from_bytes(shape: Vec<usize>, dtype: DType, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("expected {} bytes for shape {:?}, got {}", n * 4, shape, bytes.len());
        }
        let t = match dtype {
            DType::F32 => {
                let v: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor { shape, data: Data::F32(v) }
            }
            DType::I32 => {
                let v: Vec<i32> = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor { shape, data: Data::I32(v) }
            }
        };
        Ok(t)
    }
}

/// A named collection of tensors, persistable to a directory.
#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    tensors: BTreeMap<String, Tensor>,
    /// Free-form metadata persisted alongside (config name, step, notes).
    pub meta: BTreeMap<String, String>,
}

impl TensorStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("tensor '{name}' not in store"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.tensors.get_mut(name).ok_or_else(|| anyhow!("tensor '{name}' not in store"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.tensors.remove(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total parameter count of f32 tensors (the "model size" number).
    /// i32 tensors (token buffers, index maps) are bookkeeping, not model
    /// parameters, and must not inflate compression-ratio numbers.
    pub fn total_params(&self) -> usize {
        self.tensors.values().filter(|t| t.dtype() == DType::F32).map(|t| t.len()).sum()
    }

    pub fn total_bytes(&self) -> usize {
        self.tensors.values().map(|t| t.size_bytes()).sum()
    }

    /// Persist to `dir/index.json` + `dir/<mangled>.bin`.
    ///
    /// Every file is written to a sibling temp file and `rename`d into
    /// place (atomic within a directory), so a crash mid-save leaves
    /// the previous version intact, never a truncated blob; and each
    /// entry records an FNV-1a checksum of its blob bytes that
    /// [`TensorStore::load`] verifies — a half-written compress/heal
    /// checkpoint can never load as silently wrong weights.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut index = JsonObj::new();
        let mut meta = JsonObj::new();
        for (k, v) in &self.meta {
            meta.insert(k.clone(), Json::Str(v.clone()));
        }
        index.insert("meta", Json::Obj(meta));
        let mut entries = JsonObj::new();
        for (name, t) in &self.tensors {
            let file = format!("{}.bin", mangle(name));
            let bytes = t.to_bytes();
            write_atomic(dir, &file, &bytes)
                .with_context(|| format!("tensor '{name}': writing {file}"))?;
            let mut e = JsonObj::new();
            e.insert("file", Json::Str(file));
            e.insert("dtype", Json::Str(t.dtype().tag().to_string()));
            e.insert(
                "shape",
                Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
            // Hex string, not Json::Num: the full u64 range does not
            // survive an f64 round-trip.
            e.insert("fnv1a64", Json::Str(format!("{:016x}", fnv1a64(&bytes))));
            entries.insert(name.clone(), Json::Obj(e));
        }
        index.insert("tensors", Json::Obj(entries));
        // The index goes last, atomically too: it only ever names blobs
        // that are already fully on disk.
        write_atomic(dir, "index.json", Json::Obj(index).to_string_pretty().as_bytes())?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<TensorStore> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("no tensor store at {}", dir.display()))?;
        let idx = Json::parse(&text)?;
        let mut store = TensorStore::new();
        if let Some(meta) = idx.at(&["meta"]).and_then(|m| m.as_obj()) {
            for (k, v) in meta.iter() {
                if let Some(s) = v.as_str() {
                    store.meta.insert(k.to_string(), s.to_string());
                }
            }
        }
        let entries = idx
            .at(&["tensors"])
            .and_then(|t| t.as_obj())
            .ok_or_else(|| anyhow!("index.json missing 'tensors'"))?;
        for (name, e) in entries.iter() {
            let file = e
                .at(&["file"])
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("tensor '{name}': index entry missing 'file'"))?;
            let dtag = e
                .at(&["dtype"])
                .and_then(|d| d.as_str())
                .ok_or_else(|| anyhow!("tensor '{name}': index entry missing 'dtype'"))?;
            let dtype = DType::from_tag(dtag)
                .with_context(|| format!("tensor '{name}': bad dtype tag"))?;
            let shape_json = e
                .at(&["shape"])
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("tensor '{name}': index entry missing 'shape'"))?;
            let mut shape = Vec::with_capacity(shape_json.len());
            for d in shape_json {
                shape.push(
                    d.as_usize()
                        .ok_or_else(|| anyhow!("tensor '{name}': non-integer shape entry"))?,
                );
            }
            let mut bytes = Vec::new();
            std::fs::File::open(dir.join(file))
                .with_context(|| format!("tensor '{name}': cannot open {file}"))?
                .read_to_end(&mut bytes)?;
            // Verify the recorded checksum before trusting the bytes.
            // Stores written before checksums existed carry no
            // `fnv1a64` entry and still load.
            if let Some(sum) = e.at(&["fnv1a64"]).and_then(|s| s.as_str()) {
                let expected = u64::from_str_radix(sum, 16).with_context(|| {
                    format!("tensor '{name}': malformed checksum '{sum}' in index.json")
                })?;
                let actual = fnv1a64(&bytes);
                if actual != expected {
                    return Err(anyhow::Error::new(StoreCorruption {
                        name: name.to_string(),
                        file: file.to_string(),
                        expected,
                        actual,
                    }));
                }
            }
            store.insert(
                name,
                Tensor::from_bytes(shape, dtype, &bytes)
                    .with_context(|| format!("tensor '{name}': corrupt blob {file}"))?,
            );
        }
        Ok(store)
    }
}

/// The typed error [`TensorStore::load`] raises when a blob's bytes do
/// not hash to the checksum its `index.json` entry records — corruption
/// (truncation, bit rot, a concurrent writer) detected before the
/// tensor can be used. Downcast from the anyhow chain to branch on it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreCorruption {
    pub name: String,
    pub file: String,
    pub expected: u64,
    pub actual: u64,
}

impl std::fmt::Display for StoreCorruption {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tensor '{}' is corrupt: {} hashes to {:016x}, index records {:016x}",
            self.name, self.file, self.actual, self.expected
        )
    }
}

impl std::error::Error for StoreCorruption {}

/// FNV-1a, 64-bit — the store's blob checksum. Not cryptographic;
/// chosen because it is tiny, dependency-free, and byte-order stable.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write `bytes` to `dir/file` via a sibling temp file + `rename`.
/// Readers only ever observe a complete file; a crash between the two
/// steps leaves at worst an orphaned `.tmp` next to the intact old
/// version (the checksum in the index catches anything subtler).
fn write_atomic(dir: &Path, file: &str, bytes: &[u8]) -> Result<()> {
    let tmp = dir.join(format!("{file}.tmp"));
    std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?
        .write_all(bytes)?;
    std::fs::rename(&tmp, dir.join(file))
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Filesystem-safe, *injective* name mangling. Alphanumerics and '-' pass
/// through; every other character (including '_', so `L0.w_q` and
/// `L0_w_q` cannot collide on disk) becomes `_XXXXXX` with the fixed
/// 6-hex-digit code point. `load` never inverts this — index.json records
/// file names.
fn mangle(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '-' {
            out.push(c);
        } else {
            out.push_str(&format!("_{:06x}", c as u32));
        }
    }
    out
}

/// Resolve a store path under the run directory.
pub fn store_path(root: &Path, name: &str) -> PathBuf {
    root.join("stores").join(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_store() {
        let dir = std::env::temp_dir().join(format!("curing_store_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = TensorStore::new();
        s.insert("L0.w_q", Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        s.insert("tokens", Tensor::from_i32(&[4], vec![1, 2, 3, 4]));
        s.meta.insert("config".into(), "tiny".into());
        s.save(&dir).unwrap();
        let s2 = TensorStore::load(&dir).unwrap();
        assert_eq!(s2.get("L0.w_q").unwrap(), s.get("L0.w_q").unwrap());
        assert_eq!(s2.get("tokens").unwrap(), s.get("tokens").unwrap());
        assert_eq!(s2.meta.get("config").map(|s| s.as_str()), Some("tiny"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fro_norm() {
        let t = Tensor::from_f32(&[2, 2], vec![3.0, 0.0, 0.0, 4.0]);
        assert!((t.fro_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn shape_mismatch_panics() {
        let r = std::panic::catch_unwind(|| Tensor::from_f32(&[2, 2], vec![1.0]));
        assert!(r.is_err());
    }

    #[test]
    fn byte_roundtrip_preserves_bits() {
        let t = Tensor::from_f32(&[3], vec![f32::MIN_POSITIVE, -0.0, 1e30]);
        let b = t.to_bytes();
        let t2 = Tensor::from_bytes(vec![3], DType::F32, &b).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn total_params_counts_only_f32() {
        let mut s = TensorStore::new();
        s.insert("w", Tensor::from_f32(&[2, 3], vec![0.0; 6]));
        s.insert("tokens", Tensor::from_i32(&[100], vec![0; 100]));
        // The i32 token buffer must not inflate the "model size" number.
        assert_eq!(s.total_params(), 6);
        // total_bytes still accounts for everything persisted.
        assert_eq!(s.total_bytes(), (6 + 100) * 4);
    }

    #[test]
    fn mangle_is_injective_for_colliding_names() {
        assert_ne!(mangle("L0.w_q"), mangle("L0_w_q"));
        assert_ne!(mangle("a.b"), mangle("a_b"));
        assert_ne!(mangle("a..b"), mangle("a._b"));
        // Plain alphanumerics and '-' stay readable.
        assert_eq!(mangle("emb-v2"), "emb-v2");
    }

    #[test]
    fn colliding_names_roundtrip_without_overwrite() {
        let dir =
            std::env::temp_dir().join(format!("curing_mangle_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = TensorStore::new();
        s.insert("L0.w_q", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        s.insert("L0_w_q", Tensor::from_f32(&[2], vec![3.0, 4.0]));
        s.save(&dir).unwrap();
        let s2 = TensorStore::load(&dir).unwrap();
        assert_eq!(s2.get("L0.w_q").unwrap().f32s().unwrap(), &[1.0, 2.0]);
        assert_eq!(s2.get("L0_w_q").unwrap().f32s().unwrap(), &[3.0, 4.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_rejects_malformed_index_gracefully() {
        let dir =
            std::env::temp_dir().join(format!("curing_badstore_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Entry with a missing file field must error, not panic.
        std::fs::write(
            dir.join("index.json"),
            r#"{"meta": {}, "tensors": {"w": {"dtype": "f32", "shape": [2]}}}"#,
        )
        .unwrap();
        let err = TensorStore::load(&dir).unwrap_err();
        assert!(err.to_string().contains("file"), "err: {err:#}");
        // Unknown dtype tag must error, not panic.
        std::fs::write(
            dir.join("index.json"),
            r#"{"tensors": {"w": {"file": "w.bin", "dtype": "f16", "shape": [2]}}}"#,
        )
        .unwrap();
        assert!(TensorStore::load(&dir).is_err());
        // Truncated blob must error, not panic.
        std::fs::write(
            dir.join("index.json"),
            r#"{"tensors": {"w": {"file": "w.bin", "dtype": "f32", "shape": [2]}}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("w.bin"), [0u8; 3]).unwrap();
        assert!(TensorStore::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_leaves_no_temp_files_and_records_checksums() {
        let dir =
            std::env::temp_dir().join(format!("curing_atomic_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = TensorStore::new();
        s.insert("w", Tensor::from_f32(&[2], vec![1.0, 2.0]));
        s.save(&dir).unwrap();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name();
            let name = name.to_string_lossy().to_string();
            assert!(!name.ends_with(".tmp"), "stray temp file {name} after save");
        }
        let index = std::fs::read_to_string(dir.join("index.json")).unwrap();
        assert!(index.contains("fnv1a64"), "index records no checksums:\n{index}");
        // Saving over an existing store replaces files in place.
        s.save(&dir).unwrap();
        assert_eq!(TensorStore::load(&dir).unwrap().get("w").unwrap(), s.get("w").unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_blob_fails_load_with_typed_error() {
        let dir =
            std::env::temp_dir().join(format!("curing_corrupt_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = TensorStore::new();
        s.insert("w", Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]));
        s.save(&dir).unwrap();
        // Flip one byte of the blob, keeping its length valid — only
        // the checksum can catch this.
        let blob = dir.join(format!("{}.bin", mangle("w")));
        let mut bytes = std::fs::read(&blob).unwrap();
        bytes[1] ^= 0x40;
        std::fs::write(&blob, &bytes).unwrap();
        let err = TensorStore::load(&dir).unwrap_err();
        let corrupt = err
            .downcast_ref::<StoreCorruption>()
            .unwrap_or_else(|| panic!("expected StoreCorruption, got: {err:#}"));
        assert_eq!(corrupt.name, "w");
        assert_ne!(corrupt.expected, corrupt.actual);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}

//! Experiment coordinator: the high-level building blocks every example,
//! bench and CLI command composes — pretraining the "original" model,
//! cached calibration, compression, healing, and the four-metric
//! evaluation suite of paper Figure 4.

use crate::backend::Backend;
use crate::calib::{calibrate, Calibration};
use crate::compress::{cure_layers, select_layers, CompressOptions, CompressReport, LayerStrategy};
use crate::data::{self, Corpus, CorpusKind, Vocab};
use crate::heal::cosine_lr;
use crate::pipeline::{LayerPlan, Pipeline};
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorStore};
use crate::util::{Json, Rng};
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Shared context: runtime + vocabulary + a run directory for stores.
pub struct Ctx {
    pub rt: Runtime,
    pub vocab: Vocab,
    pub root: PathBuf,
}

/// The four-metric evaluation of paper Figure 4.
#[derive(Debug, Clone, Default)]
pub struct Suite {
    pub c4_ppl: f64,
    pub wiki_ppl: f64,
    pub boolq_acc: f64,
    pub mmlu_acc: f64,
}

impl Suite {
    pub fn row(&self) -> String {
        format!(
            "c4_ppl {:>8.2}  wiki_ppl {:>8.2}  boolq {:>6.3}  mmlu {:>6.3}",
            self.c4_ppl, self.wiki_ppl, self.boolq_acc, self.mmlu_acc
        )
    }
}

/// Evaluation workload sizes (kept small — every extra batch is a full
/// pipeline pass on one CPU core; bump for final numbers).
#[derive(Debug, Clone)]
pub struct EvalSizes {
    pub ppl_batches: usize,
    pub boolq_items: usize,
    pub mmlu_items: usize,
}

impl Default for EvalSizes {
    fn default() -> Self {
        EvalSizes { ppl_batches: 4, boolq_items: 32, mmlu_items: 32 }
    }
}

impl Ctx {
    pub fn new() -> Result<Ctx> {
        Ctx::with_runtime(Runtime::open_default()?, &crate::util::config::run_dir())
    }

    /// Build a context over an explicit runtime and run directory (tests
    /// and embedding callers; `new` reads the environment instead).
    pub fn with_runtime(rt: Runtime, root: &Path) -> Result<Ctx> {
        let ctx = Ctx { rt, vocab: Vocab::build(), root: root.to_path_buf() };
        std::fs::create_dir_all(&ctx.root)?;
        Ok(ctx)
    }

    pub fn pipeline(&self, config: &str) -> Result<Pipeline<'_>> {
        Pipeline::new(&self.rt, config)
    }

    fn store_dir(&self, name: &str) -> PathBuf {
        self.root.join("stores").join(name)
    }

    /// Pretrain a dense model with the backend's train step; returns the
    /// weight store and the loss curve.
    pub fn pretrain(
        &self,
        config: &str,
        steps: usize,
        base_lr: f64,
        seed: u64,
        log: &mut dyn FnMut(usize, f64),
    ) -> Result<(TensorStore, Vec<f64>)> {
        let pipe = self.pipeline(config)?;
        let cfg = &pipe.cfg;
        let mut rng = Rng::new(seed, 0x7261_494e); // "traiN"
        let mut store = cfg.init_dense(&mut rng);
        let mut opt = TensorStore::new();
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_PRETRAIN);
        let mut losses = Vec::with_capacity(steps);
        let warmup = (steps / 10).max(1);
        for step in 0..steps {
            let lr = cosine_lr(step, steps, base_lr, warmup);
            // 30% task-format sequences: the eval suite's QA/choice
            // formats must appear in pretraining (DESIGN.md §2).
            let (toks, tgts) = corpus.batch_mixed(&self.vocab, cfg.batch, cfg.seq, 0.3);
            let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
            let targets = Tensor::from_i32(&[cfg.batch, cfg.seq], tgts);
            let loss = self.rt.backend().train_step(
                cfg,
                &mut store,
                &mut opt,
                &tokens,
                &targets,
                lr as f32,
                (step + 1) as f32,
            )?;
            losses.push(loss);
            log(step, loss);
        }
        store.meta.insert("pretrain_steps".into(), steps.to_string());
        Ok((store, losses))
    }

    /// Load the cached pretrained model or train it now (one-time cost,
    /// shared by every experiment).
    pub fn load_or_pretrain(&self, config: &str, steps: usize) -> Result<TensorStore> {
        let dir = self.store_dir(&format!("{config}_dense_{steps}"));
        if dir.join("index.json").exists() {
            return TensorStore::load(&dir);
        }
        eprintln!("[coordinator] pretraining {config} for {steps} steps (cached afterwards)...");
        let mut last = 0.0;
        let (store, losses) = self.pretrain(config, steps, 1e-3, 42, &mut |s, l| {
            last = l;
            if s % 50 == 0 {
                eprintln!("  pretrain step {s}: loss {l:.4}");
            }
        })?;
        eprintln!("  final loss {last:.4}");
        store.save(&dir)?;
        let curve = Json::Arr(losses.iter().map(|&l| Json::Num(l)).collect());
        std::fs::write(dir.join("loss_curve.json"), curve.to_string())?;
        Ok(store)
    }

    /// Calibration with on-disk cache (paper default 128 examples).
    pub fn calibrate_cached(
        &self,
        pipe: &Pipeline,
        store: &TensorStore,
        n_examples: usize,
    ) -> Result<Calibration> {
        let key = format!(
            "{}_calib_{}_{}.json",
            pipe.cfg.name,
            n_examples,
            store.meta.get("pretrain_steps").cloned().unwrap_or_default()
        );
        let path = self.root.join(key);
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(j) = Json::parse(&text) {
                return Calibration::from_json(&j);
            }
        }
        let mut corpus = Corpus::new(CorpusKind::SynthC4, data::SEED_CALIB);
        let calib = calibrate(pipe, store, &self.vocab, &mut corpus, n_examples)?;
        std::fs::write(&path, calib.to_json().to_string_pretty())?;
        Ok(calib)
    }

    /// Compress `k` layers: returns the cured store + plan + report.
    pub fn compress_k(
        &self,
        pipe: &Pipeline,
        dense: &TensorStore,
        calib: &Calibration,
        k: usize,
        strategy: LayerStrategy,
        opts: &CompressOptions,
    ) -> Result<(TensorStore, LayerPlan, CompressReport)> {
        let mut rng = Rng::new(opts.seed, 0x5E1E); // layer-selection stream
        let layers = select_layers(&pipe.cfg, calib, k, strategy, &mut rng)?;
        let mut student = dense.clone();
        let report = cure_layers(&mut student, &pipe.cfg, calib, &layers, opts)?;
        let plan = LayerPlan::with_cured(&pipe.cfg, &layers, report_rank(&report), &opts.combo);
        Ok((student, plan, report))
    }

    /// Figure 4 evaluation suite over both corpora and both tasks.
    pub fn eval_suite(
        &self,
        pipe: &Pipeline,
        store: &TensorStore,
        plan: &LayerPlan,
        sizes: &EvalSizes,
    ) -> Result<Suite> {
        let mut c4 = Corpus::new(CorpusKind::SynthC4, data::SEED_EVAL);
        let mut wiki = Corpus::new(CorpusKind::SynthWiki, data::SEED_EVAL);
        let mut rng = Rng::new(data::SEED_EVAL, 0xE7A1);
        let boolq: Vec<_> = (0..sizes.boolq_items)
            .map(|_| data::boolq_item(&self.vocab, &mut rng, pipe.cfg.seq))
            .collect();
        let mmlu: Vec<_> = (0..sizes.mmlu_items)
            .map(|_| data::mmlu_item(&self.vocab, &mut rng, pipe.cfg.seq))
            .collect();
        Ok(Suite {
            c4_ppl: crate::eval::perplexity(pipe, store, plan, &self.vocab, &mut c4, sizes.ppl_batches)?,
            wiki_ppl: crate::eval::perplexity(pipe, store, plan, &self.vocab, &mut wiki, sizes.ppl_batches)?,
            boolq_acc: crate::eval::choice_accuracy(pipe, store, plan, &boolq)?,
            mmlu_acc: crate::eval::choice_accuracy(pipe, store, plan, &mmlu)?,
        })
    }

    /// Persist a JSON experiment record under the run dir.
    pub fn write_record(&self, name: &str, j: &Json) -> Result<PathBuf> {
        let dir = self.root.join("records");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.json"));
        std::fs::write(&path, j.to_string_pretty())?;
        Ok(path)
    }
}

fn report_rank(report: &CompressReport) -> usize {
    report.weights.first().map(|w| w.rank).unwrap_or(16)
}

/// The default pretraining length used by all experiments (one-time,
/// cached). Override with CURING_PRETRAIN_STEPS.
pub fn default_pretrain_steps() -> usize {
    crate::util::config::pretrain_steps_override().unwrap_or(400)
}

/// Resolve an artifacts+runs context rooted at the repo (examples/benches
/// run from the workspace root).
pub fn open_ctx() -> Result<Ctx> {
    Ctx::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_steps_env_override() {
        // No env set in tests: default.
        assert!(default_pretrain_steps() >= 1);
    }

    #[test]
    fn suite_row_formats() {
        let s = Suite { c4_ppl: 12.3, wiki_ppl: 45.6, boolq_acc: 0.75, mmlu_acc: 0.25 };
        let r = s.row();
        assert!(r.contains("12.30") && r.contains("0.750"));
    }
}

//! # CURing — compression via CUR decomposition
//!
//! A reproduction of *"CURing Large Models: Compression via CUR
//! Decomposition"* (Park & Moon, ICML 2025): the coordinator owns
//! weights, data, calibration, DEIM-CUR compression, healing, PEFT
//! comparisons, evaluation and serving, and executes the model through a
//! pluggable [`backend::Backend`]:
//!
//! * **native** (default) — a pure-Rust CPU implementation of the full
//!   per-layer operation set (embed, RMSNorm, RoPE causal attention,
//!   SwiGLU FFN, dense and CURed linear chains, calibration Σx² taps,
//!   train/heal optimizer steps) with multithreaded blocked matmuls.
//!   `cargo build && cargo test` work anywhere, no artifacts needed.
//! * **pjrt** (`--features pjrt`) — the accelerator path: AOT HLO-text
//!   artifacts (JAX Llama-mini family + Pallas kernels, emitted by the
//!   Python build step into `artifacts/`) executed via the `xla` PJRT
//!   crate. Python never runs on the request path; after `make
//!   artifacts` the Rust binary is self-contained.
//!
//! Start at [`coordinator`] for the end-to-end pipeline, or [`cur`] for
//! the core decomposition math.

pub mod backend;
pub mod calib;
pub mod compress;
pub mod coordinator;
pub mod cur;
pub mod data;
pub mod eval;
pub mod heal;
pub mod linalg;
pub mod model;
pub mod peft;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
pub mod wanda;

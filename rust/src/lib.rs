//! # CURing — compression via CUR decomposition
//!
//! A three-layer reproduction of *"CURing Large Models: Compression via
//! CUR Decomposition"* (Park & Moon, ICML 2025):
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`)
//!   for the CURed linear chain, RMSNorm and WANDA statistics.
//! * **L2** — a JAX Llama-mini model family AOT-lowered to HLO text
//!   (`python/compile/`, emitted into `artifacts/`).
//! * **L3** — this crate: the coordinator that owns weights, data,
//!   calibration, DEIM-CUR compression, healing, PEFT comparisons,
//!   evaluation and serving, executing the AOT artifacts via PJRT.
//!
//! Python never runs on the request path; after `make artifacts` the Rust
//! binary is self-contained.
//!
//! Start at [`coordinator`] for the end-to-end pipeline, or [`cur`] for
//! the core decomposition math.

pub mod calib;
pub mod compress;
pub mod coordinator;
pub mod cur;
pub mod data;
pub mod eval;
pub mod heal;
pub mod linalg;
pub mod model;
pub mod peft;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
pub mod wanda;

//! Layer-pipeline executor: the serving-style composition engine.
//!
//! The coordinator never runs a monolithic model for inference. Instead it
//! composes per-layer AOT executables — dense or cured, any rank/combo —
//! according to a [`LayerPlan`], exactly like a serving router picking
//! model variants per stage. This is what makes "compress k layers at
//! runtime" possible with a finite artifact set, and it doubles as the
//! calibration engine (the calib artifact emits WANDA statistics).

use crate::model::ModelConfig;
use crate::runtime::{Bindings, Runtime};
use crate::tensor::{Tensor, TensorStore};
use anyhow::{ensure, Context, Result};

/// How one layer executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    Dense,
    Cured { rank: usize, combo: String },
}

/// Per-layer execution plan.
#[derive(Debug, Clone)]
pub struct LayerPlan(pub Vec<LayerKind>);

impl LayerPlan {
    pub fn all_dense(cfg: &ModelConfig) -> LayerPlan {
        LayerPlan(vec![LayerKind::Dense; cfg.n_layers])
    }

    /// Cure the given layers at (rank, combo), dense elsewhere.
    pub fn with_cured(cfg: &ModelConfig, layers: &[usize], rank: usize, combo: &str) -> LayerPlan {
        let mut plan = Self::all_dense(cfg);
        for &l in layers {
            plan.0[l] = LayerKind::Cured { rank, combo: combo.to_string() };
        }
        plan
    }

    pub fn cured_layers(&self) -> Vec<usize> {
        self.0
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k, LayerKind::Cured { .. }))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Output of one calibration forward pass.
#[derive(Debug, Clone)]
pub struct CalibForward {
    /// Per-layer output hidden states (n_layers entries, each (b,s,d)).
    pub layer_outputs: Vec<Tensor>,
    /// Embedding output (the input to layer 0).
    pub embed_out: Tensor,
    /// Per-layer Σx² over attention inputs, (d,) each.
    pub attn_sumsq: Vec<Tensor>,
    /// Per-layer Σx² over FFN inputs, (d,) each.
    pub ffn_sumsq: Vec<Tensor>,
    /// Per-layer raw attention inputs (post-ln1), (b, s, d) each —
    /// feeds the Table 6 activation-norm analysis.
    pub attn_in: Vec<Tensor>,
    /// Per-layer raw FFN inputs (post-ln2), (b, s, d) each.
    pub ffn_in: Vec<Tensor>,
}

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ModelConfig,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Result<Pipeline<'rt>> {
        let cfg = ModelConfig::from_manifest(&rt.manifest, config)?;
        Ok(Pipeline { rt, cfg })
    }

    fn art(&self, suffix: &str) -> String {
        format!("{}_{}", self.cfg.name, suffix)
    }

    pub fn layer_artifact(&self, kind: &LayerKind) -> String {
        match kind {
            LayerKind::Dense => self.art("layer_fwd_dense"),
            LayerKind::Cured { rank, combo } => {
                self.art(&format!("layer_fwd_cured_r{rank}_c{combo}"))
            }
        }
    }

    /// Embed a token batch: (b, s) i32 -> (b, s, d).
    pub fn embed(&self, store: &TensorStore, tokens: &Tensor) -> Result<Tensor> {
        let emb = store.get("emb")?;
        let mut out = self.rt.execute(
            &self.art("embed_fwd"),
            &Bindings::new().bind("tokens", tokens).bind("emb", emb),
        )?;
        out.remove("x").context("embed output missing")
    }

    /// Bind one layer's parameters (store names `L{l}.*` → artifact names
    /// `L.*`); for cured projections the merged `U = U0 + dU` is computed
    /// host-side (r×r, negligible).
    pub fn bind_layer<'b>(
        &self,
        b: &mut Bindings<'b>,
        store: &'b TensorStore,
        l: usize,
        kind: &LayerKind,
    ) -> Result<()> {
        match kind {
            LayerKind::Dense => {
                for suffix in ["ln1", "w_q", "w_k", "w_v", "w_o", "ln2", "w_gate", "w_up", "w_down"]
                {
                    b.bind_mut(format!("L.{suffix}"), store.get(&format!("L{l}.{suffix}"))?);
                }
            }
            LayerKind::Cured { combo, .. } => {
                let targets = crate::model::combo_targets(combo)?;
                for suffix in ["ln1", "ln2", "w_v", "w_o", "w_up", "w_down"] {
                    b.bind_mut(format!("L.{suffix}"), store.get(&format!("L{l}.{suffix}"))?);
                }
                for proj in ["q", "k", "gate"] {
                    if targets.contains(&proj) {
                        b.bind_mut(format!("L.c_{proj}"), store.get(&format!("L{l}.c_{proj}"))?);
                        b.bind_mut(format!("L.r_{proj}"), store.get(&format!("L{l}.r_{proj}"))?);
                        b.bind_owned(format!("L.u_{proj}"), self.merged_u(store, l, proj)?);
                    } else {
                        b.bind_mut(format!("L.w_{proj}"), store.get(&format!("L{l}.w_{proj}"))?);
                    }
                }
            }
        }
        Ok(())
    }

    /// `U = U0 + dU` (dU optional in the store).
    pub fn merged_u(&self, store: &TensorStore, l: usize, proj: &str) -> Result<Tensor> {
        let u0 = store.get(&format!("L{l}.u_{proj}"))?;
        let mut u = u0.clone();
        if let Ok(du) = store.get(&format!("L{l}.du_{proj}")) {
            let us = u.f32s_mut()?;
            for (a, b) in us.iter_mut().zip(du.f32s()?) {
                *a += b;
            }
        }
        Ok(u)
    }

    /// Run one layer: x -> y.
    pub fn layer_forward(
        &self,
        store: &TensorStore,
        l: usize,
        kind: &LayerKind,
        x: &Tensor,
    ) -> Result<Tensor> {
        let mut b = Bindings::new().bind("x", x);
        self.bind_layer(&mut b, store, l, kind)?;
        let mut out = self.rt.execute(&self.layer_artifact(kind), &b)?;
        out.remove("y").context("layer output missing")
    }

    /// Full forward to final hidden states.
    pub fn forward_hidden(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        let mut x = self.embed(store, tokens)?;
        for (l, kind) in plan.0.iter().enumerate() {
            x = self.layer_forward(store, l, kind, &x)?;
        }
        Ok(x)
    }

    /// Per-token NLL, (b, s).
    pub fn nll(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        let mut out = self.rt.execute(
            &self.art("head_nll"),
            &Bindings::new()
                .bind("x", &x)
                .bind("ln_f", store.get("ln_f")?)
                .bind("emb", store.get("emb")?)
                .bind("targets", targets),
        )?;
        out.remove("nll").context("nll output missing")
    }

    /// Full logits, (b, s, vocab).
    pub fn logits(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        let mut out = self.rt.execute(
            &self.art("head_logits"),
            &Bindings::new()
                .bind("x", &x)
                .bind("ln_f", store.get("ln_f")?)
                .bind("emb", store.get("emb")?),
        )?;
        out.remove("logits").context("logits output missing")
    }

    /// Calibration forward: dense layers only, collecting per-layer
    /// outputs and WANDA Σx² statistics.
    pub fn forward_calib(&self, store: &TensorStore, tokens: &Tensor) -> Result<CalibForward> {
        let embed_out = self.embed(store, tokens)?;
        let mut x = embed_out.clone();
        let mut layer_outputs = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_in = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_in = Vec::with_capacity(self.cfg.n_layers);
        let art = self.art("layer_fwd_calib");
        for l in 0..self.cfg.n_layers {
            let mut b = Bindings::new().bind("x", &x);
            self.bind_layer(&mut b, store, l, &LayerKind::Dense)?;
            let mut out = self.rt.execute(&art, &b)?;
            let y = out.remove("y").context("calib y missing")?;
            attn_sumsq.push(out.remove("attn_sumsq").context("attn_sumsq missing")?);
            ffn_sumsq.push(out.remove("ffn_sumsq").context("ffn_sumsq missing")?);
            attn_in.push(out.remove("attn_in").context("attn_in missing")?);
            ffn_in.push(out.remove("ffn_in").context("ffn_in missing")?);
            layer_outputs.push(y.clone());
            x = y;
        }
        Ok(CalibForward { layer_outputs, embed_out, attn_sumsq, ffn_sumsq, attn_in, ffn_in })
    }

    /// Greedy decoding through the per-layer pipeline.
    ///
    /// The AOT artifacts are fixed-shape (b, s); generation keeps a
    /// sliding window of the last `seq` tokens and recomputes the full
    /// window per emitted token (no KV cache — honest cost: one pipeline
    /// pass per token; fine for demo-scale serving and it exercises the
    /// exact deployed compute path). Returns `n_new` generated ids for
    /// each prompt row.
    pub fn generate_greedy(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let (b, s, v) = (self.cfg.batch, self.cfg.seq, self.cfg.vocab);
        ensure!(!prompts.is_empty() && prompts.len() <= b, "1..=batch prompts");
        // Windows padded on the left to length s; track logical lengths.
        let mut windows: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for i in 0..b {
            let p = &prompts[i.min(prompts.len() - 1)];
            let take = p.len().min(s);
            let mut w = vec![0i32; s];
            w[..take].copy_from_slice(&p[p.len() - take..]);
            windows.push(w);
            lens.push(take);
        }
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        for _ in 0..n_new {
            let flat: Vec<i32> = windows.iter().flatten().copied().collect();
            let tokens = Tensor::from_i32(&[b, s], flat);
            let logits = self.logits(store, plan, &tokens)?;
            let data = logits.f32s()?;
            for (i, g) in generated.iter_mut().enumerate() {
                let pos = lens[i] - 1; // last real token's prediction
                let row = &data[(i * s + pos) * v..(i * s + pos + 1) * v];
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for (j, &x) in row.iter().enumerate() {
                    if x > bv {
                        bv = x;
                        best = j;
                    }
                }
                g.push(best as i32);
                // Slide or append.
                if lens[i] < s {
                    windows[i][lens[i]] = best as i32;
                    lens[i] += 1;
                } else {
                    windows[i].rotate_left(1);
                    windows[i][s - 1] = best as i32;
                }
            }
        }
        Ok(generated)
    }

    /// Teacher-forced per-layer forward used for layer-wise KD: returns
    /// the (input, output) pair of every layer under the dense model.
    pub fn forward_trace(
        &self,
        store: &TensorStore,
        tokens: &Tensor,
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mut x = self.embed(store, tokens)?;
        let mut inputs = Vec::with_capacity(self.cfg.n_layers);
        let mut outputs = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            inputs.push(x.clone());
            let y = self.layer_forward(store, l, &LayerKind::Dense, &x)?;
            outputs.push(y.clone());
            x = y;
        }
        Ok((inputs, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"configs":{"t":{"vocab":64,"d_model":16,"n_layers":6,"n_heads":2,
            "d_inter":32,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    #[test]
    fn layer_plan_construction() {
        let c = cfg();
        let plan = LayerPlan::all_dense(&c);
        assert_eq!(plan.0.len(), 6);
        assert!(plan.cured_layers().is_empty());
        let plan = LayerPlan::with_cured(&c, &[2, 4], 4, "all");
        assert_eq!(plan.cured_layers(), vec![2, 4]);
        assert_eq!(plan.0[1], LayerKind::Dense);
        assert_eq!(plan.0[2], LayerKind::Cured { rank: 4, combo: "all".into() });
    }

    #[test]
    fn cured_artifact_names() {
        // Artifact naming must match aot.py's emission scheme.
        let kind = LayerKind::Cured { rank: 16, combo: "qk".into() };
        let dense = LayerKind::Dense;
        // Pipeline::layer_artifact needs a runtime; test the format here.
        let name = match &kind {
            LayerKind::Cured { rank, combo } => format!("tiny_layer_fwd_cured_r{rank}_c{combo}"),
            LayerKind::Dense => "tiny_layer_fwd_dense".into(),
        };
        assert_eq!(name, "tiny_layer_fwd_cured_r16_cqk");
        assert!(matches!(dense, LayerKind::Dense));
    }
}

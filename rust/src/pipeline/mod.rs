//! Layer-pipeline executor: the serving-style composition engine.
//!
//! The coordinator never runs a monolithic model for inference. Instead it
//! composes per-layer operations — dense or cured, any rank/combo —
//! according to a [`LayerPlan`], exactly like a serving router picking
//! model variants per stage. This is what makes "compress k layers at
//! runtime" possible with a finite operation set, and it doubles as the
//! calibration engine (the calib forward emits WANDA statistics).
//!
//! The pipeline is backend-agnostic: it assembles each layer's
//! [`LayerParams`] view from the store and hands execution to the
//! runtime's [`crate::backend::Backend`] (native CPU or PJRT artifacts).

use crate::backend::{Backend, KvCache, KvPolicy, LayerParams, PackedHead, Proj};
use crate::model::ModelConfig;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorStore};
use anyhow::{ensure, Result};
use std::borrow::Cow;

/// `CURING_NO_KV_CACHE=1` forces greedy decode onto the cache-free
/// per-token replay reference ([`Pipeline::generate_greedy_uncached`] —
/// same token stream, no persistent KV state; debugging escape hatch).
fn kv_cache_disabled() -> bool {
    crate::util::config::kv_cache_disabled()
}

/// Typed poisoned-decode error: the logits row fed to greedy token
/// selection held a NaN or infinity. Downcast from the anyhow chain to
/// distinguish numeric poisoning from other decode failures — the serve
/// loop routes it through its per-slot failure path (fail one request,
/// keep serving) instead of letting a silent `NaN > x == false`
/// comparison emit token 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NonFiniteLogits;

impl std::fmt::Display for NonFiniteLogits {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("non-finite logits (NaN/Inf) reached greedy token selection")
    }
}

impl std::error::Error for NonFiniteLogits {}

/// Greedy token over one logits row. Any non-finite entry is a typed
/// [`NonFiniteLogits`] error: a poisoned row must fail its request, not
/// silently decode token 0 (NaN loses every `>` comparison).
pub fn greedy_token(row: &[f32]) -> Result<i32> {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (j, &x) in row.iter().enumerate() {
        if !x.is_finite() {
            return Err(anyhow::Error::new(NonFiniteLogits));
        }
        if x > bv {
            bv = x;
            best = j;
        }
    }
    Ok(best as i32)
}

/// How one layer executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    Dense,
    Cured { rank: usize, combo: String },
}

/// Per-layer execution plan.
#[derive(Debug, Clone)]
pub struct LayerPlan(pub Vec<LayerKind>);

impl LayerPlan {
    pub fn all_dense(cfg: &ModelConfig) -> LayerPlan {
        LayerPlan(vec![LayerKind::Dense; cfg.n_layers])
    }

    /// Cure the given layers at (rank, combo), dense elsewhere.
    pub fn with_cured(cfg: &ModelConfig, layers: &[usize], rank: usize, combo: &str) -> LayerPlan {
        let mut plan = Self::all_dense(cfg);
        for &l in layers {
            plan.0[l] = LayerKind::Cured { rank, combo: combo.to_string() };
        }
        plan
    }

    pub fn cured_layers(&self) -> Vec<usize> {
        self.0
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k, LayerKind::Cured { .. }))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Output of one calibration forward pass.
#[derive(Debug, Clone)]
pub struct CalibForward {
    /// Per-layer output hidden states (n_layers entries, each (b,s,d)).
    pub layer_outputs: Vec<Tensor>,
    /// Embedding output (the input to layer 0).
    pub embed_out: Tensor,
    /// Per-layer Σx² over attention inputs, (d,) each.
    pub attn_sumsq: Vec<Tensor>,
    /// Per-layer Σx² over FFN inputs, (d,) each.
    pub ffn_sumsq: Vec<Tensor>,
    /// Per-layer raw attention inputs (post-ln1), (b, s, d) each —
    /// feeds the Table 6 activation-norm analysis.
    pub attn_in: Vec<Tensor>,
    /// Per-layer raw FFN inputs (post-ln2), (b, s, d) each.
    pub ffn_in: Vec<Tensor>,
}

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ModelConfig,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Result<Pipeline<'rt>> {
        let cfg = ModelConfig::from_manifest(rt.manifest(), config)?;
        Ok(Pipeline { rt, cfg })
    }

    /// Embed a token batch: (b, s) i32 -> (b, s, d).
    pub fn embed(&self, store: &TensorStore, tokens: &Tensor) -> Result<Tensor> {
        self.rt.backend().embed(&self.cfg, store.get("emb")?, tokens)
    }

    /// Assemble one layer's parameter view (store names `L{l}.*`); for
    /// cured projections the merged `U = U0 + dU` is computed host-side
    /// (r×r, negligible).
    pub fn layer_params<'b>(
        &self,
        store: &'b TensorStore,
        l: usize,
        kind: &LayerKind,
    ) -> Result<LayerParams<'b>> {
        let (q, k, gate) = match kind {
            LayerKind::Dense => (
                Proj::Dense(store.get(&format!("L{l}.w_q"))?),
                Proj::Dense(store.get(&format!("L{l}.w_k"))?),
                Proj::Dense(store.get(&format!("L{l}.w_gate"))?),
            ),
            LayerKind::Cured { combo, .. } => {
                let targets = crate::model::combo_targets(combo)?;
                let view = |proj: &'static str| -> Result<Proj<'b>> {
                    if targets.contains(&proj) {
                        Ok(Proj::Cured {
                            c: store.get(&format!("L{l}.c_{proj}"))?,
                            u: Cow::Owned(self.merged_u(store, l, proj)?),
                            r: store.get(&format!("L{l}.r_{proj}"))?,
                        })
                    } else {
                        Ok(Proj::Dense(store.get(&format!("L{l}.w_{proj}"))?))
                    }
                };
                (view("q")?, view("k")?, view("gate")?)
            }
        };
        Ok(LayerParams {
            ln1: store.get(&format!("L{l}.ln1"))?,
            ln2: store.get(&format!("L{l}.ln2"))?,
            q,
            k,
            gate,
            v: store.get(&format!("L{l}.w_v"))?,
            o: store.get(&format!("L{l}.w_o"))?,
            up: store.get(&format!("L{l}.w_up"))?,
            down: store.get(&format!("L{l}.w_down"))?,
            adapter: None,
        })
    }

    /// `U = U0 + dU` (dU optional in the store).
    pub fn merged_u(&self, store: &TensorStore, l: usize, proj: &str) -> Result<Tensor> {
        let u0 = store.get(&format!("L{l}.u_{proj}"))?;
        let mut u = u0.clone();
        if let Ok(du) = store.get(&format!("L{l}.du_{proj}")) {
            let us = u.f32s_mut()?;
            for (a, b) in us.iter_mut().zip(du.f32s()?) {
                *a += b;
            }
        }
        Ok(u)
    }

    /// Run one layer: x -> y (the cached reference path).
    pub fn layer_forward(
        &self,
        store: &TensorStore,
        l: usize,
        kind: &LayerKind,
        x: &Tensor,
    ) -> Result<Tensor> {
        let params = self.layer_params(store, l, kind)?;
        self.rt.backend().layer_forward(&self.cfg, &params, x)
    }

    /// Run one layer on the inference-only path (no backward caches).
    pub fn layer_forward_infer(
        &self,
        store: &TensorStore,
        l: usize,
        kind: &LayerKind,
        x: &Tensor,
    ) -> Result<Tensor> {
        let params = self.layer_params(store, l, kind)?;
        self.rt.backend().layer_forward_infer(&self.cfg, &params, x)
    }

    /// Full forward to final hidden states (inference path: eval, serve
    /// and decode all come through here).
    pub fn forward_hidden(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        let mut x = self.embed(store, tokens)?;
        for (l, kind) in plan.0.iter().enumerate() {
            x = self.layer_forward_infer(store, l, kind, &x)?;
        }
        Ok(x)
    }

    /// Per-token NLL, (b, s).
    pub fn nll(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        self.rt.backend().head_nll(
            &self.cfg,
            &x,
            store.get("ln_f")?,
            store.get("emb")?,
            targets,
        )
    }

    /// Full logits, (b, s, vocab).
    pub fn logits(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        self.rt.backend().head_logits(&self.cfg, &x, store.get("ln_f")?, store.get("emb")?)
    }

    /// Calibration forward: dense layers only, collecting per-layer
    /// outputs and WANDA Σx² statistics.
    pub fn forward_calib(&self, store: &TensorStore, tokens: &Tensor) -> Result<CalibForward> {
        let embed_out = self.embed(store, tokens)?;
        let mut x = embed_out.clone();
        let mut layer_outputs = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_in = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_in = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            let params = self.layer_params(store, l, &LayerKind::Dense)?;
            let out = self.rt.backend().layer_forward_calib(&self.cfg, &params, &x)?;
            attn_sumsq.push(out.attn_sumsq);
            ffn_sumsq.push(out.ffn_sumsq);
            attn_in.push(out.attn_in);
            ffn_in.push(out.ffn_in);
            layer_outputs.push(out.y.clone());
            x = out.y;
        }
        Ok(CalibForward { layer_outputs, embed_out, attn_sumsq, ffn_sumsq, attn_in, ffn_in })
    }

    /// Pre-pack the LM head for repeated decode-step logits calls.
    /// `None` on backends without a packed kernel — pass the result to
    /// [`Pipeline::head_rows`], which falls back to the plain head.
    pub fn pack_head(&self, store: &TensorStore) -> Result<Option<PackedHead>> {
        self.rt.backend().pack_head(store.get("emb")?)
    }

    /// Head logits over hidden rows `x` (any (b, s, d)), preferring the
    /// pre-packed kernel when one was built. Every head call of a
    /// generation run must go through the same kernel (packed or not) —
    /// the decode/replay parity is bit-exact only within one kernel.
    pub fn head_rows(
        &self,
        store: &TensorStore,
        x: &Tensor,
        packed: Option<&PackedHead>,
    ) -> Result<Tensor> {
        match packed {
            Some(ph) => {
                self.rt.backend().head_logits_packed(&self.cfg, x, store.get("ln_f")?, ph)
            }
            None => self.rt.backend().head_logits(
                &self.cfg,
                x,
                store.get("ln_f")?,
                store.get("emb")?,
            ),
        }
    }

    /// Admit one prompt into KV-cache slot `slot`: reset the lane,
    /// prefill the last `min(len, window)` prompt tokens (positions
    /// 0..w — the one and only prefill this slot ever runs; ring
    /// rotation never re-enters this path), then head the final
    /// position. Returns the first emitted token.
    pub fn prefill_slot(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        kv: &mut KvCache,
        slot: usize,
        prompt: &[i32],
        packed: Option<&PackedHead>,
    ) -> Result<i32> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        ensure!(!prompt.is_empty(), "empty prompt");
        let d = self.cfg.d_model;
        let w = prompt.len().min(kv.window);
        kv.reset_slot(slot);
        let tokens = Tensor::from_i32(&[1, w], prompt[prompt.len() - w..].to_vec());
        let mut x = self.embed(store, &tokens)?;
        for (l, kind) in plan.0.iter().enumerate() {
            let params = self.layer_params(store, l, kind)?;
            x = self.rt.backend().layer_prefill(&self.cfg, &params, &x, kv, l, slot)?;
        }
        kv.commit_prefill(slot, w);
        let hidden =
            Tensor::from_f32(&[1, 1, d], x.f32s()?[(w - 1) * d..w * d].to_vec());
        let logits = self.head_rows(store, &hidden, packed)?;
        greedy_token(&logits.f32s()?[..self.cfg.vocab])
    }

    /// Compact `slot`'s lane if it is full under [`KvPolicy::Cur`];
    /// returns whether a compaction ran. The granular entry point for
    /// callers that need per-slot error isolation (the serve loop fails
    /// only the slot whose compaction errored);
    /// [`Pipeline::decode_step_logits`] runs it for every slot
    /// automatically.
    pub fn compact_slot(&self, kv: &mut KvCache, slot: usize) -> Result<bool> {
        if kv.needs_compaction(slot) {
            self.rt.backend().compress_kv_slot(&self.cfg, kv, slot)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// The hidden-state half of one fused decode step: embed `last` and
    /// run every layer's single-position pass across the slots,
    /// returning the (n, 1, d) hidden rows. Performs **no** compaction
    /// and does **not** advance the cache — callers own both
    /// ([`Pipeline::decode_step_logits`] composes all three; the serve
    /// loop calls the pieces so a failure can be rolled back per slot
    /// via [`KvCache::rollback_token`] and retried or failed in
    /// isolation). Full [`KvPolicy::Cur`] lanes must be compacted first.
    pub fn decode_hidden(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        kv: &mut KvCache,
        slots: &[usize],
        last: &[i32],
    ) -> Result<Tensor> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        ensure!(slots.len() == last.len() && !slots.is_empty(), "one token per slot");
        let toks = Tensor::from_i32(&[slots.len(), 1], last.to_vec());
        let mut x = self.embed(store, &toks)?;
        for (l, kind) in plan.0.iter().enumerate() {
            let params = self.layer_params(store, l, kind)?;
            x = self.rt.backend().layer_decode_batch(&self.cfg, &params, &x, kv, l, slots)?;
        }
        Ok(x)
    }

    /// One fused decode step across the active slots, returning the raw
    /// head logits (n, 1, vocab) for each row. Under a
    /// [`KvPolicy::Cur`] cache, any slot whose lane hit the high-water
    /// mark is first compacted via
    /// [`crate::backend::Backend::compress_kv_slot`] — the caller never
    /// schedules compactions itself. Advances the slots.
    pub fn decode_step_logits(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        kv: &mut KvCache,
        slots: &[usize],
        last: &[i32],
        packed: Option<&PackedHead>,
    ) -> Result<Tensor> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        ensure!(slots.len() == last.len() && !slots.is_empty(), "one token per slot");
        if matches!(kv.policy, KvPolicy::Cur { .. }) {
            for &slot in slots {
                self.compact_slot(kv, slot)?;
            }
        }
        let x = self.decode_hidden(store, plan, kv, slots, last)?;
        kv.advance(slots);
        self.head_rows(store, &x, packed)
    }

    /// One fused decode step across the active slots: feed `last[r]`
    /// (slot `slots[r]`'s most recent token) as an (n, 1) batch, run one
    /// single-position layer pass per layer over all n rows at once,
    /// advance the slots, and return each slot's next greedy token.
    /// Compacts full [`KvPolicy::Cur`] lanes first (see
    /// [`Pipeline::decode_step_logits`]).
    pub fn decode_step(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        kv: &mut KvCache,
        slots: &[usize],
        last: &[i32],
        packed: Option<&PackedHead>,
    ) -> Result<Vec<i32>> {
        let (n, v) = (slots.len(), self.cfg.vocab);
        let logits = self.decode_step_logits(store, plan, kv, slots, last, packed)?;
        let data = logits.f32s()?;
        (0..n).map(|r| greedy_token(&data[r * v..(r + 1) * v])).collect()
    }

    /// Greedy decoding through the per-layer pipeline.
    ///
    /// On backends with a KV-cache decode path (native) this is
    /// streaming generation: each prompt is prefilled once into its own
    /// ring-buffer KV lane, then every token is one fused single-
    /// position layer pass across all rows. RoPE positions increase
    /// monotonically and a full window rotates by overwriting the
    /// oldest ring row — sliding-window attention over the last
    /// `cfg.seq` tokens with **no recompute and no re-prefill**, ever.
    /// Token ids are bit-identical to the cache-free replay reference
    /// ([`Pipeline::generate_greedy_uncached`], asserted in tests),
    /// which `CURING_NO_KV_CACHE=1` forces. Backends without a decode
    /// path (fixed-shape pjrt artifacts) fall back to the windowed
    /// full-recompute loop. Returns `n_new` generated ids per prompt.
    pub fn generate_greedy(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        if !self.rt.backend().supports_kv_decode() {
            return self.generate_greedy_windowed(store, plan, prompts, n_new);
        }
        if kv_cache_disabled() {
            return self.generate_greedy_uncached(store, plan, prompts, n_new);
        }
        self.decode_streaming(store, plan, prompts, n_new, KvPolicy::Exact)
    }

    /// [`Pipeline::generate_greedy`] under an explicit KV eviction
    /// policy: `KvPolicy::Exact` is the fast path above;
    /// `KvPolicy::Cur { .. }` decodes against a CUR-compressed cache —
    /// token-identical to the exact stream until the first compaction
    /// (and bit-identical throughout at keep = 1.0, asserted in tests),
    /// after which dropped positions may shift the greedy argmax. Needs
    /// a KV-decode backend (no windowed fallback: the recompute loop
    /// cannot reproduce compacted-cache semantics).
    pub fn generate_greedy_with_policy(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
        policy: KvPolicy,
    ) -> Result<Vec<Vec<i32>>> {
        ensure!(
            self.rt.backend().supports_kv_decode(),
            "kv policy '{policy}' needs a KV-decode backend (backend '{}' has none)",
            self.rt.backend().name()
        );
        policy.validate(self.cfg.seq)?;
        self.decode_streaming(store, plan, prompts, n_new, policy)
    }

    /// The fast path: per-slot prefill once, then lockstep fused decode.
    fn decode_streaming(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
        policy: KvPolicy,
    ) -> Result<Vec<Vec<i32>>> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        ensure!(!prompts.is_empty(), "need at least one prompt");
        let cfg = &self.cfg;
        let n = prompts.len();
        if n_new == 0 {
            return Ok(vec![Vec::new(); n]);
        }
        let mut kv = KvCache::with_policy(cfg.n_layers, n, cfg.seq, cfg.d_model, policy);
        let packed = self.pack_head(store)?;
        let mut last = Vec::with_capacity(n);
        for (slot, prompt) in prompts.iter().enumerate() {
            last.push(self.prefill_slot(store, plan, &mut kv, slot, prompt, packed.as_ref())?);
        }
        let mut generated: Vec<Vec<i32>> = last.iter().map(|&t| vec![t]).collect();
        let slots: Vec<usize> = (0..n).collect();
        for _ in 1..n_new {
            last = self.decode_step(store, plan, &mut kv, &slots, &last, packed.as_ref())?;
            for (g, &t) in generated.iter_mut().zip(&last) {
                g.push(t);
            }
        }
        Ok(generated)
    }

    /// The cache-free reference of the same streaming semantics — the
    /// parity oracle the fast path is tested against, and the
    /// `CURING_NO_KV_CACHE=1` behavior.
    ///
    /// No state survives between emitted tokens: for every token the
    /// slot's entire history is replayed from scratch, one position at
    /// a time, through a fresh **never-wrapping linear** cache
    /// (capacity = history length) with the same attention window. The
    /// replay exercises none of the fast path's machinery — no ring
    /// wrap-around, no fused multi-slot batching, no prompt-window
    /// prefill, no incremental reuse — yet must reproduce its token
    /// stream bit-for-bit, because every kernel produces identical rows
    /// regardless of batch shape (see `backend::native::math`). On
    /// backends without a decode path this falls back to the windowed
    /// full-recompute loop.
    pub fn generate_greedy_uncached(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        if !self.rt.backend().supports_kv_decode() {
            return self.generate_greedy_windowed(store, plan, prompts, n_new);
        }
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        ensure!(!prompts.is_empty(), "need at least one prompt");
        let cfg = &self.cfg;
        let backend = self.rt.backend();
        let window = cfg.seq;
        let packed = self.pack_head(store)?;
        let mut out = Vec::with_capacity(prompts.len());
        for prompt in prompts {
            ensure!(!prompt.is_empty(), "empty prompt");
            // Entry truncation matches the fast path: only the last
            // `window` prompt tokens ever enter the model.
            let take = prompt.len().min(window);
            let mut hist: Vec<i32> = prompt[prompt.len() - take..].to_vec();
            let mut gen = Vec::with_capacity(n_new);
            for _ in 0..n_new {
                let cap = hist.len().max(window);
                let mut kv =
                    KvCache::with_capacity(cfg.n_layers, 1, window, cap, cfg.d_model);
                let mut x_last = None;
                for &tok in &hist {
                    let toks = Tensor::from_i32(&[1, 1], vec![tok]);
                    let mut x = self.embed(store, &toks)?;
                    for (l, kind) in plan.0.iter().enumerate() {
                        let params = self.layer_params(store, l, kind)?;
                        x = backend
                            .layer_decode_batch(cfg, &params, &x, &mut kv, l, &[0])?;
                    }
                    kv.advance(&[0]);
                    x_last = Some(x);
                }
                let hidden = x_last
                    .ok_or_else(|| anyhow::anyhow!("empty decode history for slot replay"))?;
                let logits = self.head_rows(store, &hidden, packed.as_ref())?;
                let t = greedy_token(&logits.f32s()?[..cfg.vocab])?;
                gen.push(t);
                hist.push(t);
            }
            out.push(gen);
        }
        Ok(out)
    }

    /// The seed full-window loop: one whole-window pipeline pass per
    /// emitted token, windows left-padded to `cfg.seq`, RoPE positions
    /// rebased on rotation. The only generation path available to
    /// fixed-shape backends (pjrt AOT artifacts); identical to the
    /// streaming path until the first rotation, after which the rebase
    /// semantics diverge from the KV semantics (positions shift instead
    /// of sliding) — documented, not hidden.
    pub fn generate_greedy_windowed(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        let (s, v) = (self.cfg.seq, self.cfg.vocab);
        // Fixed-shape backends must run the manifest batch (padding with
        // repeated rows); the native backend runs exactly the prompts.
        let b = if self.rt.backend().fixed_shape() { self.cfg.batch } else { prompts.len() };
        ensure!(!prompts.is_empty() && prompts.len() <= b, "1..={b} prompts");
        // Windows padded on the left to length s; track logical lengths.
        let mut windows: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for i in 0..b {
            let p = &prompts[i.min(prompts.len() - 1)];
            let take = p.len().min(s);
            let mut w = vec![0i32; s];
            w[..take].copy_from_slice(&p[p.len() - take..]);
            windows.push(w);
            lens.push(take);
        }
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        for _ in 0..n_new {
            let flat: Vec<i32> = windows.iter().flatten().copied().collect();
            let tokens = Tensor::from_i32(&[b, s], flat);
            let logits = self.logits(store, plan, &tokens)?;
            let data = logits.f32s()?;
            for (i, g) in generated.iter_mut().enumerate() {
                let pos = lens[i] - 1; // last real token's prediction
                let best = greedy_token(&data[(i * s + pos) * v..(i * s + pos + 1) * v])?;
                g.push(best);
                // Slide or append.
                if lens[i] < s {
                    windows[i][lens[i]] = best;
                    lens[i] += 1;
                } else {
                    windows[i].rotate_left(1);
                    windows[i][s - 1] = best;
                }
            }
        }
        Ok(generated)
    }

    /// Teacher-forced per-layer forward used for layer-wise KD: returns
    /// the (input, output) pair of every layer under the dense model.
    pub fn forward_trace(
        &self,
        store: &TensorStore,
        tokens: &Tensor,
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mut x = self.embed(store, tokens)?;
        let mut inputs = Vec::with_capacity(self.cfg.n_layers);
        let mut outputs = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            inputs.push(x.clone());
            let y = self.layer_forward_infer(store, l, &LayerKind::Dense, &x)?;
            outputs.push(y.clone());
            x = y;
        }
        Ok((inputs, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"configs":{"t":{"vocab":64,"d_model":16,"n_layers":6,"n_heads":2,
            "d_inter":32,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    #[test]
    fn greedy_token_rejects_non_finite() {
        assert_eq!(greedy_token(&[0.1, 0.9, -0.5]).unwrap(), 1);
        let err = greedy_token(&[0.1, f32::NAN, 0.3]).unwrap_err();
        assert!(err.downcast_ref::<NonFiniteLogits>().is_some(), "typed error expected: {err}");
        assert!(greedy_token(&[f32::INFINITY, 0.0]).is_err());
        assert!(greedy_token(&[f32::NEG_INFINITY, 0.0]).is_err());
    }

    #[test]
    fn layer_plan_construction() {
        let c = cfg();
        let plan = LayerPlan::all_dense(&c);
        assert_eq!(plan.0.len(), 6);
        assert!(plan.cured_layers().is_empty());
        let plan = LayerPlan::with_cured(&c, &[2, 4], 4, "all");
        assert_eq!(plan.cured_layers(), vec![2, 4]);
        assert_eq!(plan.0[1], LayerKind::Dense);
        assert_eq!(plan.0[2], LayerKind::Cured { rank: 4, combo: "all".into() });
    }

    #[test]
    fn layer_params_views_match_plan() {
        let c = cfg();
        let mut rng = crate::util::Rng::new(5, 0);
        let mut store = c.init_dense(&mut rng);
        let rt = Runtime::native();
        let pipe = Pipeline { rt: &rt, cfg: c.clone() };
        let p = pipe.layer_params(&store, 1, &LayerKind::Dense).unwrap();
        assert!(!p.q.is_cured() && !p.k.is_cured() && !p.gate.is_cured());
        // Cure layer 1 (combo qk: gate stays dense), then re-assemble.
        let calib = crate::calib::Calibration {
            attn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            ffn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            angular: vec![0.0; c.n_layers],
            n_examples: 1,
        };
        let opts = crate::compress::CompressOptions {
            combo: "qk".into(),
            r_max: 4,
            ..Default::default()
        };
        crate::compress::cure_layers(&mut store, &c, &calib, &[1], &opts).unwrap();
        let kind = LayerKind::Cured { rank: 4, combo: "qk".into() };
        let p = pipe.layer_params(&store, 1, &kind).unwrap();
        assert!(p.q.is_cured() && p.k.is_cured());
        assert!(!p.gate.is_cured());
        assert_eq!(p.q.rank(), Some(4));
        // A dense view of a cured layer must fail loudly (w_q is gone).
        assert!(pipe.layer_params(&store, 1, &LayerKind::Dense).is_err());
    }

    #[test]
    fn merged_u_adds_delta() {
        let c = cfg();
        let rt = Runtime::native();
        let pipe = Pipeline { rt: &rt, cfg: c };
        let mut store = TensorStore::new();
        store.insert("L0.u_q", Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        store.insert("L0.du_q", Tensor::from_f32(&[2, 2], vec![0.5, 0.0, -1.0, 0.25]));
        let u = pipe.merged_u(&store, 0, "q").unwrap();
        assert_eq!(u.f32s().unwrap(), &[1.5, 2.0, 2.0, 4.25]);
    }
}

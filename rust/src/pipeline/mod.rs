//! Layer-pipeline executor: the serving-style composition engine.
//!
//! The coordinator never runs a monolithic model for inference. Instead it
//! composes per-layer operations — dense or cured, any rank/combo —
//! according to a [`LayerPlan`], exactly like a serving router picking
//! model variants per stage. This is what makes "compress k layers at
//! runtime" possible with a finite operation set, and it doubles as the
//! calibration engine (the calib forward emits WANDA statistics).
//!
//! The pipeline is backend-agnostic: it assembles each layer's
//! [`LayerParams`] view from the store and hands execution to the
//! runtime's [`crate::backend::Backend`] (native CPU or PJRT artifacts).

use crate::backend::{Backend, KvCache, LayerParams, Proj};
use crate::model::ModelConfig;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorStore};
use anyhow::{ensure, Result};
use std::borrow::Cow;

/// `CURING_NO_KV_CACHE=1` forces greedy decode onto the full-window
/// recompute path (debugging escape hatch).
fn kv_cache_disabled() -> bool {
    std::env::var("CURING_NO_KV_CACHE").map(|v| v == "1").unwrap_or(false)
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (j, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = j;
        }
    }
    best
}

/// How one layer executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    Dense,
    Cured { rank: usize, combo: String },
}

/// Per-layer execution plan.
#[derive(Debug, Clone)]
pub struct LayerPlan(pub Vec<LayerKind>);

impl LayerPlan {
    pub fn all_dense(cfg: &ModelConfig) -> LayerPlan {
        LayerPlan(vec![LayerKind::Dense; cfg.n_layers])
    }

    /// Cure the given layers at (rank, combo), dense elsewhere.
    pub fn with_cured(cfg: &ModelConfig, layers: &[usize], rank: usize, combo: &str) -> LayerPlan {
        let mut plan = Self::all_dense(cfg);
        for &l in layers {
            plan.0[l] = LayerKind::Cured { rank, combo: combo.to_string() };
        }
        plan
    }

    pub fn cured_layers(&self) -> Vec<usize> {
        self.0
            .iter()
            .enumerate()
            .filter(|(_, k)| matches!(k, LayerKind::Cured { .. }))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Output of one calibration forward pass.
#[derive(Debug, Clone)]
pub struct CalibForward {
    /// Per-layer output hidden states (n_layers entries, each (b,s,d)).
    pub layer_outputs: Vec<Tensor>,
    /// Embedding output (the input to layer 0).
    pub embed_out: Tensor,
    /// Per-layer Σx² over attention inputs, (d,) each.
    pub attn_sumsq: Vec<Tensor>,
    /// Per-layer Σx² over FFN inputs, (d,) each.
    pub ffn_sumsq: Vec<Tensor>,
    /// Per-layer raw attention inputs (post-ln1), (b, s, d) each —
    /// feeds the Table 6 activation-norm analysis.
    pub attn_in: Vec<Tensor>,
    /// Per-layer raw FFN inputs (post-ln2), (b, s, d) each.
    pub ffn_in: Vec<Tensor>,
}

pub struct Pipeline<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: ModelConfig,
}

impl<'rt> Pipeline<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Result<Pipeline<'rt>> {
        let cfg = ModelConfig::from_manifest(rt.manifest(), config)?;
        Ok(Pipeline { rt, cfg })
    }

    /// Embed a token batch: (b, s) i32 -> (b, s, d).
    pub fn embed(&self, store: &TensorStore, tokens: &Tensor) -> Result<Tensor> {
        self.rt.backend().embed(&self.cfg, store.get("emb")?, tokens)
    }

    /// Assemble one layer's parameter view (store names `L{l}.*`); for
    /// cured projections the merged `U = U0 + dU` is computed host-side
    /// (r×r, negligible).
    pub fn layer_params<'b>(
        &self,
        store: &'b TensorStore,
        l: usize,
        kind: &LayerKind,
    ) -> Result<LayerParams<'b>> {
        let (q, k, gate) = match kind {
            LayerKind::Dense => (
                Proj::Dense(store.get(&format!("L{l}.w_q"))?),
                Proj::Dense(store.get(&format!("L{l}.w_k"))?),
                Proj::Dense(store.get(&format!("L{l}.w_gate"))?),
            ),
            LayerKind::Cured { combo, .. } => {
                let targets = crate::model::combo_targets(combo)?;
                let mut projs = Vec::with_capacity(3);
                for proj in ["q", "k", "gate"] {
                    if targets.contains(&proj) {
                        projs.push(Proj::Cured {
                            c: store.get(&format!("L{l}.c_{proj}"))?,
                            u: Cow::Owned(self.merged_u(store, l, proj)?),
                            r: store.get(&format!("L{l}.r_{proj}"))?,
                        });
                    } else {
                        projs.push(Proj::Dense(store.get(&format!("L{l}.w_{proj}"))?));
                    }
                }
                let gate = projs.pop().expect("gate");
                let k = projs.pop().expect("k");
                let q = projs.pop().expect("q");
                (q, k, gate)
            }
        };
        Ok(LayerParams {
            ln1: store.get(&format!("L{l}.ln1"))?,
            ln2: store.get(&format!("L{l}.ln2"))?,
            q,
            k,
            gate,
            v: store.get(&format!("L{l}.w_v"))?,
            o: store.get(&format!("L{l}.w_o"))?,
            up: store.get(&format!("L{l}.w_up"))?,
            down: store.get(&format!("L{l}.w_down"))?,
        })
    }

    /// `U = U0 + dU` (dU optional in the store).
    pub fn merged_u(&self, store: &TensorStore, l: usize, proj: &str) -> Result<Tensor> {
        let u0 = store.get(&format!("L{l}.u_{proj}"))?;
        let mut u = u0.clone();
        if let Ok(du) = store.get(&format!("L{l}.du_{proj}")) {
            let us = u.f32s_mut()?;
            for (a, b) in us.iter_mut().zip(du.f32s()?) {
                *a += b;
            }
        }
        Ok(u)
    }

    /// Run one layer: x -> y (the cached reference path).
    pub fn layer_forward(
        &self,
        store: &TensorStore,
        l: usize,
        kind: &LayerKind,
        x: &Tensor,
    ) -> Result<Tensor> {
        let params = self.layer_params(store, l, kind)?;
        self.rt.backend().layer_forward(&self.cfg, &params, x)
    }

    /// Run one layer on the inference-only path (no backward caches).
    pub fn layer_forward_infer(
        &self,
        store: &TensorStore,
        l: usize,
        kind: &LayerKind,
        x: &Tensor,
    ) -> Result<Tensor> {
        let params = self.layer_params(store, l, kind)?;
        self.rt.backend().layer_forward_infer(&self.cfg, &params, x)
    }

    /// Full forward to final hidden states (inference path: eval, serve
    /// and decode all come through here).
    pub fn forward_hidden(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        let mut x = self.embed(store, tokens)?;
        for (l, kind) in plan.0.iter().enumerate() {
            x = self.layer_forward_infer(store, l, kind, &x)?;
        }
        Ok(x)
    }

    /// Per-token NLL, (b, s).
    pub fn nll(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
        targets: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        self.rt.backend().head_nll(
            &self.cfg,
            &x,
            store.get("ln_f")?,
            store.get("emb")?,
            targets,
        )
    }

    /// Full logits, (b, s, vocab).
    pub fn logits(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        tokens: &Tensor,
    ) -> Result<Tensor> {
        let x = self.forward_hidden(store, plan, tokens)?;
        self.rt.backend().head_logits(&self.cfg, &x, store.get("ln_f")?, store.get("emb")?)
    }

    /// Calibration forward: dense layers only, collecting per-layer
    /// outputs and WANDA Σx² statistics.
    pub fn forward_calib(&self, store: &TensorStore, tokens: &Tensor) -> Result<CalibForward> {
        let embed_out = self.embed(store, tokens)?;
        let mut x = embed_out.clone();
        let mut layer_outputs = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_sumsq = Vec::with_capacity(self.cfg.n_layers);
        let mut attn_in = Vec::with_capacity(self.cfg.n_layers);
        let mut ffn_in = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            let params = self.layer_params(store, l, &LayerKind::Dense)?;
            let out = self.rt.backend().layer_forward_calib(&self.cfg, &params, &x)?;
            attn_sumsq.push(out.attn_sumsq);
            ffn_sumsq.push(out.ffn_sumsq);
            attn_in.push(out.attn_in);
            ffn_in.push(out.ffn_in);
            layer_outputs.push(out.y.clone());
            x = out.y;
        }
        Ok(CalibForward { layer_outputs, embed_out, attn_sumsq, ffn_sumsq, attn_in, ffn_in })
    }

    /// Greedy decoding through the per-layer pipeline.
    ///
    /// On backends with a KV-cache decode path (native), the prompt
    /// window is prefilled once and each subsequent token is a single-
    /// position layer pass against per-layer K/V buffers — token ids are
    /// identical to the full-window recompute path (asserted in tests).
    /// When a row's window fills, RoPE positions shift under the sliding
    /// window and the remaining tokens fall back to full recompute, the
    /// seed behavior. Fixed-shape backends (pjrt) and
    /// `CURING_NO_KV_CACHE=1` always take the full-recompute path.
    /// Returns `n_new` generated ids for each prompt row.
    pub fn generate_greedy(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let use_kv = self.rt.backend().supports_kv_decode() && !kv_cache_disabled();
        self.generate_greedy_impl(store, plan, prompts, n_new, use_kv)
    }

    /// The full-window recompute path (one pipeline pass over the whole
    /// window per emitted token): the reference the KV-cached path is
    /// tested against, and the `CURING_NO_KV_CACHE=1` behavior.
    pub fn generate_greedy_uncached(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        self.generate_greedy_impl(store, plan, prompts, n_new, false)
    }

    fn generate_greedy_impl(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        prompts: &[Vec<i32>],
        n_new: usize,
        use_kv: bool,
    ) -> Result<Vec<Vec<i32>>> {
        ensure!(plan.0.len() == self.cfg.n_layers, "plan length mismatch");
        let (s, v) = (self.cfg.seq, self.cfg.vocab);
        // Fixed-shape backends must run the manifest batch (padding with
        // repeated rows); the native backend runs exactly the prompts.
        let b = if self.rt.backend().fixed_shape() { self.cfg.batch } else { prompts.len() };
        ensure!(!prompts.is_empty() && prompts.len() <= b, "1..={b} prompts");
        // Windows padded on the left to length s; track logical lengths.
        let mut windows: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for i in 0..b {
            let p = &prompts[i.min(prompts.len() - 1)];
            let take = p.len().min(s);
            let mut w = vec![0i32; s];
            w[..take].copy_from_slice(&p[p.len() - take..]);
            windows.push(w);
            lens.push(take);
        }
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut remaining = n_new;
        if use_kv && remaining > 0 {
            let done =
                self.decode_kv(store, plan, &mut windows, &mut lens, &mut generated, remaining)?;
            remaining -= done;
        }
        for _ in 0..remaining {
            let flat: Vec<i32> = windows.iter().flatten().copied().collect();
            let tokens = Tensor::from_i32(&[b, s], flat);
            let logits = self.logits(store, plan, &tokens)?;
            let data = logits.f32s()?;
            for (i, g) in generated.iter_mut().enumerate() {
                let pos = lens[i] - 1; // last real token's prediction
                let best = argmax(&data[(i * s + pos) * v..(i * s + pos + 1) * v]);
                g.push(best as i32);
                // Slide or append.
                if lens[i] < s {
                    windows[i][lens[i]] = best as i32;
                    lens[i] += 1;
                } else {
                    windows[i].rotate_left(1);
                    windows[i][s - 1] = best as i32;
                }
            }
        }
        Ok(generated)
    }

    /// KV-cached greedy decode: prefill the current windows once, then
    /// emit tokens with single-position layer passes. Emits at most
    /// `n_new` tokens; stops early (returning the emitted count, windows
    /// and lengths seed-consistent) when any row's window fills and the
    /// sliding-window rotation invalidates the cached positions.
    fn decode_kv(
        &self,
        store: &TensorStore,
        plan: &LayerPlan,
        windows: &mut [Vec<i32>],
        lens: &mut [usize],
        generated: &mut [Vec<i32>],
        n_new: usize,
    ) -> Result<usize> {
        let backend = self.rt.backend();
        let cfg = &self.cfg;
        let (b, s, v, d) = (windows.len(), cfg.seq, cfg.vocab, cfg.d_model);
        let n_real = generated.len();
        let mut kv = KvCache::new(cfg.n_layers, b, s, d);
        // Prefill: one full-window inference pass seeding every layer's
        // K/V, then the head over just each row's last real position.
        let flat: Vec<i32> = windows.iter().flatten().copied().collect();
        let tokens = Tensor::from_i32(&[b, s], flat);
        let mut x = self.embed(store, &tokens)?;
        for (l, kind) in plan.0.iter().enumerate() {
            let params = self.layer_params(store, l, kind)?;
            x = backend.layer_prefill(cfg, &params, &x, &mut kv, l)?;
        }
        let xs = x.f32s()?;
        let mut rows = vec![0.0f32; b * d];
        for i in 0..b {
            let p = lens[i] - 1;
            rows[i * d..(i + 1) * d].copy_from_slice(&xs[(i * s + p) * d..(i * s + p + 1) * d]);
        }
        let hidden = Tensor::from_f32(&[b, 1, d], rows);
        let logits =
            backend.head_logits(cfg, &hidden, store.get("ln_f")?, store.get("emb")?)?;
        // `last[i]` is the most recent token of row i, pending append;
        // pad rows (fixed-shape batches) mirror the last real row.
        let mut last = vec![0i32; b];
        {
            let data = logits.f32s()?;
            for i in 0..b {
                let t = argmax(&data[i * v..(i + 1) * v]) as i32;
                if i < n_real {
                    generated[i].push(t);
                    last[i] = t;
                } else {
                    last[i] = last[n_real - 1];
                }
            }
        }
        let mut emitted = 1usize;
        while emitted < n_new {
            if lens.iter().any(|&l| l >= s) {
                // A full window would rotate: append/slide seed-style and
                // hand the rest to the full-recompute loop.
                Self::append_or_slide(windows, lens, &last, s);
                return Ok(emitted);
            }
            let mut pos = vec![0usize; b];
            for i in 0..b {
                windows[i][lens[i]] = last[i];
                pos[i] = lens[i];
                lens[i] += 1;
            }
            let toks = Tensor::from_i32(&[b, 1], last.clone());
            let mut x = self.embed(store, &toks)?;
            for (l, kind) in plan.0.iter().enumerate() {
                let params = self.layer_params(store, l, kind)?;
                x = backend.layer_decode(cfg, &params, &x, &mut kv, l, &pos)?;
            }
            let logits =
                backend.head_logits(cfg, &x, store.get("ln_f")?, store.get("emb")?)?;
            let data = logits.f32s()?;
            for i in 0..b {
                let t = argmax(&data[i * v..(i + 1) * v]) as i32;
                if i < n_real {
                    generated[i].push(t);
                    last[i] = t;
                } else {
                    last[i] = last[n_real - 1];
                }
            }
            emitted += 1;
        }
        // Append the final emission so the window state stays consistent
        // with the recompute path (harmless if generation is done).
        Self::append_or_slide(windows, lens, &last, s);
        Ok(emitted)
    }

    fn append_or_slide(windows: &mut [Vec<i32>], lens: &mut [usize], last: &[i32], s: usize) {
        for i in 0..windows.len() {
            if lens[i] < s {
                windows[i][lens[i]] = last[i];
                lens[i] += 1;
            } else {
                windows[i].rotate_left(1);
                windows[i][s - 1] = last[i];
            }
        }
    }

    /// Teacher-forced per-layer forward used for layer-wise KD: returns
    /// the (input, output) pair of every layer under the dense model.
    pub fn forward_trace(
        &self,
        store: &TensorStore,
        tokens: &Tensor,
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let mut x = self.embed(store, tokens)?;
        let mut inputs = Vec::with_capacity(self.cfg.n_layers);
        let mut outputs = Vec::with_capacity(self.cfg.n_layers);
        for l in 0..self.cfg.n_layers {
            inputs.push(x.clone());
            let y = self.layer_forward_infer(store, l, &LayerKind::Dense, &x)?;
            outputs.push(y.clone());
            x = y;
        }
        Ok((inputs, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Json;

    fn cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"configs":{"t":{"vocab":64,"d_model":16,"n_layers":6,"n_heads":2,
            "d_inter":32,"seq":8,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    #[test]
    fn layer_plan_construction() {
        let c = cfg();
        let plan = LayerPlan::all_dense(&c);
        assert_eq!(plan.0.len(), 6);
        assert!(plan.cured_layers().is_empty());
        let plan = LayerPlan::with_cured(&c, &[2, 4], 4, "all");
        assert_eq!(plan.cured_layers(), vec![2, 4]);
        assert_eq!(plan.0[1], LayerKind::Dense);
        assert_eq!(plan.0[2], LayerKind::Cured { rank: 4, combo: "all".into() });
    }

    #[test]
    fn layer_params_views_match_plan() {
        let c = cfg();
        let mut rng = crate::util::Rng::new(5, 0);
        let mut store = c.init_dense(&mut rng);
        let rt = Runtime::native();
        let pipe = Pipeline { rt: &rt, cfg: c.clone() };
        let p = pipe.layer_params(&store, 1, &LayerKind::Dense).unwrap();
        assert!(!p.q.is_cured() && !p.k.is_cured() && !p.gate.is_cured());
        // Cure layer 1 (combo qk: gate stays dense), then re-assemble.
        let calib = crate::calib::Calibration {
            attn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            ffn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            angular: vec![0.0; c.n_layers],
            n_examples: 1,
        };
        let opts = crate::compress::CompressOptions {
            combo: "qk".into(),
            r_max: 4,
            ..Default::default()
        };
        crate::compress::cure_layers(&mut store, &c, &calib, &[1], &opts).unwrap();
        let kind = LayerKind::Cured { rank: 4, combo: "qk".into() };
        let p = pipe.layer_params(&store, 1, &kind).unwrap();
        assert!(p.q.is_cured() && p.k.is_cured());
        assert!(!p.gate.is_cured());
        assert_eq!(p.q.rank(), Some(4));
        // A dense view of a cured layer must fail loudly (w_q is gone).
        assert!(pipe.layer_params(&store, 1, &LayerKind::Dense).is_err());
    }

    #[test]
    fn merged_u_adds_delta() {
        let c = cfg();
        let rt = Runtime::native();
        let pipe = Pipeline { rt: &rt, cfg: c };
        let mut store = TensorStore::new();
        store.insert("L0.u_q", Tensor::from_f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        store.insert("L0.du_q", Tensor::from_f32(&[2, 2], vec![0.5, 0.0, -1.0, 0.25]));
        let u = pipe.merged_u(&store, 0, "q").unwrap();
        assert_eq!(u.f32s().unwrap(), &[1.5, 2.0, 2.0, 4.25]);
    }
}

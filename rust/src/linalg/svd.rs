//! Singular value decompositions.
//!
//! * `jacobi_svd` — exact one-sided Jacobi SVD; robust for matrices whose
//!   smaller dimension is at most a few hundred (every CUR factor and every
//!   small/medium weight in this repo).
//! * `rand_svd` — randomized truncated SVD (Halko-Martinsson-Tropp) used
//!   for the DEIM selection on full weight matrices, where only the top-r
//!   singular vectors are needed.

use super::{householder_qr, Mat};
use crate::util::Rng;

/// SVD result: `a ≈ u * diag(s) * v^T`, singular values descending.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Mat, // m x k
    pub s: Vec<f64>, // k
    pub v: Mat, // n x k
}

/// Exact one-sided Jacobi SVD.
///
/// Rotates column pairs of a working copy until all pairs are orthogonal;
/// the column norms become singular values, normalized columns the left
/// vectors, and the accumulated rotations the right vectors. We always
/// orthogonalize over the *smaller* dimension by transposing when needed.
pub fn jacobi_svd(a: &Mat) -> Svd {
    if a.rows < a.cols {
        // A = U S V^T  <=>  A^T = V S U^T.
        let t = jacobi_svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let (m, n) = (a.rows, a.cols);
    // Column-major working copy for fast column ops.
    let mut w: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut v = Mat::eye(n);
    let tol = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    app += w[p][i] * w[p][i];
                    aqq += w[q][i] * w[q][i];
                    apq += w[p][i] * w[q][i];
                }
                if apq.abs() <= tol * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[p][i];
                    let wq = w[q][i];
                    w[p][i] = c * wp - s * wq;
                    w[q][i] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-300 {
            break;
        }
    }
    // Extract singular values and sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = w.iter().map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].total_cmp(&norms[i]));
    let mut u = Mat::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vv = Mat::zeros(n, n);
    for (jj, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(nj);
        for i in 0..m {
            u[(i, jj)] = if nj > 1e-300 { w[j][i] / nj } else { 0.0 };
        }
        for i in 0..n {
            vv[(i, jj)] = v[(i, j)];
        }
    }
    Svd { u, s, v: vv }
}

/// Randomized truncated SVD: top-k factors of a large matrix.
///
/// Oversampling + `power_iters` subspace iterations per HMT; accuracy is
/// ample for DEIM index selection and σ_{r+1} reporting.
pub fn rand_svd(a: &Mat, k: usize, oversample: usize, power_iters: usize, rng: &mut Rng) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let kk = (k + oversample).min(n).min(m);
    // Range finder: Y = A Ω.
    let omega = Mat::random_normal(n, kk, rng);
    let mut y = a.matmul(&omega);
    let (mut q, _) = householder_qr(&y);
    for _ in 0..power_iters {
        // Subspace iteration with re-orthogonalization.
        let z = a.matmul_tn(&q); // A^T Q : n x kk
        let (qz, _) = householder_qr(&z);
        y = a.matmul(&qz);
        let (q2, _) = householder_qr(&y);
        q = q2;
    }
    // B = Q^T A (kk x n); small exact SVD.
    let b = q.matmul_tn(a);
    let sb = jacobi_svd(&b);
    // U = Q * U_b, truncate to k.
    let u_full = q.matmul(&sb.u);
    let k = k.min(sb.s.len());
    let mut u = Mat::zeros(m, k);
    let mut v = Mat::zeros(n, k);
    for i in 0..m {
        for j in 0..k {
            u[(i, j)] = u_full[(i, j)];
        }
    }
    for i in 0..n {
        for j in 0..k {
            v[(i, j)] = sb.v[(i, j)];
        }
    }
    Svd { u, s: sb.s[..k].to_vec(), v }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruct(svd: &Svd) -> Mat {
        let k = svd.s.len();
        let mut us = svd.u.clone();
        for i in 0..us.rows {
            for j in 0..k {
                us[(i, j)] *= svd.s[j];
            }
        }
        us.matmul(&svd.v.transpose())
    }

    #[test]
    fn jacobi_reconstructs_random() {
        for (m, n, seed) in [(8, 8, 1u64), (20, 6, 2), (6, 20, 3), (64, 32, 4)] {
            let mut rng = Rng::new(seed, 0);
            let a = Mat::random_normal(m, n, &mut rng);
            let svd = jacobi_svd(&a);
            assert!(
                reconstruct(&svd).sub(&a).fro_norm() < 1e-9 * a.fro_norm(),
                "reconstruction failed {m}x{n}"
            );
            // Orthonormality.
            let k = svd.s.len();
            assert!(svd.u.matmul_tn(&svd.u).sub(&Mat::eye(k)).fro_norm() < 1e-9);
            assert!(svd.v.matmul_tn(&svd.v).sub(&Mat::eye(k)).fro_norm() < 1e-9);
            // Descending.
            for i in 1..k {
                assert!(svd.s[i] <= svd.s[i - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn jacobi_known_singular_values() {
        // diag(5, 3, 1) embedded in 5x3.
        let mut a = Mat::zeros(5, 3);
        a[(0, 0)] = 5.0;
        a[(1, 1)] = 3.0;
        a[(2, 2)] = 1.0;
        let svd = jacobi_svd(&a);
        assert!((svd.s[0] - 5.0).abs() < 1e-12);
        assert!((svd.s[1] - 3.0).abs() < 1e-12);
        assert!((svd.s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_rank_deficient() {
        let mut rng = Rng::new(7, 0);
        let b = Mat::random_normal(10, 2, &mut rng);
        let c = Mat::random_normal(2, 8, &mut rng);
        let a = b.matmul(&c); // rank 2
        let svd = jacobi_svd(&a);
        assert!(svd.s[2] < 1e-10 * svd.s[0]);
        assert!(reconstruct(&svd).sub(&a).fro_norm() < 1e-9 * a.fro_norm());
    }

    #[test]
    fn rand_svd_matches_exact_leading() {
        let mut rng = Rng::new(11, 0);
        // Build a matrix with a known fast-decaying spectrum.
        let u = {
            let g = Mat::random_normal(60, 60, &mut rng);
            householder_qr(&g).0
        };
        let v = {
            let g = Mat::random_normal(40, 40, &mut rng);
            householder_qr(&g).0
        };
        let mut a = Mat::zeros(60, 40);
        let spec: Vec<f64> = (0..40).map(|i| 2.0f64.powi(-(i as i32))).collect();
        for i in 0..60 {
            for j in 0..40 {
                let mut x = 0.0;
                for (k, s) in spec.iter().enumerate().take(40) {
                    x += u[(i, k)] * s * v[(j, k)];
                }
                a[(i, j)] = x;
            }
        }
        let ex = jacobi_svd(&a);
        let rs = rand_svd(&a, 8, 8, 2, &mut rng);
        for i in 0..8 {
            assert!(
                (rs.s[i] - ex.s[i]).abs() < 1e-6 * ex.s[0],
                "sigma_{i}: {} vs {}",
                rs.s[i],
                ex.s[i]
            );
        }
    }
}

//! Dense linear algebra substrate (no BLAS/LAPACK in the vendor set).
//!
//! `Mat` is a row-major f64 matrix; decomposition math runs in f64 even
//! though model weights are f32, to keep DEIM/pseudoinverse numerics well
//! clear of selection noise. Provides blocked matmul, Householder QR,
//! one-sided Jacobi SVD (exact, small matrices), randomized truncated SVD
//! (large matrices, used for WANDA+DEIM selection), LU solve, and the
//! Moore-Penrose pseudoinverse.

mod qr;
mod solve;
mod svd;

pub use qr::householder_qr;
pub use solve::{lu_solve, lu_solve_mat, pinv, pinv_rcond};
pub use svd::{jacobi_svd, rand_svd, Svd};

use crate::tensor::Tensor;
use crate::util::Rng;

/// Row-major dense f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn random_normal(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat { rows, cols, data: (0..rows * cols).map(|_| rng.normal() as f64).collect() }
    }

    /// Convert from an f32 host tensor (must be rank 2).
    pub fn from_tensor(t: &Tensor) -> anyhow::Result<Mat> {
        anyhow::ensure!(t.shape.len() == 2, "expected rank-2 tensor, got {:?}", t.shape);
        let d = t.f32s()?;
        Ok(Mat {
            rows: t.shape[0],
            cols: t.shape[1],
            data: d.iter().map(|&x| x as f64).collect(),
        })
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::from_f32(
            &[self.rows, self.cols],
            self.data.iter().map(|&x| x as f32).collect(),
        )
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self @ other`, blocked over k for cache friendliness.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        // i-k-j loop order: streams both `other` rows and `out` rows.
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = a_row[kk];
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    out_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn dim mismatch");
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut out = Mat::zeros(m, n);
        for kk in 0..k {
            let a_row = self.row(kk);
            let b_row = other.row(kk);
            for i in 0..m {
                let a = a_row[i];
                if a == 0.0 {
                    continue;
                }
                let out_row = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    out_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum::<f64>().sqrt()
    }

    /// Spectral norm (largest singular value) via power iteration —
    /// cheap and accurate enough for error-bound reporting.
    pub fn spectral_norm(&self, rng: &mut Rng) -> f64 {
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.normal() as f64).collect();
        let mut norm = 0.0;
        for _ in 0..60 {
            // w = A v
            let mut w = vec![0.0; self.rows];
            for i in 0..self.rows {
                w[i] = self.row(i).iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            // v = A^T w
            let mut v2 = vec![0.0; self.cols];
            for i in 0..self.rows {
                let wi = w[i];
                for (j, a) in self.row(i).iter().enumerate() {
                    v2[j] += a * wi;
                }
            }
            let n2 = v2.iter().map(|x| x * x).sum::<f64>().sqrt();
            if n2 == 0.0 {
                return 0.0;
            }
            for x in &mut v2 {
                *x /= n2;
            }
            v = v2;
            norm = n2.sqrt();
        }
        norm
    }

    /// Select columns by index into a new matrix (CUR's C extraction).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (jj, &j) in idx.iter().enumerate() {
                out[(i, jj)] = self[(i, j)];
            }
        }
        out
    }

    /// Select rows by index (CUR's R extraction).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (ii, &i) in idx.iter().enumerate() {
            out.row_mut(ii).copy_from_slice(self.row(i));
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_tn_equals_explicit_transpose() {
        let mut rng = Rng::new(1, 0);
        let a = Mat::random_normal(7, 5, &mut rng);
        let b = Mat::random_normal(7, 4, &mut rng);
        let got = a.matmul_tn(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.sub(&want).fro_norm() < 1e-12);
    }

    #[test]
    fn select_rows_cols() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0], vec![7.0, 8.0, 9.0]]);
        let c = a.select_cols(&[2, 0]);
        assert_eq!(c.data, vec![3.0, 1.0, 6.0, 4.0, 9.0, 7.0]);
        let r = a.select_rows(&[1]);
        assert_eq!(r.data, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn spectral_norm_diag() {
        let mut rng = Rng::new(2, 0);
        let mut a = Mat::zeros(4, 4);
        for (i, s) in [3.0, 1.0, 0.5, 0.1].iter().enumerate() {
            a[(i, i)] = *s;
        }
        let n = a.spectral_norm(&mut rng);
        assert!((n - 3.0).abs() < 1e-6, "n={n}");
    }

    #[test]
    fn eye_identity() {
        let mut rng = Rng::new(3, 0);
        let a = Mat::random_normal(5, 5, &mut rng);
        let i = Mat::eye(5);
        assert!(a.matmul(&i).sub(&a).fro_norm() < 1e-12);
        assert!(i.matmul(&a).sub(&a).fro_norm() < 1e-12);
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = Mat::from_tensor(&t).unwrap();
        assert_eq!(m.to_tensor(), t);
    }
}

//! Linear solves and the Moore-Penrose pseudoinverse.
//!
//! LU with partial pivoting powers the DEIM interpolation solves (tiny
//! r x r systems); the pseudoinverse (via exact Jacobi SVD — CUR factors
//! always have a small dimension) computes the paper's `U = C^+ W R^+`.

use super::{jacobi_svd, Mat};
use anyhow::{bail, Result};

/// Solve `A x = b` for square A via LU with partial pivoting.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    let x = lu_solve_mat(a, &Mat { rows: b.len(), cols: 1, data: b.to_vec() })?;
    Ok(x.data)
}

/// Solve `A X = B` for square A (B may have many columns).
pub fn lu_solve_mat(a: &Mat, b: &Mat) -> Result<Mat> {
    let n = a.rows;
    if a.cols != n || b.rows != n {
        bail!("lu_solve: dim mismatch ({}x{} vs {}x{})", a.rows, a.cols, b.rows, b.cols);
    }
    let mut lu = a.clone();
    let mut x = b.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // Pivot.
        let mut pmax = k;
        for i in (k + 1)..n {
            if lu[(i, k)].abs() > lu[(pmax, k)].abs() {
                pmax = i;
            }
        }
        if lu[(pmax, k)].abs() < 1e-300 {
            bail!("lu_solve: singular matrix at pivot {k}");
        }
        if pmax != k {
            for j in 0..n {
                let t = lu[(k, j)];
                lu[(k, j)] = lu[(pmax, j)];
                lu[(pmax, j)] = t;
            }
            for j in 0..x.cols {
                let t = x[(k, j)];
                x[(k, j)] = x[(pmax, j)];
                x[(pmax, j)] = t;
            }
            perm.swap(k, pmax);
        }
        // Eliminate.
        let piv = lu[(k, k)];
        for i in (k + 1)..n {
            let f = lu[(i, k)] / piv;
            lu[(i, k)] = f;
            for j in (k + 1)..n {
                let v = lu[(k, j)];
                lu[(i, j)] -= f * v;
            }
            for j in 0..x.cols {
                let v = x[(k, j)];
                x[(i, j)] -= f * v;
            }
        }
    }
    // Back substitution.
    for j in 0..x.cols {
        for i in (0..n).rev() {
            let mut s = x[(i, j)];
            for k in (i + 1)..n {
                s -= lu[(i, k)] * x[(k, j)];
            }
            x[(i, j)] = s / lu[(i, i)];
        }
    }
    Ok(x)
}

/// Moore-Penrose pseudoinverse via exact SVD with relative cutoff.
///
/// `pinv(A) = V diag(1/s) U^T` over singular values above
/// `rcond * s_max`. CUR's C (m x r) and R (r x n) have r <= a few dozen,
/// so the Jacobi SVD here is exact and fast.
///
/// The default `rcond = 1e-6` matters: CUR factors are slices of *f32*
/// weights, so a rank-deficient selection (true rank < r) carries noise
/// singular values around `1e-7 * smax`. Inverting those puts ~1e7
/// entries into `U = C^+ W R^+` — exact in f64, catastrophic once U is
/// stored back to f32 (observed: 34% reconstruction error). Clamping at
/// 1e-6 keeps U representable while leaving genuine full-rank spectra
/// untouched.
pub fn pinv(a: &Mat) -> Mat {
    pinv_rcond(a, 1e-6)
}

/// Pseudoinverse with an explicit relative cutoff.
pub fn pinv_rcond(a: &Mat, rcond: f64) -> Mat {
    let svd = jacobi_svd(a);
    let smax = svd.s.first().copied().unwrap_or(0.0);
    let k = svd.s.len();
    // V * diag(1/s) * U^T computed without forming diag.
    let mut vs = svd.v.clone(); // n x k
    for j in 0..k {
        let inv = if svd.s[j] > rcond * smax && svd.s[j] > 0.0 { 1.0 / svd.s[j] } else { 0.0 };
        for i in 0..vs.rows {
            vs[(i, j)] *= inv;
        }
    }
    vs.matmul(&svd.u.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn lu_solve_known() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let x = lu_solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lu_solve_random_roundtrip() {
        let mut rng = Rng::new(5, 0);
        for n in [1usize, 3, 10, 40] {
            let a = Mat::random_normal(n, n, &mut rng);
            let xs = Mat::random_normal(n, 3, &mut rng);
            let b = a.matmul(&xs);
            let got = lu_solve_mat(&a, &b).unwrap();
            assert!(got.sub(&xs).fro_norm() < 1e-8 * xs.fro_norm().max(1.0), "n={n}");
        }
    }

    #[test]
    fn lu_singular_detected() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn pinv_identities() {
        let mut rng = Rng::new(6, 0);
        for (m, n) in [(12, 4), (4, 12), (8, 8)] {
            let a = Mat::random_normal(m, n, &mut rng);
            let p = pinv(&a);
            assert_eq!((p.rows, p.cols), (n, m));
            // A A+ A = A
            let apa = a.matmul(&p).matmul(&a);
            assert!(apa.sub(&a).fro_norm() < 1e-9 * a.fro_norm());
            // A+ A A+ = A+
            let pap = p.matmul(&a).matmul(&p);
            assert!(pap.sub(&p).fro_norm() < 1e-9 * p.fro_norm());
        }
    }

    #[test]
    fn pinv_rank_deficient() {
        let mut rng = Rng::new(8, 0);
        let b = Mat::random_normal(10, 2, &mut rng);
        let c = Mat::random_normal(2, 6, &mut rng);
        let a = b.matmul(&c);
        let p = pinv(&a);
        let apa = a.matmul(&p).matmul(&a);
        assert!(apa.sub(&a).fro_norm() < 1e-8 * a.fro_norm());
    }

    #[test]
    fn pinv_f32_noise_regression() {
        // Regression for the U-explosion bug: a rank-8 matrix stored as
        // f32 then pinv'd over 32 columns must NOT invert the f32-noise
        // singular values. The resulting pinv norm stays modest.
        let mut rng = Rng::new(20, 0);
        let b = Mat::random_normal(64, 8, &mut rng);
        let c = Mat::random_normal(8, 32, &mut rng);
        let exact = b.matmul(&c);
        // f32 roundtrip injects ~1e-7 relative noise.
        let noisy = Mat::from_tensor(&exact.to_tensor()).unwrap();
        let p = pinv(&noisy);
        let pmax = p.data.iter().cloned().fold(0.0f64, |m, x| m.max(x.abs()));
        assert!(pmax < 1e3, "pinv inverted f32 noise: max entry {pmax}");
        let apa = noisy.matmul(&p).matmul(&noisy);
        assert!(apa.sub(&noisy).fro_norm() < 1e-4 * noisy.fro_norm());
    }
}

//! Householder QR for tall matrices (m >= n): A = Q R with thin Q.
//!
//! Used by the randomized SVD's range finder and by tests; numerically
//! stable (no Gram-Schmidt drift).

use super::Mat;

/// Thin QR of an m x n matrix with m >= n. Returns (Q: m x n, R: n x n).
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_qr expects tall matrix, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors stored per column.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let mut x: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -x[0].signum() * x.iter().map(|v| v * v).sum::<f64>().sqrt();
        if alpha == 0.0 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        x[0] -= alpha;
        let vnorm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        if vnorm < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        for v in &mut x {
            *v /= vnorm;
        }
        // Apply I - 2 v v^T to the trailing block of R.
        for j in k..n {
            let dot: f64 = (k..m).map(|i| x[i - k] * r[(i, j)]).sum();
            for i in k..m {
                r[(i, j)] -= 2.0 * x[i - k] * dot;
            }
        }
        vs.push(x);
    }
    // Accumulate thin Q by applying reflectors (in reverse) to I's first
    // n columns.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..n {
            let dot: f64 = (k..m).map(|i| v[i - k] * q[(i, j)]).sum();
            for i in k..m {
                q[(i, j)] -= 2.0 * v[i - k] * dot;
            }
        }
    }
    // Zero strictly-lower part of R, return top n x n block.
    let mut rr = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }
    (q, rr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed, 0);
        let a = Mat::random_normal(m, n, &mut rng);
        let (q, r) = householder_qr(&a);
        // Reconstruction.
        assert!(q.matmul(&r).sub(&a).fro_norm() < 1e-10 * a.fro_norm().max(1.0));
        // Orthonormal columns.
        let qtq = q.matmul_tn(&q);
        assert!(qtq.sub(&Mat::eye(n)).fro_norm() < 1e-10);
        // R upper triangular.
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn qr_shapes() {
        check_qr(8, 8, 1);
        check_qr(20, 5, 2);
        check_qr(64, 32, 3);
        check_qr(5, 1, 4);
    }

    #[test]
    fn qr_rank_deficient() {
        // Duplicate columns: QR must still reconstruct.
        let mut rng = Rng::new(9, 0);
        let a1 = Mat::random_normal(10, 2, &mut rng);
        let mut a = Mat::zeros(10, 4);
        for i in 0..10 {
            a[(i, 0)] = a1[(i, 0)];
            a[(i, 1)] = a1[(i, 1)];
            a[(i, 2)] = a1[(i, 0)];
            a[(i, 3)] = a1[(i, 1)] * 2.0;
        }
        let (q, r) = householder_qr(&a);
        assert!(q.matmul(&r).sub(&a).fro_norm() < 1e-9);
    }
}

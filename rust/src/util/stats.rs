//! Small statistics + timing helpers shared by eval, benches and serving
//! metrics.

use std::time::Instant;

/// Online mean/min/max/std accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a copied, sorted sample (fine at our sample sizes).
/// Sort key demoting NaN for **descending** `total_cmp` sorts: NaN maps
/// to −∞ so it never outranks a finite score (`f64::total_cmp` alone
/// ranks +NaN above +∞). A NaN score carries no ordering information —
/// it must lose to every finite candidate, whichever end of the sort
/// "wins". Shared by the wanda selectors and the KV position picker.
pub fn nan_last_desc(x: f64) -> f64 {
    if x.is_nan() {
        f64::NEG_INFINITY
    } else {
        x
    }
}

/// Companion of [`nan_last_desc`] for **ascending** sorts: NaN maps to
/// +∞ so it sorts after every finite score.
pub fn nan_last_asc(x: f64) -> f64 {
    if x.is_nan() {
        f64::INFINITY
    } else {
        x
    }
}

/// Coefficient of variation (sample std / mean) of a timing sample.
/// Returns 0 for fewer than two samples or a non-positive mean — the
/// bench iteration policy treats that as "no spread measured yet".
pub fn coeff_var(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mut a = Accum::new();
    for &x in xs {
        a.add(x);
    }
    if a.mean() <= 0.0 {
        return 0.0;
    }
    a.std() / a.mean()
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Simple scope timer: `let _t = Timer::new("phase");` prints on drop if
/// CURING_TIMING=1; or use `elapsed_ms` manually.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Self {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if crate::util::config::timing_enabled() {
            eprintln!("[timing] {}: {:.1} ms", self.label, self.elapsed_ms());
        }
    }
}

/// GiB formatting used by the Table 1 / Table 2 reproductions.
pub fn gib(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0 * 1024.0)
}

pub fn mib(bytes: f64) -> f64 {
    bytes / (1024.0 * 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_moments() {
        let mut a = Accum::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.add(x);
        }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn gib_mib() {
        assert!((gib(1024.0 * 1024.0 * 1024.0) - 1.0).abs() < 1e-12);
        assert!((mib(1024.0 * 1024.0) - 1.0).abs() < 1e-12);
    }
}

//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `binary <command> [--flag] [--key value] [positional...]`.
//! Unknown flags are an error; every accessor records the option so
//! `usage()` can print a complete flag list.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    seen: std::cell::RefCell<Vec<(String, String)>>, // (name, default/desc)
}

impl Args {
    /// Parse from an explicit iterator (testable) — pass
    /// `std::env::args().skip(1)` in main.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    // `--` terminates flag parsing.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn str_opt(&self, name: &str, default: &str) -> String {
        self.seen.borrow_mut().push((name.to_string(), default.to_string()));
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_opt(&self, name: &str, default: usize) -> usize {
        self.seen.borrow_mut().push((name.to_string(), default.to_string()));
        self.flags
            .get(name)
            // curlint: allow(panic) -- CLI flag validation: abort with a clear message
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn f64_opt(&self, name: &str, default: f64) -> f64 {
        self.seen.borrow_mut().push((name.to_string(), default.to_string()));
        self.flags
            .get(name)
            // curlint: allow(panic) -- CLI flag validation: abort with a clear message
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v}")))
            .unwrap_or(default)
    }

    pub fn bool_flag(&self, name: &str) -> bool {
        self.seen.borrow_mut().push((name.to_string(), "false".to_string()));
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Flags given on the command line but never read by the command —
    /// almost always a typo; commands should error on these.
    pub fn unknown_flags(&self) -> Vec<String> {
        let seen = self.seen.borrow();
        self.flags
            .keys()
            .filter(|k| !seen.iter().any(|(n, _)| n == *k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse(&["compress", "--layers", "10", "--rank=16", "--heal"]);
        assert_eq!(a.command.as_deref(), Some("compress"));
        assert_eq!(a.usize_opt("layers", 0), 10);
        assert_eq!(a.usize_opt("rank", 8), 16);
        assert!(a.bool_flag("heal"));
        assert!(!a.bool_flag("verbose"));
        assert!(a.unknown_flags().is_empty());
    }

    #[test]
    fn defaults_and_positionals() {
        let a = parse(&["eval", "pos1", "pos2"]);
        assert_eq!(a.str_opt("model", "tiny"), "tiny");
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["run", "--oops", "3"]);
        let _ = a.str_opt("model", "tiny");
        assert_eq!(a.unknown_flags(), vec!["oops".to_string()]);
    }

    #[test]
    fn double_dash_stops_flags() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["--not-a-flag".to_string()]);
    }
}

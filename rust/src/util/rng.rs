//! Deterministic PCG64-family RNG (no `rand` crate in the vendor set).
//!
//! Every stochastic piece of the system — weight init, corpus generation,
//! random selectors, property tests — draws from this generator so whole
//! experiments replay bit-identically from a seed.

/// PCG XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    /// Seed with a stream id; different `(seed, stream)` pairs are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive a child generator; used to give each module its own stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64(), tag)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64().max(1e-300)) as f64;
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted choice over non-negative weights; returns an index.
    pub fn choice_weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9, 0);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3, 3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(5, 5);
        for _ in 0..50 {
            let k = 1 + r.below(20);
            let s = r.sample_distinct(30, k);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), s.len());
            assert!(s.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn weighted_choice_respects_zero_weight() {
        let mut r = Rng::new(11, 0);
        for _ in 0..200 {
            let i = r.choice_weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}

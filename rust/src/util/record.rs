//! The recorded-run format behind `BENCH_native.json` (schema v2).
//!
//! A *recorded run* is the machine-readable output of `cargo bench`:
//! run-level provenance (engine, commit, date, quick-vs-full mode) plus
//! one [`WorkloadRecord`] per named workload model. Every measurement
//! carries an explicit [`Unit`] (so `cargo xtask bench-diff` knows
//! which direction is an improvement), its iteration count, coefficient
//! of variation and raw samples (so the diff can derive a per-row noise
//! threshold instead of a global fudge factor), and a `deterministic`
//! flag separating timing numbers from outputs the barometer asserts
//! are bit-stable across runs (token-stream hashes, compaction counts,
//! byte footprints, losses).
//!
//! The v1 format — the flat section grab-bag earlier PRs appended to —
//! is still readable: [`RecordedRun::load`] migrates it losslessly (see
//! [`RecordedRun::migrate_v1`]), and [`RecordedRun::merge_into`]
//! preserves the old writer's contract that sections it does not own
//! (unknown top-level keys, workloads that were not re-run) survive a
//! partial bench run untouched.

use crate::util::{Json, JsonObj};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Whether a bigger number is better, worse, or neither — derived from
/// the unit, used by the delta report to classify changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    Neutral,
}

/// The closed set of measurement units a recorded run may use. An
/// unknown unit string is a schema error on load — the diff tool cannot
/// classify what it cannot orient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Generation throughput. Higher is better; timing-derived.
    TokensPerS,
    /// Optimizer-step throughput. Higher is better; timing-derived.
    StepsPerS,
    /// Mean wall time of one iteration of a timed closure.
    MsPerIter,
    /// Wall seconds of a one-shot phase (e.g. compression).
    Seconds,
    /// Memory footprint. Lower is better; deterministic.
    Bytes,
    /// A 0..1-ish quality score (accuracy, agreement, speedup factor).
    Ratio,
    /// A loss in nats. Lower is better; deterministic.
    Nats,
    /// Perplexity. Lower is better; deterministic.
    Ppl,
    /// A plain count (iterations, compactions, crashes). Neutral: the
    /// diff reports changes but never calls them regressions.
    Count,
}

impl Unit {
    pub const ALL: [Unit; 9] = [
        Unit::TokensPerS,
        Unit::StepsPerS,
        Unit::MsPerIter,
        Unit::Seconds,
        Unit::Bytes,
        Unit::Ratio,
        Unit::Nats,
        Unit::Ppl,
        Unit::Count,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Unit::TokensPerS => "tokens/s",
            Unit::StepsPerS => "steps/s",
            Unit::MsPerIter => "ms/iter",
            Unit::Seconds => "s",
            Unit::Bytes => "bytes",
            Unit::Ratio => "ratio",
            Unit::Nats => "nats",
            Unit::Ppl => "ppl",
            Unit::Count => "count",
        }
    }

    pub fn parse(s: &str) -> Option<Unit> {
        Unit::ALL.into_iter().find(|u| u.as_str() == s)
    }

    pub fn direction(self) -> Direction {
        match self {
            Unit::TokensPerS | Unit::StepsPerS | Unit::Ratio => Direction::HigherIsBetter,
            Unit::MsPerIter | Unit::Seconds | Unit::Bytes | Unit::Nats | Unit::Ppl => {
                Direction::LowerIsBetter
            }
            Unit::Count => Direction::Neutral,
        }
    }

    /// Timing-derived units vary run to run; everything else defaults
    /// to deterministic (the determinism suite asserts it).
    pub fn is_timing(self) -> bool {
        matches!(self, Unit::TokensPerS | Unit::StepsPerS | Unit::MsPerIter | Unit::Seconds)
    }
}

/// One recorded number: value, unit, and the sampling evidence behind
/// it (iterations, CV, raw samples).
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub value: f64,
    pub unit: Unit,
    /// Recorded iterations behind `value` (1 for one-shot numbers).
    pub iters: usize,
    /// Coefficient of variation across `samples` (0 when unsampled).
    pub cv: f64,
    /// Whether re-running the workload in the same build must reproduce
    /// `value` bit-for-bit. Defaults by unit; counts that depend on
    /// thread scheduling (crash tallies under fault injection) opt out
    /// via [`Measurement::volatile`].
    pub deterministic: bool,
    /// Raw per-iteration samples in the measurement's own unit.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// A single observed value (no sampling).
    pub fn point(value: f64, unit: Unit) -> Measurement {
        Measurement {
            value,
            unit,
            iters: 1,
            cv: 0.0,
            deterministic: !unit.is_timing(),
            samples: Vec::new(),
        }
    }

    /// Summarize raw samples: value = mean, CV from the spread.
    pub fn from_samples(samples: Vec<f64>, unit: Unit) -> Measurement {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        Measurement {
            value: mean,
            unit,
            iters: samples.len(),
            cv: crate::util::stats::coeff_var(&samples),
            deterministic: !unit.is_timing(),
            samples,
        }
    }

    /// Mark a by-default-deterministic measurement (e.g. a crash count
    /// under fault injection) as scheduling-dependent.
    pub fn volatile(mut self) -> Measurement {
        self.deterministic = false;
        self
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("value", Json::Num(self.value));
        o.insert("unit", Json::Str(self.unit.as_str().to_string()));
        o.insert("iters", Json::Num(self.iters as f64));
        o.insert("cv", Json::Num(self.cv));
        o.insert("deterministic", Json::Bool(self.deterministic));
        if !self.samples.is_empty() {
            o.insert("samples", Json::Arr(self.samples.iter().map(|&s| Json::Num(s)).collect()));
        }
        Json::Obj(o)
    }

    fn from_json(name: &str, j: &Json) -> Result<Measurement> {
        let o = j.as_obj().ok_or_else(|| anyhow!("measurement `{name}` is not an object"))?;
        let value = o
            .get("value")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("measurement `{name}` has no numeric `value`"))?;
        if !value.is_finite() {
            bail!("measurement `{name}` has a non-finite value");
        }
        let unit_s = o
            .get("unit")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("measurement `{name}` has no `unit`"))?;
        let unit = Unit::parse(unit_s)
            .ok_or_else(|| anyhow!("measurement `{name}` has unknown unit `{unit_s}`"))?;
        let iters = o.get("iters").and_then(Json::as_usize).unwrap_or(1);
        let cv = o.get("cv").and_then(Json::as_f64).unwrap_or(0.0);
        let deterministic = match o.get("deterministic") {
            Some(Json::Bool(b)) => *b,
            _ => !unit.is_timing(),
        };
        let samples = match o.get("samples") {
            Some(Json::Arr(a)) => a
                .iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| anyhow!("measurement `{name}` has non-numeric samples"))
                })
                .collect::<Result<Vec<f64>>>()?,
            _ => Vec::new(),
        };
        Ok(Measurement { value, unit, iters, cv, deterministic, samples })
    }
}

/// One named workload model's recorded output: its parameter point
/// (model config, sizes, grid axes), its measurements, and any loss /
/// metric series.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkloadRecord {
    pub name: String,
    /// The parameter point (and grid axes) the workload ran at. Scalar
    /// values plus arrays for sweep axes.
    pub params: JsonObj,
    /// Ordered measurement map (insertion order is report order).
    pub measurements: Vec<(String, Measurement)>,
    /// Named numeric series (e.g. a heal-loss curve).
    pub series: Vec<(String, Vec<f64>)>,
}

impl WorkloadRecord {
    pub fn new(name: &str) -> WorkloadRecord {
        WorkloadRecord { name: name.to_string(), ..Default::default() }
    }

    pub fn param_num(&mut self, key: &str, v: f64) {
        self.params.insert(key, Json::Num(v));
    }

    pub fn param_str(&mut self, key: &str, v: &str) {
        self.params.insert(key, Json::Str(v.to_string()));
    }

    pub fn param_json(&mut self, key: &str, v: Json) {
        self.params.insert(key, v);
    }

    /// Insert or replace a measurement.
    pub fn put(&mut self, key: &str, m: Measurement) {
        if let Some(slot) = self.measurements.iter_mut().find(|(k, _)| k == key) {
            slot.1 = m;
        } else {
            self.measurements.push((key.to_string(), m));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Measurement> {
        self.measurements.iter().find(|(k, _)| k == key).map(|(_, m)| m)
    }

    pub fn put_series(&mut self, key: &str, values: Vec<f64>) {
        if let Some(slot) = self.series.iter_mut().find(|(k, _)| k == key) {
            slot.1 = values;
        } else {
            self.series.push((key.to_string(), values));
        }
    }

    /// A printable digest of everything that must not change between
    /// two in-process runs of the same workload: the parameter point,
    /// every deterministic measurement, and every series. Timing rows
    /// and volatile counts are excluded. The determinism suite compares
    /// these strings verbatim.
    pub fn fingerprint(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("workload {}\n", self.name));
        for (k, v) in self.params.iter() {
            out.push_str(&format!("param {k} = {v}\n"));
        }
        for (k, m) in &self.measurements {
            if m.deterministic {
                out.push_str(&format!("{k} = {:.9e} {}\n", m.value, m.unit.as_str()));
            }
        }
        for (k, vs) in &self.series {
            out.push_str(&format!("series {k} ="));
            for v in vs {
                out.push_str(&format!(" {v:.9e}"));
            }
            out.push('\n');
        }
        out
    }

    fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        if !self.params.is_empty() {
            o.insert("params", Json::Obj(self.params.clone()));
        }
        let mut ms = JsonObj::new();
        for (k, m) in &self.measurements {
            ms.insert(k.clone(), m.to_json());
        }
        o.insert("measurements", Json::Obj(ms));
        if !self.series.is_empty() {
            let mut se = JsonObj::new();
            for (k, vs) in &self.series {
                se.insert(k.clone(), Json::Arr(vs.iter().map(|&v| Json::Num(v)).collect()));
            }
            o.insert("series", Json::Obj(se));
        }
        Json::Obj(o)
    }

    fn from_json(name: &str, j: &Json) -> Result<WorkloadRecord> {
        let o = j.as_obj().ok_or_else(|| anyhow!("workload `{name}` is not an object"))?;
        let mut rec = WorkloadRecord::new(name);
        if let Some(Json::Obj(p)) = o.get("params") {
            rec.params = p.clone();
        }
        let ms = o
            .get("measurements")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("workload `{name}` has no `measurements` object"))?;
        for (k, v) in ms.iter() {
            rec.measurements.push((k.to_string(), Measurement::from_json(k, v)?));
        }
        if let Some(Json::Obj(se)) = o.get("series") {
            for (k, v) in se.iter() {
                let arr = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("workload `{name}` series `{k}` is not an array"))?;
                let vals = arr
                    .iter()
                    .map(|x| {
                        x.as_f64().ok_or_else(|| {
                            anyhow!("workload `{name}` series `{k}` has non-numeric entries")
                        })
                    })
                    .collect::<Result<Vec<f64>>>()?;
                rec.series.push((k.to_string(), vals));
            }
        }
        Ok(rec)
    }
}

/// A full recorded run: provenance plus every workload that executed.
#[derive(Debug, Clone, PartialEq)]
pub struct RecordedRun {
    pub engine: String,
    pub commit: Option<String>,
    /// UTC calendar date of the run, `YYYY-MM-DD`.
    pub date: String,
    /// `"quick"` (CI smoke sizes) or `"full"`.
    pub mode: String,
    pub workloads: Vec<WorkloadRecord>,
    /// Unknown top-level sections preserved verbatim across merges.
    pub extra: Vec<(String, Json)>,
}

impl RecordedRun {
    pub const SCHEMA: f64 = 2.0;

    /// A fresh run stamped with today's date and the commit from the
    /// environment (CURING_COMMIT / GITHUB_SHA), if any.
    pub fn new(engine: &str, quick: bool) -> RecordedRun {
        RecordedRun {
            engine: engine.to_string(),
            commit: crate::util::config::commit_sha(),
            date: today_utc(),
            mode: if quick { "quick" } else { "full" }.to_string(),
            workloads: Vec::new(),
            extra: Vec::new(),
        }
    }

    pub fn workload(&self, name: &str) -> Option<&WorkloadRecord> {
        self.workloads.iter().find(|w| w.name == name)
    }

    /// Insert or replace a workload record by name.
    pub fn put_workload(&mut self, rec: WorkloadRecord) {
        if let Some(slot) = self.workloads.iter_mut().find(|w| w.name == rec.name) {
            *slot = rec;
        } else {
            self.workloads.push(rec);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("schema", Json::Num(Self::SCHEMA));
        o.insert("engine", Json::Str(self.engine.clone()));
        match &self.commit {
            Some(c) => o.insert("commit", Json::Str(c.clone())),
            None => o.insert("commit", Json::Null),
        }
        o.insert("date", Json::Str(self.date.clone()));
        o.insert("mode", Json::Str(self.mode.clone()));
        let mut ws = JsonObj::new();
        for w in &self.workloads {
            ws.insert(w.name.clone(), w.to_json());
        }
        o.insert("workloads", Json::Obj(ws));
        for (k, v) in &self.extra {
            o.insert(k.clone(), v.clone());
        }
        Json::Obj(o)
    }

    /// Strict v2 parse: measurements must carry known units and finite
    /// values. Top-level keys outside the schema land in `extra`.
    pub fn from_json(j: &Json) -> Result<RecordedRun> {
        let o = j.as_obj().ok_or_else(|| anyhow!("recorded run is not a JSON object"))?;
        let ws = o
            .get("workloads")
            .and_then(Json::as_obj)
            .ok_or_else(|| {
                anyhow!("recorded run has no `workloads` object (v1 file? see `RecordedRun::load`)")
            })?;
        let mut run = RecordedRun {
            engine: o.get("engine").and_then(Json::as_str).unwrap_or("unknown").to_string(),
            commit: o.get("commit").and_then(Json::as_str).map(str::to_string),
            date: o.get("date").and_then(Json::as_str).unwrap_or("").to_string(),
            mode: o.get("mode").and_then(Json::as_str).unwrap_or("full").to_string(),
            workloads: Vec::new(),
            extra: Vec::new(),
        };
        for (k, v) in ws.iter() {
            run.workloads.push(WorkloadRecord::from_json(k, v)?);
        }
        for (k, v) in o.iter() {
            if !matches!(k, "schema" | "engine" | "commit" | "date" | "mode" | "workloads") {
                run.extra.push((k.to_string(), v.clone()));
            }
        }
        Ok(run)
    }

    /// Load a recorded run from disk, auto-migrating the v1 flat format
    /// (detected by the absence of a `workloads` object).
    pub fn load(path: &Path) -> Result<RecordedRun> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let o = j.as_obj().ok_or_else(|| anyhow!("{}: not a JSON object", path.display()))?;
        if o.get("workloads").is_some() {
            RecordedRun::from_json(&j)
        } else {
            Ok(RecordedRun::migrate_v1(o))
        }
    }

    /// Migrate the v1 flat grab-bag into v2 without loss: every known
    /// section becomes the corresponding workload (units inferred per
    /// key), every numeric leaf becomes a measurement, strings/bools
    /// become params, numeric arrays become series, and unrecognized
    /// top-level sections are preserved verbatim in `extra`.
    pub fn migrate_v1(o: &JsonObj) -> RecordedRun {
        let quick = matches!(o.get("fast"), Some(Json::Bool(true)));
        let mut run = RecordedRun {
            engine: o.get("backend").and_then(Json::as_str).unwrap_or("native").to_string(),
            commit: None,
            date: String::new(),
            mode: if quick { "quick" } else { "full" }.to_string(),
            workloads: Vec::new(),
            extra: Vec::new(),
        };
        // v1 `rows` (micro kernel timings) + `decode` + top-level
        // `config` belong to the micro / decode_heavy workloads.
        if let Some(Json::Arr(rows)) = o.get("rows") {
            let mut micro = WorkloadRecord::new("micro");
            if let Some(Json::Str(cfg)) = o.get("config") {
                micro.param_str("config", cfg);
            }
            for row in rows {
                let Some(ro) = row.as_obj() else { continue };
                let Some(name) = ro.get("name").and_then(Json::as_str) else { continue };
                let iters = ro.get("iters").and_then(Json::as_usize).unwrap_or(1);
                for (stat, suffix) in [
                    ("mean_ms", ""),
                    ("p50_ms", " [p50]"),
                    ("p95_ms", " [p95]"),
                    ("min_ms", " [min]"),
                ] {
                    if let Some(v) = ro.get(stat).and_then(Json::as_f64) {
                        let mut m = Measurement::point(v, Unit::MsPerIter);
                        m.iters = iters;
                        micro.put(&format!("{name}{suffix}"), m);
                    }
                }
            }
            run.workloads.push(micro);
        }
        for (section, workload) in [
            ("decode", "decode_heavy"),
            ("serve", "serve_mixed"),
            ("kv_cur", "kv_cur"),
            ("peft_heal", "peft_heal"),
            ("peft_task", "peft_task"),
            ("peft_uuid", "peft_uuid"),
        ] {
            if let Some(Json::Obj(sec)) = o.get(section) {
                let mut rec = WorkloadRecord::new(workload);
                if let Some(Json::Str(cfg)) = o.get("config") {
                    if section == "decode" {
                        rec.param_str("config", cfg);
                    }
                }
                for (k, v) in sec.iter() {
                    match v {
                        Json::Num(n) => {
                            let unit = infer_v1_unit(k);
                            let mut m = Measurement::point(*n, unit);
                            if unit == Unit::Count && v1_count_is_volatile(k) {
                                m = m.volatile();
                            }
                            rec.put(k, m);
                        }
                        Json::Arr(a) if a.iter().all(|x| x.as_f64().is_some()) => {
                            rec.put_series(
                                k,
                                a.iter().filter_map(Json::as_f64).collect::<Vec<f64>>(),
                            );
                        }
                        other => rec.param_json(k, other.clone()),
                    }
                }
                run.workloads.push(rec);
            }
        }
        // Everything else (minus the v1 bookkeeping keys that the v2
        // header replaces) is preserved verbatim.
        for (k, v) in o.iter() {
            let consumed = matches!(
                k,
                "schema"
                    | "backend"
                    | "config"
                    | "fast"
                    | "rows"
                    | "decode"
                    | "serve"
                    | "kv_cur"
                    | "peft_heal"
                    | "peft_task"
                    | "peft_uuid"
            );
            if !consumed {
                run.extra.push((k.to_string(), v.clone()));
            }
        }
        run
    }

    /// Merge this run into the recorded-run file at `path`, preserving
    /// everything it does not own: workloads that were not re-run this
    /// invocation, and unknown top-level sections (both v2 `extra` keys
    /// and, via migration, any v1 sections already in the file). This
    /// is the contract the old `merge_bench_json` kept for partial
    /// bench runs — pinned by `tests/bench_record.rs`.
    pub fn merge_into(&self, path: &Path) -> Result<()> {
        let mut merged = if path.exists() {
            RecordedRun::load(path)?
        } else {
            RecordedRun {
                engine: String::new(),
                commit: None,
                date: String::new(),
                mode: String::new(),
                workloads: Vec::new(),
                extra: Vec::new(),
            }
        };
        merged.engine = self.engine.clone();
        merged.commit = self.commit.clone();
        merged.date = self.date.clone();
        merged.mode = self.mode.clone();
        for w in &self.workloads {
            merged.put_workload(w.clone());
        }
        for (k, v) in &self.extra {
            if let Some(slot) = merged.extra.iter_mut().find(|(ek, _)| ek == k) {
                slot.1 = v.clone();
            } else {
                merged.extra.push((k.clone(), v.clone()));
            }
        }
        std::fs::write(path, merged.to_json().to_string_pretty())
            .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
        Ok(())
    }
}

/// Unit inference for v1 keys (the old flat sections carried no units).
fn infer_v1_unit(key: &str) -> Unit {
    if key.contains("tokens_per_s") {
        Unit::TokensPerS
    } else if key.contains("steps_per_s") {
        Unit::StepsPerS
    } else if key.contains("_ms") {
        Unit::MsPerIter
    } else if key.contains("bytes") {
        Unit::Bytes
    } else if key.contains("loss") {
        Unit::Nats
    } else if key.starts_with("ppl") || key.contains("_ppl") {
        Unit::Ppl
    } else if key.contains("acc")
        || key.contains("agreement")
        || key.contains("speedup")
        || key.contains("occupancy")
    {
        Unit::Ratio
    } else {
        Unit::Count
    }
}

/// v1 counts that depend on thread scheduling under fault injection.
fn v1_count_is_volatile(key: &str) -> bool {
    key.contains("failures") || key.contains("crashes") || key.contains("retried")
}

/// Today's UTC date as `YYYY-MM-DD` (civil-from-days; no chrono in the
/// offline vendor set).
pub fn today_utc() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let days = (secs / 86_400) as i64;
    let (y, m, d) = civil_from_days(days);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Days-since-epoch to (year, month, day), Howard Hinnant's algorithm.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097;
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_table_is_closed_and_oriented() {
        for u in Unit::ALL {
            assert_eq!(Unit::parse(u.as_str()), Some(u));
        }
        assert_eq!(Unit::parse("furlongs/fortnight"), None);
        assert_eq!(Unit::TokensPerS.direction(), Direction::HigherIsBetter);
        assert_eq!(Unit::Bytes.direction(), Direction::LowerIsBetter);
        assert_eq!(Unit::Count.direction(), Direction::Neutral);
        assert!(Unit::MsPerIter.is_timing());
        assert!(!Unit::Bytes.is_timing());
    }

    #[test]
    fn civil_dates() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1)); // 2024-01-01
        let today = today_utc();
        assert_eq!(today.len(), 10);
        assert_eq!(&today[4..5], "-");
    }

    #[test]
    fn fingerprint_excludes_timing() {
        let mut rec = WorkloadRecord::new("w");
        rec.put("tps", Measurement::point(123.4, Unit::TokensPerS));
        rec.put("bytes", Measurement::point(4096.0, Unit::Bytes));
        rec.put("crashes", Measurement::point(2.0, Unit::Count).volatile());
        let fp = rec.fingerprint();
        assert!(fp.contains("bytes"));
        assert!(!fp.contains("tps"), "timing rows must not pin determinism: {fp}");
        assert!(!fp.contains("crashes"), "volatile counts must not pin determinism: {fp}");
    }
}

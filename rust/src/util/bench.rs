//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Runs a closure with warmup, then timed iterations under an explicit
//! [`IterPolicy`]: at least `min_iters` samples, then keep sampling
//! until the coefficient of variation drops under `cv_target` or the
//! iteration/wall-clock budget runs out. Reports mean/p50/p95/min plus
//! the raw samples and their CV, which the recorded-run format
//! ([`crate::util::record`]) serializes so `cargo xtask bench-diff` can
//! derive a per-measurement noise threshold. Used by
//! `rust/benches/` (cargo bench, `harness = false`).

use super::stats::{coeff_var, percentile};
use std::time::Instant;

/// Iteration policy for one timed measurement: warmup runs that are
/// never recorded, a floor of recorded iterations, then a CV-based stop
/// (keep sampling while the spread is above `cv_target`) bounded by an
/// iteration cap and a wall-clock budget.
#[derive(Debug, Clone, Copy)]
pub struct IterPolicy {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
    /// Stop early once `coeff_var(samples) <= cv_target` (and the
    /// `min_iters` floor is met). 0 disables the early stop.
    pub cv_target: f64,
}

impl Default for IterPolicy {
    fn default() -> Self {
        IterPolicy { warmup_iters: 2, min_iters: 5, max_iters: 50, budget_s: 2.0, cv_target: 0.05 }
    }
}

impl IterPolicy {
    /// Smoke-size policy for CI and quick-mode runs.
    pub fn quick() -> Self {
        IterPolicy { warmup_iters: 1, min_iters: 3, max_iters: 10, budget_s: 0.5, cv_target: 0.10 }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
    /// Coefficient of variation of `samples` (std / mean).
    pub cv: f64,
    /// The raw per-iteration wall times, in milliseconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Summarize raw per-iteration samples (milliseconds).
    pub fn from_samples(name: &str, samples: Vec<f64>) -> BenchResult {
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ms: mean,
            p50_ms: percentile(&samples, 50.0),
            p95_ms: percentile(&samples, 95.0),
            min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            cv: coeff_var(&samples),
            samples,
        }
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>7} it  mean {:>10.4} ms  p50 {:>10.4} ms  p95 {:>10.4} ms  cv {:>5.1}%",
            self.name,
            self.iters,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            100.0 * self.cv
        )
    }
}

pub struct Bencher {
    pub policy: IterPolicy,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { policy: IterPolicy::default() }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { policy: IterPolicy::quick() }
    }

    /// Time `f` repeatedly under the iteration policy. The closure
    /// result is returned through a volatile sink so the optimizer
    /// cannot elide the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        let p = &self.policy;
        for _ in 0..p.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        loop {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
            if samples.len() < p.min_iters.max(1) {
                continue;
            }
            if samples.len() >= p.max_iters || start.elapsed().as_secs_f64() >= p.budget_s {
                break;
            }
            if p.cv_target > 0.0 && coeff_var(&samples) <= p.cv_target {
                break;
            }
        }
        BenchResult::from_samples(name, samples)
    }
}

/// Optimizer barrier (std::hint::black_box is stable; thin alias so bench
/// code reads like criterion's).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ms >= 0.0);
        assert!(r.p95_ms >= r.p50_ms * 0.5);
        assert_eq!(r.samples.len(), r.iters);
        assert!(r.cv >= 0.0);
    }

    #[test]
    fn respects_min_iters_floor() {
        // A zero CV target disables the early stop; the budget is huge,
        // so the run must hit the min floor and then stop exactly at
        // whichever bound triggers first (max_iters here).
        let b = Bencher {
            policy: IterPolicy {
                warmup_iters: 0,
                min_iters: 4,
                max_iters: 4,
                budget_s: 60.0,
                cv_target: 0.0,
            },
        };
        let r = b.run("noop", || 1u8);
        assert_eq!(r.iters, 4);
    }

    #[test]
    fn cv_stop_halts_stable_workloads_early() {
        // A no-op body has ~zero spread; the CV stop should finish well
        // under the iteration cap once the floor is met.
        let b = Bencher {
            policy: IterPolicy {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 1000,
                budget_s: 60.0,
                cv_target: 0.95,
            },
        };
        let r = b.run("noop", || black_box(0u8));
        assert!(r.iters < 1000, "CV stop never triggered: {} iters", r.iters);
        assert!(r.iters >= 3);
    }
}

//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Runs a closure with warmup, then timed iterations until a wall-clock
//! budget or iteration cap is hit, and reports mean/p50/p95. Used by
//! `rust/benches/bench_main.rs` (cargo bench, `harness = false`).

use super::stats::percentile;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>7} it  mean {:>10.4} ms  p50 {:>10.4} ms  p95 {:>10.4} ms  min {:>10.4} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms
        )
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 2, max_iters: 50, budget_s: 2.0 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, max_iters: 10, budget_s: 0.5 }
    }

    /// Time `f` repeatedly. The closure result is returned through a
    /// volatile sink so the optimizer cannot elide the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < 3 || start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ms: mean,
            p50_ms: percentile(&samples, 50.0),
            p95_ms: percentile(&samples, 95.0),
            min_ms: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        }
    }
}

/// Optimizer barrier (std::hint::black_box is stable; thin alias so bench
/// code reads like criterion's).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::quick();
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ms >= 0.0);
        assert!(r.p95_ms >= r.p50_ms * 0.5);
    }
}

//! The one place `CURING_*` environment escape hatches are read.
//!
//! curlint's `env-var` rule forbids `env::var` everywhere else in
//! `rust/src/**`, so this table is the complete inventory — a new knob
//! means a new accessor here, documented in the same commit.
//!
//! | Variable                | Accessor                    | Effect |
//! |-------------------------|-----------------------------|--------|
//! | `CURING_RUNDIR`         | [`run_dir`]                 | Root for run outputs and cached stores (default `runs`) |
//! | `CURING_ARTIFACTS`      | [`artifacts_dir`]           | PJRT AOT artifact directory (default `artifacts`) |
//! | `CURING_BACKEND`        | [`backend_override`]        | Force `native` or `pjrt` instead of auto-detection |
//! | `CURING_THREADS`        | [`thread_count_override`]   | Kernel thread-pool width (default: available parallelism) |
//! | `CURING_NO_KV_CACHE`    | [`kv_cache_disabled`]       | `1` routes greedy decode onto the cache-free replay reference |
//! | `CURING_PRETRAIN_STEPS` | [`pretrain_steps_override`] | Pretraining length for the one-time cached dense store |
//! | `CURING_TIMING`         | [`timing_enabled`]          | `1` prints `[timing]` lines from `util::stats::Timer` |
//! | `CURING_BENCH_FAST`     | [`bench_fast`]              | `1` shrinks every bench to CI smoke sizes |
//! | `CURING_FAULTS`         | [`faults_spec`]             | Fault-injection plan wrapped around the backend (see below) |
//! | `CURING_COMMIT`         | [`commit_sha`]              | Commit stamped into recorded bench runs (falls back to `GITHUB_SHA`) |
//!
//! `CURING_FAULTS` holds a [`crate::backend::fault::FaultPlan`] spec —
//! `;`-separated clauses `seed=<u64>`, `<site>=<p>[:<kind>]` or
//! `all=<p>[:<kind>]` with site ∈ `prefill|decode|compress|head` and
//! kind ∈ `err|nan|inf|delay<ms>` (default `err`), e.g.
//! `seed=7;decode=0.05;head=0.01:nan`. When set,
//! `Runtime::open_default` wraps whichever backend it picked in a
//! [`crate::backend::fault::FaultyBackend`], so any command becomes a
//! chaos run; a malformed spec is a hard error, never a silent
//! fault-free run.

use std::path::PathBuf;

/// The single allowed `env::var` call site (see module docs).
fn var(name: &str) -> Option<String> {
    std::env::var(name).ok()
}

fn flag(name: &str) -> bool {
    var(name).as_deref() == Some("1")
}

/// `CURING_RUNDIR`: root directory for run outputs, cached stores and
/// reports. Defaults to `runs` under the current working directory.
pub fn run_dir() -> PathBuf {
    PathBuf::from(var("CURING_RUNDIR").unwrap_or_else(|| "runs".to_string()))
}

/// `CURING_ARTIFACTS`: where the PJRT backend looks for AOT artifacts
/// (`manifest.json` plus HLO programs). Defaults to `artifacts`.
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(var("CURING_ARTIFACTS").unwrap_or_else(|| "artifacts".to_string()))
}

/// `CURING_BACKEND`: force a backend (`native` or `pjrt`) instead of the
/// auto-detection in `Runtime::open_default`. `None` means auto.
/// Validation stays with the caller so unknown names keep their
/// current "hard error, list the options" behavior.
pub fn backend_override() -> Option<String> {
    var("CURING_BACKEND")
}

/// `CURING_THREADS`: worker-thread count for the native kernels' row
/// fan-out. `None` (unset, unparsable, or zero) means use the machine's
/// available parallelism.
pub fn thread_count_override() -> Option<usize> {
    var("CURING_THREADS").and_then(|s| s.parse::<usize>().ok()).filter(|&n| n > 0)
}

/// `CURING_NO_KV_CACHE=1`: route greedy decode onto the cache-free
/// per-token replay reference (same token stream, no persistent KV
/// state; the debugging escape hatch).
pub fn kv_cache_disabled() -> bool {
    flag("CURING_NO_KV_CACHE")
}

/// `CURING_PRETRAIN_STEPS`: override the pretraining length used to
/// build the one-time cached dense store. `None` means the caller's
/// default (400 for all experiments; CI smoke uses 5).
pub fn pretrain_steps_override() -> Option<usize> {
    var("CURING_PRETRAIN_STEPS").and_then(|s| s.parse().ok())
}

/// `CURING_TIMING=1`: `util::stats::Timer` prints `[timing]` lines on
/// drop.
pub fn timing_enabled() -> bool {
    flag("CURING_TIMING")
}

/// `CURING_BENCH_FAST=1`: every bench drops to CI smoke sizes.
pub fn bench_fast() -> bool {
    flag("CURING_BENCH_FAST")
}

/// `CURING_FAULTS`: a [`crate::backend::fault::FaultPlan`] spec to wrap
/// around the backend `Runtime::open_default` picks (see module docs
/// for the grammar). `None` (or empty) means no injection.
pub fn faults_spec() -> Option<String> {
    var("CURING_FAULTS").filter(|s| !s.trim().is_empty())
}

/// `CURING_COMMIT` (or CI's `GITHUB_SHA`): the commit hash stamped into
/// recorded bench runs (`util::record`). `None` means the run is
/// recorded without provenance — the harness never shells out to git.
pub fn commit_sha() -> Option<String> {
    var("CURING_COMMIT").or_else(|| var("GITHUB_SHA")).filter(|s| !s.trim().is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Env mutation is process-global, so everything lives in one test
    // (cargo runs tests in parallel threads).
    #[test]
    fn accessors_parse_and_default() {
        // Defaults with the variables unset. CI never sets these; a dev
        // shell that does will still exercise the parse paths below.
        if std::env::var_os("CURING_RUNDIR").is_none() {
            assert_eq!(run_dir(), PathBuf::from("runs"));
        }
        if std::env::var_os("CURING_ARTIFACTS").is_none() {
            assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
        }

        std::env::set_var("CURING_THREADS", "0");
        assert_eq!(thread_count_override(), None, "zero threads means auto");
        std::env::set_var("CURING_THREADS", "three");
        assert_eq!(thread_count_override(), None, "garbage means auto");
        std::env::set_var("CURING_THREADS", "3");
        assert_eq!(thread_count_override(), Some(3));
        std::env::remove_var("CURING_THREADS");

        std::env::set_var("CURING_PRETRAIN_STEPS", "17");
        assert_eq!(pretrain_steps_override(), Some(17));
        std::env::remove_var("CURING_PRETRAIN_STEPS");

        // Exercise the shared `flag` parse through the harmless timing
        // knob (flipping CURING_NO_KV_CACHE here could race a parallel
        // decode test in this binary).
        std::env::set_var("CURING_TIMING", "1");
        assert!(timing_enabled());
        std::env::set_var("CURING_TIMING", "0");
        assert!(!timing_enabled());
        std::env::remove_var("CURING_TIMING");
    }
}

//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar we exchange with the Python build step
//! (`artifacts/manifest.json`) and use for run logs: objects, arrays,
//! strings with escapes, f64 numbers, booleans, null. Preserves object
//! insertion order (important for positional argument marshalling).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep insertion order via a Vec of pairs plus a
/// key index for O(log n) lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    pairs: Vec<(String, Json)>,
    index: BTreeMap<String, usize>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, val: Json) {
        let key = key.into();
        if let Some(&i) = self.index.get(&key) {
            self.pairs[i].1 = val;
        } else {
            self.index.insert(key.clone(), self.pairs.len());
            self.pairs.push((key, val));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.index.get(key).map(|&i| &self.pairs[i].1)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["artifacts", "tiny_embed_fwd", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.as_obj()?.get(key)?;
        }
        Some(cur)
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                        v.write(out, Some(ind + 1));
                    } else {
                        v.write(out, None);
                    }
                }
                if let (Some(ind), false) = (indent, a.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(ind) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(ind + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(ind + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let (Some(ind), false) = (indent, o.is_empty()) {
                    out.push('\n');
                    out.push_str(&" ".repeat(ind));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Note: surrogate pairs unsupported (never emitted
                            // by our Python side); map to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].at(&["b"]).unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.at(&["c"]), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"tiny","dims":[8,64,256],"f":0.125,"ok":true,"s":"q\"k"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn insertion_order_preserved() {
        let j = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = j.as_obj().unwrap().iter().map(|(k, _)| k.to_string()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse("\"caf\u{e9} \\u0041\"").unwrap();
        assert_eq!(j.as_str(), Some("café A"));
    }
}

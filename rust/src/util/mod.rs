//! Infrastructure substrates: JSON, RNG, CLI, stats, bench harness.
//!
//! The offline vendor set has no serde/clap/rand/criterion, so these are
//! first-class modules of the library (and tested like everything else).

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod record;
pub mod rng;
pub mod stats;

pub use json::{Json, JsonObj};
pub use rng::Rng;

//! Healing (paper §4.5): restore the cured model's performance by
//! training only `ΔU` (with `U = U₀ + ΔU`), via knowledge distillation.
//!
//! Two drivers:
//!
//! * [`heal_layers`] — the paper's layer-wise KD: MSE between teacher and
//!   student layer outputs, per cured layer, via the backend's
//!   `heal_step` operation (teacher-forced layer inputs). Runs on any
//!   backend, native CPU included.
//! * [`SwitchedRunner`] — full-model steps on the runtime-maskable
//!   switched artifacts (`heal_full_*` = 0.9·KD(T=10) + 0.1·CE;
//!   `task_step_*` = masked CE), shared with the PEFT comparisons.
//!   Artifact-backed: needs the `pjrt` backend.
//!
//! Hyperparameters follow paper App. B: AdamW, lr 3e-4, cosine schedule
//! with 100 warmup steps.

use crate::backend::Backend;
use crate::data::{Corpus, Vocab};
use crate::pipeline::Pipeline;
use crate::runtime::Bindings;
use crate::tensor::{Tensor, TensorStore};
use anyhow::{Context, Result};

/// Cosine LR schedule with linear warmup (Loshchilov & Hutter; paper
/// App. B uses 100 warmup steps and base lr 3e-4).
pub fn cosine_lr(step: usize, total: usize, base_lr: f64, warmup: usize) -> f64 {
    if warmup > 0 && step < warmup {
        return base_lr * (step + 1) as f64 / warmup as f64;
    }
    if total <= warmup {
        return base_lr;
    }
    let p = (step - warmup) as f64 / (total - warmup).max(1) as f64;
    0.5 * base_lr * (1.0 + (std::f64::consts::PI * p.min(1.0)).cos())
}

#[derive(Debug, Clone)]
pub struct HealOptions {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup: usize,
}

impl Default for HealOptions {
    fn default() -> Self {
        // Paper App. B uses lr 3e-4 with 100 warmup steps for r=256
        // (65k-parameter ΔU per matrix). Our tiny config's ΔU is ~250x
        // smaller (r ∈ {8,16,32}), and empirically needs a proportionally
        // hotter lr to move the layer-MSE — 1e-2 recovers ~40% of the
        // k=6 perplexity gap in 200 steps (see EXPERIMENTS.md).
        HealOptions { steps: 200, base_lr: 1e-2, warmup: 100 }
    }
}

/// One recorded healing step.
#[derive(Debug, Clone)]
pub struct HealPoint {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
}

/// Layer-wise KD healing. `teacher` is the original dense store,
/// `student` the cured store (updated in place: `du_*` tensors).
/// Optimizer state is kept in `opt` across calls.
pub fn heal_layers(
    pipe: &Pipeline,
    teacher: &TensorStore,
    student: &mut TensorStore,
    opt: &mut TensorStore,
    vocab: &Vocab,
    corpus: &mut Corpus,
    opts: &HealOptions,
    start_step: usize,
) -> Result<Vec<HealPoint>> {
    let cfg = &pipe.cfg;
    let cured = crate::compress::cured_layers_of(student);
    if cured.is_empty() {
        return Ok(vec![]);
    }
    let mut history = Vec::new();
    // Clamp warmup to a fifth of the run: short healing runs (the paper
    // itself notes recovery "within the first 100 steps") must reach full
    // lr, not spend the whole budget warming up.
    let warmup = opts.warmup.min((start_step + opts.steps) / 5);
    for s in 0..opts.steps {
        let step = start_step + s;
        let lr = cosine_lr(step, start_step + opts.steps, opts.base_lr, warmup);
        let (toks, _) = corpus.batch(vocab, cfg.batch, cfg.seq);
        let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
        // One teacher trace provides the per-layer targets (paper Fig. 3d);
        // the *student's* hidden state is propagated as each layer's input
        // so cured layers learn to correct accumulated drift, not just
        // their local approximation error.
        let (_t_inputs, t_outputs) = pipe.forward_trace(teacher, &tokens)?;
        let mut x_student = pipe.embed(student, &tokens)?;
        let mut loss_sum = 0.0;
        for l in 0..cfg.n_layers {
            if !cured.contains(&l) {
                // Forward-only propagation: the inference path (no
                // backward caches) — heal_step builds its own caches for
                // the layers it actually trains.
                x_student = pipe.layer_forward_infer(
                    student,
                    l,
                    &crate::pipeline::LayerKind::Dense,
                    &x_student,
                )?;
                continue;
            }
            let out = pipe.rt.backend().heal_step(
                cfg,
                student,
                opt,
                l,
                &x_student,
                &t_outputs[l],
                lr as f32,
                (step + 1) as f32,
            )?;
            loss_sum += out.loss;
            x_student = out.y_student;
        }
        history.push(HealPoint { step, loss: loss_sum / cured.len() as f64, lr });
    }
    Ok(history)
}

/// Which full-model step family to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepMode {
    /// `heal_full_*`: 0.9·KD(T=10) + 0.1·CE against in-graph teacher.
    Heal,
    /// `task_step_*`: CE masked to answer tokens.
    Task,
}

/// Runner for the full-model switched artifacts, shared between healing
/// (Fig. 5) and PEFT task fine-tuning (Figs. 6–7). Parameter resolution
/// per artifact input name:
///   `m.*`/`v.*` → `opt` store (zero-init on first touch);
///   adapter params (`lora_*`, `mora_*`, `cl_*`) → `adapters` store;
///   CUR factors (`c_*`,`u_*`,`du_*`,`r_*`) → `student`, zeros if absent
///   (layer not cured — its switch is 0 so values are inert);
///   dense weights → `teacher` store (they also feed the in-graph
///   teacher for KD).
pub struct SwitchedRunner {
    pub artifact: String,
    pub adapter: String,
    pub mode: StepMode,
}

impl SwitchedRunner {
    pub fn new(cfg_name: &str, adapter: &str, mode: StepMode) -> SwitchedRunner {
        let artifact = match mode {
            StepMode::Heal => format!("{cfg_name}_heal_full_{adapter}"),
            StepMode::Task => format!("{cfg_name}_task_step_{adapter}"),
        };
        SwitchedRunner { artifact, adapter: adapter.to_string(), mode }
    }

    /// Switch vector: 1.0 for layers cured in the student store.
    pub fn switches(cfg: &crate::model::ModelConfig, student: &TensorStore) -> Tensor {
        let cured = crate::compress::cured_layers_of(student);
        let mut s = vec![0.0f32; cfg.n_layers];
        for l in cured {
            s[l] = 1.0;
        }
        Tensor::from_f32(&[cfg.n_layers], s)
    }

    /// One optimizer step; returns the loss. Trainable outputs are written
    /// back to their owning stores.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        pipe: &Pipeline,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f64,
        t: usize,
    ) -> Result<f64> {
        let spec = pipe.rt.spec(&self.artifact)?;
        let switches = Self::switches(&pipe.cfg, student);
        let mut b = Bindings::new()
            .bind("tokens", tokens)
            .bind("targets", targets)
            .bind("switches", &switches);
        b.bind_owned("lr", Tensor::scalar_f32(lr as f32));
        b.bind_owned("t", Tensor::scalar_f32(t as f32));
        if let Some(m) = loss_mask {
            b.bind_mut("loss_mask", m);
        }
        for io in &spec.inputs {
            if b.get(&io.name).is_some() {
                continue;
            }
            let name = &io.name;
            if let Some(rest) = name.strip_prefix("m.").or_else(|| name.strip_prefix("v.")) {
                let kind = &name[..1];
                let key = format!("{}.{kind}.{rest}", self.adapter);
                if !opt.contains(&key) {
                    opt.insert(key.clone(), Tensor::zeros(&io.shape));
                }
                b.bind_owned(name.clone(), opt.get(&key)?.clone());
            } else if is_adapter_param(name) {
                if !adapters.contains(name) {
                    adapters.insert(name.clone(), Tensor::zeros(&io.shape));
                }
                b.bind_owned(name.clone(), adapters.get(name)?.clone());
            } else if is_cur_param(name) {
                if student.contains(name) {
                    b.bind_owned(name.clone(), student.get(name)?.clone());
                } else {
                    b.bind_owned(name.clone(), Tensor::zeros(&io.shape));
                }
            } else {
                // Dense weight / norm / embedding.
                b.bind_owned(name.clone(), teacher.get(name)?.clone());
            }
        }
        let mut out = pipe.rt.execute(&self.artifact, &b)?;
        let loss = out["loss"].f32s()?[0] as f64;
        for o in &spec.outputs {
            if o.name == "loss" {
                continue;
            }
            let tensor = out.remove(&o.name).context("missing step output")?;
            if let Some(rest) =
                o.name.strip_prefix("m.").or_else(|| o.name.strip_prefix("v."))
            {
                let kind = &o.name[..1];
                opt.insert(format!("{}.{kind}.{rest}", self.adapter), tensor);
            } else if is_adapter_param(&o.name) {
                adapters.insert(o.name.clone(), tensor);
            } else {
                // du_* updates belong to the student (only written for
                // layers that are actually cured — zeros stay zeros, and
                // writing them into the student store for non-cured layers
                // would pollute it).
                if student.contains(&o.name) {
                    student.insert(o.name.clone(), tensor);
                }
            }
        }
        Ok(loss)
    }
}

fn is_adapter_param(name: &str) -> bool {
    let suffix = name.split('.').next_back().unwrap_or("");
    suffix.starts_with("lora_") || suffix.starts_with("mora_") || suffix.starts_with("cl_")
}

fn is_cur_param(name: &str) -> bool {
    let suffix = name.split('.').next_back().unwrap_or("");
    suffix.starts_with("c_") || suffix.starts_with("u_") || suffix.starts_with("du_")
        || suffix.starts_with("r_")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_shape() {
        let base = 3e-4;
        // Warmup ramps linearly.
        assert!(cosine_lr(0, 1000, base, 100) < cosine_lr(50, 1000, base, 100));
        assert!((cosine_lr(99, 1000, base, 100) - base).abs() < base * 0.02);
        // Decays after warmup.
        assert!(cosine_lr(500, 1000, base, 100) < base);
        assert!(cosine_lr(999, 1000, base, 100) < cosine_lr(500, 1000, base, 100));
        // Approaches zero at the end.
        assert!(cosine_lr(1000, 1000, base, 100) < base * 0.01);
    }

    #[test]
    fn param_classifiers() {
        assert!(is_adapter_param("L3.lora_a_q"));
        assert!(is_adapter_param("L3.mora_m_gate"));
        assert!(is_adapter_param("L3.cl_u_k"));
        assert!(!is_adapter_param("L3.w_q"));
        assert!(is_cur_param("L3.du_q"));
        assert!(is_cur_param("L3.c_gate"));
        assert!(!is_cur_param("L3.w_gate"));
        assert!(!is_cur_param("emb"));
    }
}

//! Healing (paper §4.5): restore the cured model's performance by
//! training only `ΔU` (with `U = U₀ + ΔU`), via knowledge distillation.
//!
//! Two drivers:
//!
//! * [`heal_layers`] — the paper's layer-wise KD: MSE between teacher and
//!   student layer outputs, per cured layer, via the backend's
//!   `heal_step` operation (teacher-forced layer inputs). Runs on any
//!   backend, native CPU included.
//! * [`SwitchedRunner`] — full-model switched steps (`heal_full` =
//!   0.9·KD(T=10) + 0.1·CE; `task_step` = masked CE), shared with the
//!   PEFT comparisons. Routed through
//!   [`Backend::switched_step`]: the native backend runs the blended
//!   forward + adapter-restricted backprop directly, the pjrt backend
//!   dispatches the runtime-maskable switched AOT artifacts.
//!
//! Hyperparameters follow paper App. B: AdamW, lr 3e-4, cosine schedule
//! with 100 warmup steps.

use crate::backend::Backend;
pub use crate::backend::StepMode;
use crate::data::{Corpus, Vocab};
use crate::peft::Adapter;
use crate::pipeline::Pipeline;
use crate::tensor::{Tensor, TensorStore};
use anyhow::Result;

/// Cosine LR schedule with linear warmup (Loshchilov & Hutter; paper
/// App. B uses 100 warmup steps and base lr 3e-4).
pub fn cosine_lr(step: usize, total: usize, base_lr: f64, warmup: usize) -> f64 {
    if warmup > 0 && step < warmup {
        return base_lr * (step + 1) as f64 / warmup as f64;
    }
    if total <= warmup {
        return base_lr;
    }
    let p = (step - warmup) as f64 / (total - warmup).max(1) as f64;
    0.5 * base_lr * (1.0 + (std::f64::consts::PI * p.min(1.0)).cos())
}

#[derive(Debug, Clone)]
pub struct HealOptions {
    pub steps: usize,
    pub base_lr: f64,
    pub warmup: usize,
}

impl Default for HealOptions {
    fn default() -> Self {
        // Paper App. B uses lr 3e-4 with 100 warmup steps for r=256
        // (65k-parameter ΔU per matrix). Our tiny config's ΔU is ~250x
        // smaller (r ∈ {8,16,32}), and empirically needs a proportionally
        // hotter lr to move the layer-MSE — 1e-2 recovers ~40% of the
        // k=6 perplexity gap in 200 steps (see EXPERIMENTS.md).
        HealOptions { steps: 200, base_lr: 1e-2, warmup: 100 }
    }
}

/// One recorded healing step.
#[derive(Debug, Clone)]
pub struct HealPoint {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
}

/// Layer-wise KD healing. `teacher` is the original dense store,
/// `student` the cured store (updated in place: `du_*` tensors).
/// Optimizer state is kept in `opt` across calls.
pub fn heal_layers(
    pipe: &Pipeline,
    teacher: &TensorStore,
    student: &mut TensorStore,
    opt: &mut TensorStore,
    vocab: &Vocab,
    corpus: &mut Corpus,
    opts: &HealOptions,
    start_step: usize,
) -> Result<Vec<HealPoint>> {
    let cfg = &pipe.cfg;
    let cured = crate::compress::cured_layers_of(student);
    if cured.is_empty() {
        return Ok(vec![]);
    }
    let mut history = Vec::new();
    // Clamp warmup to a fifth of the run: short healing runs (the paper
    // itself notes recovery "within the first 100 steps") must reach full
    // lr, not spend the whole budget warming up.
    let warmup = opts.warmup.min((start_step + opts.steps) / 5);
    for s in 0..opts.steps {
        let step = start_step + s;
        let lr = cosine_lr(step, start_step + opts.steps, opts.base_lr, warmup);
        let (toks, _) = corpus.batch(vocab, cfg.batch, cfg.seq);
        let tokens = Tensor::from_i32(&[cfg.batch, cfg.seq], toks);
        // One teacher trace provides the per-layer targets (paper Fig. 3d);
        // the *student's* hidden state is propagated as each layer's input
        // so cured layers learn to correct accumulated drift, not just
        // their local approximation error.
        let (_t_inputs, t_outputs) = pipe.forward_trace(teacher, &tokens)?;
        let mut x_student = pipe.embed(student, &tokens)?;
        let mut loss_sum = 0.0;
        for l in 0..cfg.n_layers {
            if !cured.contains(&l) {
                // Forward-only propagation: the inference path (no
                // backward caches) — heal_step builds its own caches for
                // the layers it actually trains.
                x_student = pipe.layer_forward_infer(
                    student,
                    l,
                    &crate::pipeline::LayerKind::Dense,
                    &x_student,
                )?;
                continue;
            }
            let out = pipe.rt.backend().heal_step(
                cfg,
                student,
                opt,
                l,
                &x_student,
                &t_outputs[l],
                lr as f32,
                (step + 1) as f32,
            )?;
            loss_sum += out.loss;
            x_student = out.y_student;
        }
        history.push(HealPoint { step, loss: loss_sum / cured.len() as f64, lr });
    }
    Ok(history)
}

/// Runner for the full-model switched graphs, shared between healing
/// (Fig. 5) and PEFT task fine-tuning (Figs. 6–7). A thin veneer over
/// [`Backend::switched_step`]: the backend owns parameter resolution —
/// natively that is the blended [`crate::backend::AdapterView`] forward
/// with Adam restricted to the active adapter; on pjrt it is the
/// switched AOT artifact (`{config}_heal_full_{tag}` /
/// `{config}_task_step_{tag}`) with strict missing-tensor binding.
pub struct SwitchedRunner {
    pub adapter: Adapter,
    pub mode: StepMode,
}

impl SwitchedRunner {
    pub fn new(adapter: Adapter, mode: StepMode) -> SwitchedRunner {
        SwitchedRunner { adapter, mode }
    }

    /// The pjrt artifact this runner maps to (informational on native).
    pub fn artifact_name(&self, cfg_name: &str) -> String {
        format!("{cfg_name}_{}_{}", self.mode.artifact_stem(), self.adapter.tag())
    }

    /// Switch vector: 1.0 for layers cured in the student store (the
    /// pjrt artifacts' runtime layer mask; the native backend reads the
    /// store directly instead).
    pub fn switches(cfg: &crate::model::ModelConfig, student: &TensorStore) -> Tensor {
        let cured = crate::compress::cured_layers_of(student);
        let mut s = vec![0.0f32; cfg.n_layers];
        for l in cured {
            s[l] = 1.0;
        }
        Tensor::from_f32(&[cfg.n_layers], s)
    }

    /// One optimizer step; returns the loss. Trainable updates land in
    /// their owning stores (ΔU in `student`, A/B/M/U in `adapters`).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        pipe: &Pipeline,
        teacher: &TensorStore,
        student: &mut TensorStore,
        adapters: &mut TensorStore,
        opt: &mut TensorStore,
        tokens: &Tensor,
        targets: &Tensor,
        loss_mask: Option<&Tensor>,
        lr: f64,
        t: usize,
    ) -> Result<f64> {
        pipe.rt.backend().switched_step(
            &pipe.cfg,
            teacher,
            student,
            adapters,
            opt,
            self.adapter,
            self.mode,
            tokens,
            targets,
            loss_mask,
            lr as f32,
            t as f32,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_shape() {
        let base = 3e-4;
        // Warmup ramps linearly.
        assert!(cosine_lr(0, 1000, base, 100) < cosine_lr(50, 1000, base, 100));
        assert!((cosine_lr(99, 1000, base, 100) - base).abs() < base * 0.02);
        // Decays after warmup.
        assert!(cosine_lr(500, 1000, base, 100) < base);
        assert!(cosine_lr(999, 1000, base, 100) < cosine_lr(500, 1000, base, 100));
        // Approaches zero at the end.
        assert!(cosine_lr(1000, 1000, base, 100) < base * 0.01);
    }

    #[test]
    fn artifact_names_follow_the_scheme() {
        let r = SwitchedRunner::new(Adapter::Lora, StepMode::Heal);
        assert_eq!(r.artifact_name("tiny"), "tiny_heal_full_lora");
        let r = SwitchedRunner::new(Adapter::Du, StepMode::Task);
        assert_eq!(r.artifact_name("tiny"), "tiny_task_step_du");
    }
}

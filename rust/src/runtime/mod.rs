//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`). The manifest written by
//! `python/compile/aot.py` drives generic marshalling: artifacts declare
//! named, shaped inputs/outputs, and callers bind tensors by name — the
//! runtime validates shapes/dtypes and fixes positional order.
//!
//! Interchange is HLO **text**: xla_extension 0.5.1 rejects jax>=0.5
//! serialized protos (64-bit instruction ids); the text parser reassigns
//! ids (see /opt/xla-example/README.md).

use crate::tensor::{Data, DType, Tensor};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One named input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Parsed manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub config: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A compiled artifact plus its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: client + manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Json,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Cumulative PJRT execute count (perf accounting).
    pub exec_count: std::cell::Cell<u64>,
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let mpath = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("missing {} — run `make artifacts`", mpath.display()))?;
        let manifest = Json::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Default artifacts location: `$CURING_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("CURING_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Runtime::new(Path::new(&dir))
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .at(&["artifacts"])
            .and_then(|a| a.as_obj())
            .map(|o| o.iter().map(|(k, _)| k.to_string()).collect())
            .unwrap_or_default()
    }

    pub fn spec(&self, name: &str) -> Result<ArtifactSpec> {
        let a = self
            .manifest
            .at(&["artifacts", name])
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
            a.at(&[key])
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
                .iter()
                .map(|e| {
                    Ok(IoSpec {
                        name: e
                            .at(&["name"])
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| anyhow!("io missing name"))?
                            .to_string(),
                        shape: e
                            .at(&["shape"])
                            .and_then(|v| v.as_arr())
                            .ok_or_else(|| anyhow!("io missing shape"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap())
                            .collect(),
                        dtype: DType::from_tag(
                            e.at(&["dtype"]).and_then(|v| v.as_str()).unwrap_or("f32"),
                        )?,
                    })
                })
                .collect()
        };
        Ok(ArtifactSpec {
            name: name.to_string(),
            file: a
                .at(&["file"])
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string(),
            config: a.at(&["config"]).and_then(|v| v.as_str()).unwrap_or("").to_string(),
            inputs: parse_io("inputs")?,
            outputs: parse_io("outputs")?,
        })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.spec(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse hlo {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exec = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute by name with named bindings; returns outputs keyed by the
    /// manifest's output names.
    pub fn execute(&self, name: &str, bindings: &Bindings) -> Result<HashMap<String, Tensor>> {
        let exe = self.load(name)?;
        self.execute_loaded(&exe, bindings)
    }

    pub fn execute_loaded(
        &self,
        exe: &Executable,
        bindings: &Bindings,
    ) -> Result<HashMap<String, Tensor>> {
        let lits = self.marshal_inputs(&exe.spec, bindings)?;
        let outs = exe
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", exe.spec.name))?;
        self.exec_count.set(self.exec_count.get() + 1);
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {}: {e:?}", exe.spec.name))?;
        let pieces = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", exe.spec.name))?;
        if pieces.len() != exe.spec.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                exe.spec.name,
                pieces.len(),
                exe.spec.outputs.len()
            );
        }
        let mut out = HashMap::new();
        for (io, lit) in exe.spec.outputs.iter().zip(pieces) {
            out.insert(io.name.clone(), literal_to_tensor(&lit, io)?);
        }
        Ok(out)
    }

    fn marshal_inputs(&self, spec: &ArtifactSpec, bindings: &Bindings) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(spec.inputs.len());
        for io in &spec.inputs {
            let t = bindings
                .get(&io.name)
                .ok_or_else(|| anyhow!("artifact {}: missing input '{}'", spec.name, io.name))?;
            if t.shape != io.shape {
                bail!(
                    "artifact {}: input '{}' shape {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    t.shape,
                    io.shape
                );
            }
            if t.dtype() != io.dtype {
                bail!(
                    "artifact {}: input '{}' dtype {:?} != expected {:?}",
                    spec.name,
                    io.name,
                    t.dtype(),
                    io.dtype
                );
            }
            lits.push(tensor_to_literal(t)?);
        }
        Ok(lits)
    }
}

/// Named tensor bindings for one call. Entries can borrow long-lived
/// tensors (weights in a store) or own temporaries (merged U = U0 + dU,
/// scalars) — no copies happen until literal marshalling.
#[derive(Default)]
pub struct Bindings<'a> {
    map: HashMap<String, BindRef<'a>>,
}

enum BindRef<'a> {
    Borrowed(&'a Tensor),
    Owned(Tensor),
}

impl<'a> Bindings<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Chainable borrow-binding.
    pub fn bind(mut self, name: impl Into<String>, t: &'a Tensor) -> Self {
        self.map.insert(name.into(), BindRef::Borrowed(t));
        self
    }

    pub fn bind_mut(&mut self, name: impl Into<String>, t: &'a Tensor) {
        self.map.insert(name.into(), BindRef::Borrowed(t));
    }

    /// Bind an owned scalar/temporary (stored inside the bindings).
    pub fn bind_owned(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), BindRef::Owned(t));
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name).map(|b| match b {
            BindRef::Borrowed(t) => *t,
            BindRef::Owned(t) => t,
        })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // Single-copy path: build the literal directly from raw host bytes.
    // (The obvious `Literal::vec1(..).reshape(..)` costs two extra full
    // copies per argument — measured 1.32x end-to-end on the pretrain
    // step, see EXPERIMENTS.md §Perf.)
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        Data::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
        Data::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        .map_err(|e| anyhow!("create literal: {e:?}"))
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    // Safety: f32 slices are always validly viewable as bytes (alignment
    // shrinks, length scales by 4).
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn literal_to_tensor(lit: &xla::Literal, io: &IoSpec) -> Result<Tensor> {
    match io.dtype {
        DType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))?;
            Ok(Tensor::from_f32(&io.shape, v))
        }
        DType::I32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("literal to i32 vec: {e:?}"))?;
            Ok(Tensor::from_i32(&io.shape, v))
        }
    }
}

//! Runtime facade: owns the active execution [`Backend`] and routes the
//! coordinator's model operations to it.
//!
//! Two backends exist (see [`crate::backend`]):
//!
//! * **native** (default) — pure-Rust CPU execution; builds and runs
//!   anywhere with no artifacts directory.
//! * **pjrt** (`--features pjrt`) — the AOT artifact executor on the
//!   `xla` PJRT crate; picked automatically when the artifacts directory
//!   (`$CURING_ARTIFACTS`, default `./artifacts`) holds a manifest.
//!
//! `CURING_BACKEND=native|pjrt` forces the choice. The artifact-name
//! plumbing ([`ArtifactSpec`], [`Bindings`], [`Runtime::execute`]) is
//! backend-independent: the switched full-model graphs of the PEFT
//! comparison experiments go through it, and backends without artifact
//! support reject those calls with a clear error.

use crate::backend::Backend;
use crate::tensor::{DType, Tensor};
use crate::util::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// One named input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Parsed manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub config: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Parse one artifact's spec out of a manifest.
pub fn spec_from_manifest(manifest: &Json, name: &str) -> Result<ArtifactSpec> {
    let a = manifest
        .at(&["artifacts", name])
        .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
    let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
        a.at(&[key])
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("artifact {name} missing {key}"))?
            .iter()
            .map(|e| {
                let mut shape = Vec::new();
                for d in e
                    .at(&["shape"])
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("io missing shape"))?
                {
                    shape.push(d.as_usize().ok_or_else(|| anyhow!("bad shape entry"))?);
                }
                Ok(IoSpec {
                    name: e
                        .at(&["name"])
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("io missing name"))?
                        .to_string(),
                    shape,
                    dtype: DType::from_tag(
                        e.at(&["dtype"]).and_then(|v| v.as_str()).unwrap_or("f32"),
                    )?,
                })
            })
            .collect()
    };
    Ok(ArtifactSpec {
        name: name.to_string(),
        file: a
            .at(&["file"])
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("artifact {name} missing file"))?
            .to_string(),
        config: a.at(&["config"]).and_then(|v| v.as_str()).unwrap_or("").to_string(),
        inputs: parse_io("inputs")?,
        outputs: parse_io("outputs")?,
    })
}

/// The runtime: the active backend behind a uniform face.
pub struct Runtime {
    backend: Box<dyn Backend>,
}

fn default_artifacts_dir() -> PathBuf {
    crate::util::config::artifacts_dir()
}

impl Runtime {
    /// The pure-Rust CPU backend (always available).
    pub fn native() -> Runtime {
        Runtime { backend: Box::new(crate::backend::native::NativeBackend::new()) }
    }

    /// Wrap an explicit backend.
    pub fn from_backend(backend: Box<dyn Backend>) -> Runtime {
        Runtime { backend }
    }

    /// The PJRT artifact backend over an artifacts directory.
    #[cfg(feature = "pjrt")]
    pub fn pjrt(artifacts_dir: &std::path::Path) -> Result<Runtime> {
        Ok(Runtime { backend: Box::new(crate::backend::pjrt::PjrtBackend::new(artifacts_dir)?) })
    }

    #[cfg(feature = "pjrt")]
    fn pjrt_default() -> Result<Runtime> {
        Runtime::pjrt(&default_artifacts_dir())
    }

    #[cfg(not(feature = "pjrt"))]
    fn pjrt_default() -> Result<Runtime> {
        anyhow::bail!(
            "this build has no PJRT support — rebuild with `--features pjrt` \
             (and point the `xla` dependency at a real xla-rs checkout)"
        )
    }

    /// Backend selection: `CURING_BACKEND=native|pjrt` forces one;
    /// otherwise pjrt is used when built in *and* artifacts exist, with
    /// the native backend as the universal fallback. When
    /// `CURING_FAULTS` is set, the chosen backend is wrapped in a
    /// fault-injecting [`crate::backend::fault::FaultyBackend`] — any
    /// command becomes a chaos run.
    pub fn open_default() -> Result<Runtime> {
        let rt = Self::open_default_clean()?;
        match crate::util::config::faults_spec() {
            Some(spec) => {
                let plan = crate::backend::fault::FaultPlan::parse(&spec)?;
                Ok(rt.with_faults(plan))
            }
            None => Ok(rt),
        }
    }

    fn open_default_clean() -> Result<Runtime> {
        if let Some(which) = crate::util::config::backend_override() {
            return match which.as_str() {
                "native" => Ok(Runtime::native()),
                "pjrt" => Runtime::pjrt_default(),
                other => Err(anyhow!("unknown CURING_BACKEND '{other}' (native|pjrt)")),
            };
        }
        if cfg!(feature = "pjrt") && default_artifacts_dir().join("manifest.json").exists() {
            return Runtime::pjrt_default();
        }
        Ok(Runtime::native())
    }

    /// Wrap this runtime's backend in a fault-injecting
    /// [`crate::backend::fault::FaultyBackend`] driven by `plan`.
    pub fn with_faults(self, plan: crate::backend::fault::FaultPlan) -> Runtime {
        Runtime {
            backend: Box::new(crate::backend::fault::FaultyBackend::new(self.backend, plan)),
        }
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn manifest(&self) -> &Json {
        self.backend.manifest()
    }

    /// Cumulative backend-operation count (perf accounting).
    pub fn exec_count(&self) -> u64 {
        self.backend.exec_count()
    }

    /// Whether the backend can run arbitrary named AOT artifacts.
    pub fn supports_artifacts(&self) -> bool {
        self.backend.supports_artifacts()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.backend.artifact_names()
    }

    pub fn spec(&self, name: &str) -> Result<ArtifactSpec> {
        self.backend.artifact_spec(name)
    }

    /// Execute an artifact by name with named bindings; returns outputs
    /// keyed by the manifest's output names. Errors on backends without
    /// artifact support.
    pub fn execute(&self, name: &str, bindings: &Bindings) -> Result<HashMap<String, Tensor>> {
        self.backend.execute_artifact(name, bindings)
    }
}

/// Named tensor bindings for one call. Entries can borrow long-lived
/// tensors (weights in a store) or own temporaries (merged U = U0 + dU,
/// scalars) — no copies happen until literal marshalling.
#[derive(Default)]
pub struct Bindings<'a> {
    map: HashMap<String, BindRef<'a>>,
}

enum BindRef<'a> {
    Borrowed(&'a Tensor),
    Owned(Tensor),
}

impl<'a> Bindings<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Chainable borrow-binding.
    pub fn bind(mut self, name: impl Into<String>, t: &'a Tensor) -> Self {
        self.map.insert(name.into(), BindRef::Borrowed(t));
        self
    }

    pub fn bind_mut(&mut self, name: impl Into<String>, t: &'a Tensor) {
        self.map.insert(name.into(), BindRef::Borrowed(t));
    }

    /// Bind an owned scalar/temporary (stored inside the bindings).
    pub fn bind_owned(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), BindRef::Owned(t));
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name).map(|b| match b {
            BindRef::Borrowed(t) => *t,
            BindRef::Owned(t) => t,
        })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_always_opens() {
        let rt = Runtime::native();
        assert_eq!(rt.backend_name(), "native");
        assert!(!rt.supports_artifacts());
        assert!(rt.artifact_names().is_empty());
        // Config manifest is built in.
        assert!(rt.manifest().at(&["configs", "tiny"]).is_some());
        assert!(rt.manifest().at(&["configs", "mini"]).is_some());
    }

    #[test]
    fn native_runtime_rejects_artifact_calls() {
        let rt = Runtime::native();
        let err = rt.spec("tiny_model_nll_switched").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "err: {err}");
        assert!(rt.execute("tiny_embed_fwd", &Bindings::new()).is_err());
    }

    #[test]
    fn spec_parses_from_manifest() {
        let manifest = Json::parse(
            r#"{"artifacts": {"t_op": {"file": "t_op.hlo", "config": "t",
                "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}],
                "outputs": [{"name": "y", "shape": [2], "dtype": "i32"}]}}}"#,
        )
        .unwrap();
        let spec = spec_from_manifest(&manifest, "t_op").unwrap();
        assert_eq!(spec.file, "t_op.hlo");
        assert_eq!(spec.inputs.len(), 1);
        assert_eq!(spec.inputs[0].shape, vec![2, 3]);
        assert_eq!(spec.outputs[0].dtype, DType::I32);
        assert!(spec_from_manifest(&manifest, "nope").is_err());
    }

    #[test]
    fn bindings_borrow_and_own() {
        let t = Tensor::scalar_f32(1.5);
        let mut b = Bindings::new().bind("a", &t);
        b.bind_owned("b", Tensor::scalar_f32(2.5));
        assert_eq!(b.len(), 2);
        assert_eq!(b.get("a").unwrap().f32s().unwrap()[0], 1.5);
        assert_eq!(b.get("b").unwrap().f32s().unwrap()[0], 2.5);
        assert!(b.get("c").is_none());
    }
}

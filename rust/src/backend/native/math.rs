//! f32 compute kernels for the native CPU backend.
//!
//! The hot paths are the three matmul flavors (NN, N·Bᵀ, Aᵀ·B), blocked
//! row-wise and fanned out over `std::thread::scope` workers; everything
//! else (RMSNorm, RoPE, SiLU) is memory-bound and stays single-threaded.
//! Thread count comes from `CURING_THREADS` or the machine's available
//! parallelism; small problems stay on the calling thread.

use std::sync::OnceLock;

/// Below this many multiply-adds a matmul is not worth fanning out.
const PAR_MIN_FLOPS: usize = 1 << 17;

fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("CURING_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Split `out` (m rows × n cols) into per-thread row chunks and run
/// `f(first_row, chunk)` on each; falls back to one call in place when
/// the problem is small.
fn par_row_chunks<F>(out: &mut [f32], m: usize, n: usize, flops: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = if flops < PAR_MIN_FLOPS { 1 } else { num_threads().min(m) };
    if threads <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, chunk) in out.chunks_mut(chunk_rows * n).enumerate() {
            let f = &f;
            scope.spawn(move || f(t * chunk_rows, chunk));
        }
    });
}

/// C (m×n) = A (m×k) · B (k×n), all row-major.
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nn: A size");
    assert_eq!(b.len(), k * n, "matmul_nn: B size");
    let mut out = vec![0.0f32; m * n];
    par_row_chunks(&mut out, m, n, m * k * n, |lo, chunk| {
        for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
            let a_row = &a[(lo + ri) * k..(lo + ri + 1) * k];
            for (kk, &av) in a_row.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let b_row = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// C (m×n) = A (m×k) · Bᵀ where B is (n×k) row-major: rows of C are dot
/// products of A rows with B rows (never materializes the transpose).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nt: A size");
    assert_eq!(b.len(), n * k, "matmul_nt: B size");
    let mut out = vec![0.0f32; m * n];
    par_row_chunks(&mut out, m, n, m * k * n, |lo, chunk| {
        for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
            let a_row = &a[(lo + ri) * k..(lo + ri + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in a_row.iter().zip(b_row) {
                    acc += av * bv;
                }
                *o = acc;
            }
        }
    });
    out
}

/// C (m×n) = Aᵀ · B where A is (k×m) and B is (k×n) row-major (the
/// gradient-accumulation shape: dW = Xᵀ·dY).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), k * m, "matmul_tn: A size");
    assert_eq!(b.len(), k * n, "matmul_tn: B size");
    let mut out = vec![0.0f32; m * n];
    par_row_chunks(&mut out, m, n, m * k * n, |lo, chunk| {
        let rows = chunk.len() / n;
        for kk in 0..k {
            let b_row = &b[kk * n..(kk + 1) * n];
            let a_row = &a[kk * m..(kk + 1) * m];
            for ri in 0..rows {
                let av = a_row[lo + ri];
                if av == 0.0 {
                    continue;
                }
                let out_row = &mut chunk[ri * n..(ri + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

pub const RMS_EPS: f32 = 1e-5;

/// RMSNorm over the last dim: y = x / sqrt(mean(x²)+ε) ⊙ w. Returns the
/// normalized output and the per-row inverse RMS (cached for backward).
pub fn rmsnorm_fwd(x: &[f32], w: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), d);
    let mut y = vec![0.0f32; rows * d];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + RMS_EPS).sqrt();
        inv[r] = s;
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * s * w[j];
        }
    }
    (y, inv)
}

/// RMSNorm backward: given dL/dy, the forward input `x`, the scale `w`
/// and the cached per-row inverse RMS, returns (dL/dx, dL/dw).
pub fn rmsnorm_bwd(
    dy: &[f32],
    x: &[f32],
    w: &[f32],
    inv: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d];
    let mut dw = vec![0.0f32; d];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let s = inv[r];
        // dn = dy ⊙ w; dx = s·dn − x · s³ · (dn·x)/d
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += dyr[j] * w[j] * xr[j];
            dw[j] += dyr[j] * xr[j] * s;
        }
        let c = s * s * s * dot / d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = s * dyr[j] * w[j] - xr[j] * c;
        }
    }
    (dx, dw)
}

/// Precompute the RoPE rotation table for `s` positions × `half` pairs
/// (Llama convention, base 10000): returns (cos, sin), each s×half.
pub fn rope_table(s: usize, half: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    let freqs: Vec<f64> = (0..half)
        .map(|i| (10000.0f64).powf(-(2.0 * i as f64) / (2.0 * half as f64)))
        .collect();
    for pos in 0..s {
        for (i, &freq) in freqs.iter().enumerate() {
            let angle = pos as f64 * freq;
            cos[pos * half + i] = angle.cos() as f32;
            sin[pos * half + i] = angle.sin() as f32;
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to a (b·s, nh·dh) q/k buffer. `sign` = 1.0 rotates
/// forward; −1.0 applies the inverse rotation (the backward pass).
pub fn rope_apply(
    x: &mut [f32],
    b: usize,
    s: usize,
    nh: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
    sign: f32,
) {
    let d = nh * dh;
    let half = dh / 2;
    debug_assert_eq!(x.len(), b * s * d);
    for row in 0..b * s {
        let pos = row % s;
        let xr = &mut x[row * d..(row + 1) * d];
        for h in 0..nh {
            for i in 0..half {
                let c = cos[pos * half + i];
                let sn = sin[pos * half + i] * sign;
                let j0 = h * dh + 2 * i;
                let (x0, x1) = (xr[j0], xr[j0 + 1]);
                xr[j0] = x0 * c - x1 * sn;
                xr[j0 + 1] = x0 * sn + x1 * c;
            }
        }
    }
}

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d(silu)/dx = σ(x)·(1 + x·(1 − σ(x))).
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    fn to_mat(v: &[f32], r: usize, c: usize) -> Mat {
        Mat { rows: r, cols: c, data: v.iter().map(|&x| x as f64).collect() }
    }

    fn close(a: &[f32], m: &Mat, tol: f32) {
        assert_eq!(a.len(), m.data.len());
        for (x, y) in a.iter().zip(&m.data) {
            assert!((x - *y as f32).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_flavors_match_reference() {
        let mut rng = Rng::new(1, 0);
        let (m, k, n) = (13, 17, 11);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bt = rand_vec(&mut rng, n * k);
        let at = rand_vec(&mut rng, k * m);
        close(
            &matmul_nn(&a, &b, m, k, n),
            &to_mat(&a, m, k).matmul(&to_mat(&b, k, n)),
            1e-4,
        );
        close(
            &matmul_nt(&a, &bt, m, k, n),
            &to_mat(&a, m, k).matmul(&to_mat(&bt, n, k).transpose()),
            1e-4,
        );
        close(
            &matmul_tn(&at, &b, k, m, n),
            &to_mat(&at, k, m).transpose().matmul(&to_mat(&b, k, n)),
            1e-4,
        );
    }

    #[test]
    fn matmul_parallel_path_matches_serial() {
        // Big enough to cross PAR_MIN_FLOPS with a row count that does
        // not divide evenly across workers.
        let mut rng = Rng::new(2, 0);
        let (m, k, n) = (67, 64, 96);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let got = matmul_nn(&a, &b, m, k, n);
        let want = to_mat(&a, m, k).matmul(&to_mat(&b, k, n));
        close(&got, &want, 1e-3);
    }

    #[test]
    fn rmsnorm_forward_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let w = vec![1.0f32, 1.0];
        let (y, inv) = rmsnorm_fwd(&x, &w, 1, 2);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-4);
        assert!((y[1] + 4.0 / rms).abs() < 1e-4);
        assert!((inv[0] - 1.0 / rms).abs() < 1e-5);
    }

    #[test]
    fn rmsnorm_backward_matches_finite_difference() {
        let mut rng = Rng::new(3, 0);
        let (rows, d) = (2, 5);
        let x = rand_vec(&mut rng, rows * d);
        let w: Vec<f32> = (0..d).map(|i| 0.5 + 0.2 * i as f32).collect();
        // Scalar loss: L = Σ c_i y_i with fixed random c.
        let c = rand_vec(&mut rng, rows * d);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = rmsnorm_fwd(x, &w, rows, d);
            y.iter().zip(&c).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let (_, inv) = rmsnorm_fwd(&x, &w, rows, d);
        let (dx, dw) = rmsnorm_bwd(&c, &x, &w, &inv, rows, d);
        let eps = 1e-3f32;
        for i in [0usize, 3, 7, 9] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dx[{i}]: analytic {} vs numeric {num}",
                dx[i]
            );
        }
        // dw via finite differences on one weight.
        let lw = |w2: &[f32]| -> f64 {
            let (y, _) = rmsnorm_fwd(&x, w2, rows, d);
            y.iter().zip(&c).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let mut wp = w.clone();
        wp[2] += eps;
        let mut wm = w.clone();
        wm[2] -= eps;
        let num = (lw(&wp) - lw(&wm)) / (2.0 * eps as f64);
        assert!((num - dw[2] as f64).abs() < 1e-2 * (1.0 + num.abs()));
    }

    #[test]
    fn rope_roundtrips_and_preserves_norm() {
        let (b, s, nh, dh) = (1, 4, 2, 6);
        let mut rng = Rng::new(4, 0);
        let x0 = rand_vec(&mut rng, b * s * nh * dh);
        let (cos, sin) = rope_table(s, dh / 2);
        let mut x = x0.clone();
        rope_apply(&mut x, b, s, nh, dh, &cos, &sin, 1.0);
        // Norm is preserved (rotations are orthogonal).
        let n0: f32 = x0.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3 * n0);
        // Position 0 is unrotated.
        let d = nh * dh;
        assert_eq!(&x[..d], &x0[..d]);
        // Inverse rotation restores the input.
        rope_apply(&mut x, b, s, nh, dh, &cos, &sin, -1.0);
        for (a, b_) in x.iter().zip(&x0) {
            assert!((a - b_).abs() < 1e-5);
        }
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let num = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((num - silu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }
}

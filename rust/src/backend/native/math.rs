//! f32 compute kernels for the native CPU backend.
//!
//! The hot paths are the three matmul flavors (NN, N·Bᵀ, Aᵀ·B), blocked
//! row-wise and fanned out over `std::thread::scope` workers. Each worker
//! runs a register-blocked microkernel (4×16 f32 tiles for NN, an
//! 8-lane unrolled dot for NT, 4-way k-unrolling for TN) whose unrolled
//! inner loops the autovectorizer lifts to SIMD. Per output element the
//! accumulation order over k is fixed and shape-independent, so a kernel
//! produces bit-identical rows whether it is fed one row (KV decode) or a
//! full window (prefill) — the KV-cache parity tests rely on this.
//!
//! Thread count comes from `CURING_THREADS` or the machine's available
//! parallelism; small problems stay on the calling thread. The scalar
//! seed kernels are kept (`*_scalar`) as bench baselines and as the
//! reference the tiled kernels are tested against.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Below this many multiply-adds a matmul is not worth fanning out.
pub(super) const PAR_MIN_FLOPS: usize = 1 << 17;

/// Row tile of the NN microkernel.
const MR: usize = 4;
/// Column tile of the NN microkernel (fits the 4×16 f32 accumulator
/// block in registers on AVX2-class hardware).
const NR: usize = 16;
/// Lanes of the unrolled dot-product kernel.
const DOT_LANES: usize = 8;

fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        crate::util::config::thread_count_override().unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

/// Split `out` (m rows × n cols) into per-thread row chunks and run
/// `f(first_row, chunk)` on each; falls back to one call in place when
/// the problem is small.
fn par_row_chunks<F>(out: &mut [f32], m: usize, n: usize, flops: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = if flops < PAR_MIN_FLOPS { 1 } else { num_threads().min(m) };
    if threads <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, chunk) in out.chunks_mut(chunk_rows * n).enumerate() {
            let f = &f;
            scope.spawn(move || f(t * chunk_rows, chunk));
        }
    });
}

/// Split `buf` into `tasks` stride-sized chunks and run
/// `f(task, chunk, scratch)` on each, fanned out over threads for large
/// problems. The sequential path reuses the caller's `scratch` (so small
/// calls stay allocation-free); each worker thread gets its own.
pub(super) fn par_chunk_tasks<F>(
    buf: &mut [f32],
    stride: usize,
    tasks: usize,
    flops: usize,
    scratch: &mut Vec<f32>,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut Vec<f32>) + Sync,
{
    debug_assert_eq!(buf.len(), tasks * stride);
    if tasks == 0 {
        return;
    }
    let threads = if flops < PAR_MIN_FLOPS { 1 } else { num_threads().min(tasks) };
    if threads <= 1 {
        for (t, chunk) in buf.chunks_mut(stride).enumerate() {
            f(t, chunk, scratch);
        }
        return;
    }
    let per = tasks.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, chunk) in buf.chunks_mut(per * stride).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let mut local = Vec::new(); // curlint: allow(hot-path-purity) -- per-worker scratch, allocated once per spawned thread
                for (j, piece) in chunk.chunks_mut(stride).enumerate() {
                    f(ci * per + j, piece, &mut local);
                }
            });
        }
    });
}

/// Like [`par_chunk_tasks`] but over two lockstep-chunked buffers (the
/// cached attention path: per-task softmax-probs block + head-output
/// block).
pub(super) fn par_pair_tasks<F>(
    bufa: &mut [f32],
    stride_a: usize,
    bufb: &mut [f32],
    stride_b: usize,
    tasks: usize,
    flops: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(bufa.len(), tasks * stride_a);
    debug_assert_eq!(bufb.len(), tasks * stride_b);
    if tasks == 0 {
        return;
    }
    let threads = if flops < PAR_MIN_FLOPS { 1 } else { num_threads().min(tasks) };
    if threads <= 1 {
        for (t, (ca, cb)) in
            bufa.chunks_mut(stride_a).zip(bufb.chunks_mut(stride_b)).enumerate()
        {
            f(t, ca, cb);
        }
        return;
    }
    let per = tasks.div_ceil(threads);
    std::thread::scope(|scope| {
        for (ci, (ca, cb)) in bufa
            .chunks_mut(per * stride_a)
            .zip(bufb.chunks_mut(per * stride_b))
            .enumerate()
        {
            let f = &f;
            scope.spawn(move || {
                for (j, (pa, pb)) in
                    ca.chunks_mut(stride_a).zip(cb.chunks_mut(stride_b)).enumerate()
                {
                    f(ci * per + j, pa, pb);
                }
            });
        }
    });
}

/// Unrolled dot product: 8 independent accumulator lanes (SIMD-friendly)
/// combined in a fixed tree, plus a sequential tail. The reduction order
/// depends only on the vector length, never on the surrounding shape.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / DOT_LANES;
    let mut acc = [0.0f32; DOT_LANES];
    for c in 0..chunks {
        let ao = &a[c * DOT_LANES..(c + 1) * DOT_LANES];
        let bo = &b[c * DOT_LANES..(c + 1) * DOT_LANES];
        for l in 0..DOT_LANES {
            acc[l] += ao[l] * bo[l];
        }
    }
    let mut sum = ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]));
    for i in chunks * DOT_LANES..n {
        sum += a[i] * b[i];
    }
    sum
}

/// NN microkernel over one row chunk: 4×16 register tiles, k-ascending
/// accumulation per element (same order for every tile and tail path).
fn nn_rows(a: &[f32], b: &[f32], k: usize, n: usize, lo: usize, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    let mut r = 0;
    while r + MR <= rows {
        let a_rows = [
            &a[(lo + r) * k..(lo + r + 1) * k],
            &a[(lo + r + 1) * k..(lo + r + 2) * k],
            &a[(lo + r + 2) * k..(lo + r + 3) * k],
            &a[(lo + r + 3) * k..(lo + r + 4) * k],
        ];
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MR];
            for kk in 0..k {
                let bv = &b[kk * n + j..kk * n + j + NR];
                for (ri, a_row) in a_rows.iter().enumerate() {
                    let av = a_row[kk];
                    for c in 0..NR {
                        acc[ri][c] += av * bv[c];
                    }
                }
            }
            for (ri, acc_row) in acc.iter().enumerate() {
                chunk[(r + ri) * n + j..(r + ri) * n + j + NR].copy_from_slice(acc_row);
            }
            j += NR;
        }
        while j < n {
            let mut acc = [0.0f32; MR];
            for kk in 0..k {
                let bv = b[kk * n + j];
                for (ri, a_row) in a_rows.iter().enumerate() {
                    acc[ri] += a_row[kk] * bv;
                }
            }
            for (ri, &av) in acc.iter().enumerate() {
                chunk[(r + ri) * n + j] = av;
            }
            j += 1;
        }
        r += MR;
    }
    while r < rows {
        let a_row = &a[(lo + r) * k..(lo + r + 1) * k];
        let out_row = &mut chunk[r * n..(r + 1) * n];
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [0.0f32; NR];
            for kk in 0..k {
                let av = a_row[kk];
                let bv = &b[kk * n + j..kk * n + j + NR];
                for c in 0..NR {
                    acc[c] += av * bv[c];
                }
            }
            out_row[j..j + NR].copy_from_slice(&acc);
            j += NR;
        }
        while j < n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a_row[kk] * b[kk * n + j];
            }
            out_row[j] = acc;
            j += 1;
        }
        r += 1;
    }
}

/// C (m×n) = A (m×k) · B (k×n), all row-major, written into `out`.
pub fn matmul_nn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nn: A size");
    assert_eq!(b.len(), k * n, "matmul_nn: B size");
    assert_eq!(out.len(), m * n, "matmul_nn: out size");
    par_row_chunks(out, m, n, m * k * n, |lo, chunk| nn_rows(a, b, k, n, lo, chunk));
}

/// C (m×n) = A (m×k) · B (k×n), all row-major.
pub fn matmul_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n]; // curlint: allow(hot-path-purity) -- allocating convenience wrapper; hot paths use matmul_nn_into
    matmul_nn_into(a, b, m, k, n, &mut out);
    out
}

/// C (m×n) = A (m×k) · Bᵀ where B is (n×k) row-major, into `out`: rows
/// of C are dot products of A rows with B rows (never materializes the
/// transpose).
pub(crate) fn matmul_nt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nt: A size");
    assert_eq!(b.len(), n * k, "matmul_nt: B size");
    assert_eq!(out.len(), m * n, "matmul_nt: out size");
    par_row_chunks(out, m, n, m * k * n, |lo, chunk| {
        for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
            let a_row = &a[(lo + ri) * k..(lo + ri + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                *o = dot(a_row, &b[j * k..(j + 1) * k]);
            }
        }
    });
}

/// C (m×n) = A (m×k) · Bᵀ where B is (n×k) row-major.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n]; // curlint: allow(hot-path-purity) -- allocating convenience wrapper; hot paths use matmul_nt_into
    matmul_nt_into(a, b, m, k, n, &mut out);
    out
}

/// B of an NT matmul, re-laid out once into k-major column panels of
/// `NR` so the product kernel streams one contiguous buffer and reuses
/// each panel line across every A row (ROADMAP: "packing B for large-k
/// cache locality"). Built with [`pack_nt`], consumed by
/// [`matmul_nt_packed_into`]; the buffer is reusable across calls — the
/// decode hot loop packs the LM head once and reuses it every step.
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    /// ceil(n/NR) panels, each k×NR: `data[(p·k + kk)·NR + c] =
    /// B[(p·NR + c)·k + kk]`, zero-padded in the tail panel's columns.
    data: Vec<f32>,
}

/// Pack B (n×k row-major, the NT layout) into column panels.
pub fn pack_nt(b: &[f32], n: usize, k: usize) -> PackedB {
    assert_eq!(b.len(), n * k, "pack_nt: B size");
    let panels = n.div_ceil(NR);
    let mut data = vec![0.0f32; panels * k * NR]; // curlint: allow(hot-path-purity) -- one-time pack of B into panels, amortized across decode steps
    for p in 0..panels {
        let width = (n - p * NR).min(NR);
        let base = p * k * NR;
        for c in 0..width {
            let brow = &b[(p * NR + c) * k..(p * NR + c + 1) * k];
            for (kk, &bv) in brow.iter().enumerate() {
                data[base + kk * NR + c] = bv;
            }
        }
    }
    PackedB { k, n, data }
}

/// C (m×n) = A (m×k) · Bᵀ against a pre-packed B. Per output element
/// the accumulation is k-ascending and independent of m and of the
/// surrounding shape, so a row comes out bit-identical whether computed
/// alone (single-slot decode) or inside a batch (fused decode /
/// prefill) — same guarantee as the other kernels, different reduction
/// order than [`matmul_nt`]'s lane tree (do not mix the two within one
/// parity domain).
pub fn matmul_nt_packed_into(a: &[f32], pb: &PackedB, m: usize, out: &mut [f32]) {
    let (k, n) = (pb.k, pb.n);
    assert_eq!(a.len(), m * k, "matmul_nt_packed: A size");
    assert_eq!(out.len(), m * n, "matmul_nt_packed: out size");
    let panels = n.div_ceil(NR);
    par_row_chunks(out, m, n, m * k * n, |lo, chunk| {
        let rows = chunk.len() / n;
        let mut r = 0;
        // 4-row tiles share each streamed panel line; k ascending per
        // (row, column) accumulator in every tile and tail path.
        while r + MR <= rows {
            let a_rows = [
                &a[(lo + r) * k..(lo + r + 1) * k],
                &a[(lo + r + 1) * k..(lo + r + 2) * k],
                &a[(lo + r + 2) * k..(lo + r + 3) * k],
                &a[(lo + r + 3) * k..(lo + r + 4) * k],
            ];
            for p in 0..panels {
                let width = (n - p * NR).min(NR);
                let panel = &pb.data[p * k * NR..(p + 1) * k * NR];
                let mut acc = [[0.0f32; NR]; MR];
                for kk in 0..k {
                    let bv = &panel[kk * NR..(kk + 1) * NR];
                    for (ri, a_row) in a_rows.iter().enumerate() {
                        let av = a_row[kk];
                        for c in 0..NR {
                            acc[ri][c] += av * bv[c];
                        }
                    }
                }
                for (ri, acc_row) in acc.iter().enumerate() {
                    let o = (r + ri) * n + p * NR;
                    chunk[o..o + width].copy_from_slice(&acc_row[..width]);
                }
            }
            r += MR;
        }
        while r < rows {
            let a_row = &a[(lo + r) * k..(lo + r + 1) * k];
            for p in 0..panels {
                let width = (n - p * NR).min(NR);
                let panel = &pb.data[p * k * NR..(p + 1) * k * NR];
                let mut acc = [0.0f32; NR];
                for (kk, &av) in a_row.iter().enumerate() {
                    let bv = &panel[kk * NR..(kk + 1) * NR];
                    for c in 0..NR {
                        acc[c] += av * bv[c];
                    }
                }
                let o = r * n + p * NR;
                chunk[o..o + width].copy_from_slice(&acc[..width]);
            }
            r += 1;
        }
    });
}

/// Allocating convenience over [`matmul_nt_packed_into`].
pub fn matmul_nt_packed(a: &[f32], pb: &PackedB, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * pb.n]; // curlint: allow(hot-path-purity) -- allocating convenience wrapper; hot paths use matmul_nt_packed_into
    matmul_nt_packed_into(a, pb, m, &mut out);
    out
}

/// C (m×n) = Aᵀ · B where A is (k×m) and B is (k×n) row-major (the
/// gradient-accumulation shape: dW = Xᵀ·dY), into `out`. Unrolls k by 4
/// so each output row is loaded/stored once per four k steps.
pub(crate) fn matmul_tn_into(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), k * m, "matmul_tn: A size");
    assert_eq!(b.len(), k * n, "matmul_tn: B size");
    assert_eq!(out.len(), m * n, "matmul_tn: out size");
    par_row_chunks(out, m, n, m * k * n, |lo, chunk| {
        chunk.fill(0.0);
        let rows = chunk.len() / n;
        let k4 = k / 4 * 4;
        let mut kk = 0;
        while kk < k4 {
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            for ri in 0..rows {
                let c = lo + ri;
                let (a0, a1, a2, a3) =
                    (a[kk * m + c], a[(kk + 1) * m + c], a[(kk + 2) * m + c], a[(kk + 3) * m + c]);
                let out_row = &mut chunk[ri * n..(ri + 1) * n];
                for j in 0..n {
                    out_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
            }
            kk += 4;
        }
        while kk < k {
            let b_row = &b[kk * n..(kk + 1) * n];
            for ri in 0..rows {
                let av = a[kk * m + lo + ri];
                let out_row = &mut chunk[ri * n..(ri + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
            kk += 1;
        }
    });
}

/// C (m×n) = Aᵀ · B where A is (k×m) and B is (k×n) row-major.
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n]; // curlint: allow(hot-path-purity) -- allocating convenience wrapper over par_row_chunks
    matmul_tn_into(a, b, k, m, n, &mut out);
    out
}

/// Scalar NN reference (the seed kernel): bench baseline + test oracle.
pub fn matmul_nn_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nn: A size");
    assert_eq!(b.len(), k * n, "matmul_nn: B size");
    let mut out = vec![0.0f32; m * n]; // curlint: allow(hot-path-purity) -- scalar reference kernel: bench baseline + test oracle
    par_row_chunks(&mut out, m, n, m * k * n, |lo, chunk| {
        for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
            let a_row = &a[(lo + ri) * k..(lo + ri + 1) * k];
            for (kk, &av) in a_row.iter().enumerate() {
                let b_row = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in out_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// Scalar NT reference (the seed kernel): bench baseline + test oracle.
pub fn matmul_nt_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "matmul_nt: A size");
    assert_eq!(b.len(), n * k, "matmul_nt: B size");
    let mut out = vec![0.0f32; m * n]; // curlint: allow(hot-path-purity) -- scalar reference kernel: bench baseline + test oracle
    par_row_chunks(&mut out, m, n, m * k * n, |lo, chunk| {
        for (ri, out_row) in chunk.chunks_mut(n).enumerate() {
            let a_row = &a[(lo + ri) * k..(lo + ri + 1) * k];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in a_row.iter().zip(b_row) {
                    acc += av * bv;
                }
                *o = acc;
            }
        }
    });
    out
}

pub fn add_inplace(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

pub(crate) const RMS_EPS: f32 = 1e-5;

/// RMSNorm over the last dim: y = x / sqrt(mean(x²)+ε) ⊙ w. Returns the
/// normalized output and the per-row inverse RMS (cached for backward),
/// computed in one fused pass. Produces the same y as [`rmsnorm_into`].
pub fn rmsnorm_fwd(x: &[f32], w: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), d);
    let mut y = vec![0.0f32; rows * d]; // curlint: allow(hot-path-purity) -- forward output buffer, owned by caller
    let mut inv = vec![0.0f32; rows]; // curlint: allow(hot-path-purity) -- saved rms statistics for the backward pass
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + RMS_EPS).sqrt();
        inv[r] = s;
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * s * w[j];
        }
    }
    (y, inv)
}

/// RMSNorm into a caller-provided buffer (the inference path — no
/// inverse-RMS cache, no allocation).
pub fn rmsnorm_into(x: &[f32], w: &[f32], rows: usize, d: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(y.len(), rows * d);
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let ms = xr.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + RMS_EPS).sqrt();
        let yr = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            yr[j] = xr[j] * s * w[j];
        }
    }
}

/// RMSNorm backward: given dL/dy, the forward input `x`, the scale `w`
/// and the cached per-row inverse RMS, returns (dL/dx, dL/dw).
pub fn rmsnorm_bwd(
    dy: &[f32],
    x: &[f32],
    w: &[f32],
    inv: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; rows * d]; // curlint: allow(hot-path-purity) -- gradient output buffer, owned by caller
    let mut dw = vec![0.0f32; d]; // curlint: allow(hot-path-purity) -- gradient output buffer, owned by caller
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let s = inv[r];
        // dn = dy ⊙ w; dx = s·dn − x · s³ · (dn·x)/d
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += dyr[j] * w[j] * xr[j];
            dw[j] += dyr[j] * xr[j] * s;
        }
        let c = s * s * s * dot / d as f32;
        let dxr = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            dxr[j] = s * dyr[j] * w[j] - xr[j] * c;
        }
    }
    (dx, dw)
}

/// One RoPE rotation table: cos/sin, each s×half, row-major by position.
pub(crate) struct RopeTable {
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
}

/// Fill one position's RoPE rotation row (cos and sin, each `half`
/// wide; Llama convention, base 10000). The single per-position
/// definition both [`rope_table`] and the unbounded-position decode
/// path are built on, so cached tables and on-the-fly rows are
/// bit-identical by construction.
pub fn rope_row_into(pos: usize, half: usize, cos: &mut [f32], sin: &mut [f32]) {
    debug_assert!(cos.len() == half && sin.len() == half);
    for i in 0..half {
        let freq = (10000.0f64).powf(-(2.0 * i as f64) / (2.0 * half as f64));
        let angle = pos as f64 * freq;
        cos[i] = angle.cos() as f32;
        sin[i] = angle.sin() as f32;
    }
}

/// Precompute the RoPE rotation table for `s` positions × `half` pairs:
/// returns (cos, sin), each s×half.
pub(crate) fn rope_table(s: usize, half: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cos = vec![0.0f32; s * half]; // curlint: allow(hot-path-purity) -- RoPE table built once at model setup, not per step
    let mut sin = vec![0.0f32; s * half]; // curlint: allow(hot-path-purity) -- RoPE table built once at model setup, not per step
    for pos in 0..s {
        rope_row_into(
            pos,
            half,
            &mut cos[pos * half..(pos + 1) * half],
            &mut sin[pos * half..(pos + 1) * half],
        );
    }
    (cos, sin)
}

/// Process-wide RoPE table cache keyed on (seq, half-dim). Every layer of
/// every forward shares one table per shape instead of rebuilding it
/// per layer call (ROADMAP: the rebuild dominated small-batch serving).
pub(crate) fn rope_tables_cached(s: usize, half: usize) -> Arc<RopeTable> {
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<RopeTable>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    // curlint: allow(hot-path-purity) -- one short lock per layer call guards the process-wide table cache and replaces a full per-layer table rebuild
    let mut map = cache.lock().unwrap_or_else(|e| e.into_inner());
    map.entry((s, half))
        .or_insert_with(|| {
            let (cos, sin) = rope_table(s, half);
            Arc::new(RopeTable { cos, sin })
        })
        .clone()
}

/// Apply RoPE in place to a (b·s, nh·dh) q/k buffer. `sign` = 1.0 rotates
/// forward; −1.0 applies the inverse rotation (the backward pass).
pub fn rope_apply(
    x: &mut [f32],
    b: usize,
    s: usize,
    nh: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
    sign: f32,
) {
    let d = nh * dh;
    let half = dh / 2;
    debug_assert_eq!(x.len(), b * s * d);
    for row in 0..b * s {
        let pos = row % s;
        let xr = &mut x[row * d..(row + 1) * d];
        for h in 0..nh {
            for i in 0..half {
                let c = cos[pos * half + i];
                let sn = sin[pos * half + i] * sign;
                let j0 = h * dh + 2 * i;
                let (x0, x1) = (xr[j0], xr[j0 + 1]);
                xr[j0] = x0 * c - x1 * sn;
                xr[j0 + 1] = x0 * sn + x1 * c;
            }
        }
    }
}

/// Apply RoPE in place to a (rows × nh·dh) buffer where row `i` sits at
/// sequence position `pos[i]` (the single-position KV-decode path).
// curlint: allow(dead-pub) -- reference implementation that rope_apply_rows_local is checked against in tests; kept as the documented baseline
pub fn rope_apply_rows(
    x: &mut [f32],
    pos: &[usize],
    nh: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
) {
    let d = nh * dh;
    let half = dh / 2;
    debug_assert_eq!(x.len(), pos.len() * d);
    for (i, &p) in pos.iter().enumerate() {
        let xr = &mut x[i * d..(i + 1) * d];
        rope_rotate_row(xr, nh, dh, &cos[p * half..(p + 1) * half], &sin[p * half..(p + 1) * half]);
    }
}

/// Apply RoPE in place to a (rows × nh·dh) buffer where row `i` carries
/// its own precomputed rotation row (`dh/2` cos/sin values each, e.g.
/// from [`rope_row_into`]) — the unbounded-position decode path, which
/// never touches the process-wide table cache.
pub fn rope_apply_rows_local(
    x: &mut [f32],
    rows: usize,
    nh: usize,
    dh: usize,
    cos: &[f32],
    sin: &[f32],
) {
    let d = nh * dh;
    let half = dh / 2;
    debug_assert_eq!(x.len(), rows * d);
    debug_assert!(cos.len() >= rows * half && sin.len() >= rows * half);
    for i in 0..rows {
        let xr = &mut x[i * d..(i + 1) * d];
        rope_rotate_row(xr, nh, dh, &cos[i * half..(i + 1) * half], &sin[i * half..(i + 1) * half]);
    }
}

/// Rotate one (nh·dh) row by one position's cos/sin row — the shared
/// core of [`rope_apply_rows`] and [`rope_apply_rows_local`].
#[inline]
fn rope_rotate_row(xr: &mut [f32], nh: usize, dh: usize, cos: &[f32], sin: &[f32]) {
    let half = dh / 2;
    for h in 0..nh {
        for ii in 0..half {
            let c = cos[ii];
            let sn = sin[ii];
            let j0 = h * dh + 2 * ii;
            let (x0, x1) = (xr[j0], xr[j0 + 1]);
            xr[j0] = x0 * c - x1 * sn;
            xr[j0 + 1] = x0 * sn + x1 * c;
        }
    }
}

pub(crate) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d(silu)/dx = σ(x)·(1 + x·(1 − σ(x))).
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    fn to_mat(v: &[f32], r: usize, c: usize) -> Mat {
        Mat { rows: r, cols: c, data: v.iter().map(|&x| x as f64).collect() }
    }

    fn close(a: &[f32], m: &Mat, tol: f32) {
        assert_eq!(a.len(), m.data.len());
        for (x, y) in a.iter().zip(&m.data) {
            assert!((x - *y as f32).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_flavors_match_reference() {
        let mut rng = Rng::new(1, 0);
        let (m, k, n) = (13, 17, 11);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bt = rand_vec(&mut rng, n * k);
        let at = rand_vec(&mut rng, k * m);
        close(
            &matmul_nn(&a, &b, m, k, n),
            &to_mat(&a, m, k).matmul(&to_mat(&b, k, n)),
            1e-4,
        );
        close(
            &matmul_nt(&a, &bt, m, k, n),
            &to_mat(&a, m, k).matmul(&to_mat(&bt, n, k).transpose()),
            1e-4,
        );
        close(
            &matmul_tn(&at, &b, k, m, n),
            &to_mat(&at, k, m).transpose().matmul(&to_mat(&b, k, n)),
            1e-4,
        );
    }

    #[test]
    fn tiled_kernels_match_scalar_reference() {
        // Shapes chosen to hit every tile/tail combination of the
        // microkernels (row tails, column tails, k tails).
        let mut rng = Rng::new(9, 0);
        for &(m, k, n) in &[
            (1usize, 8usize, 1usize),
            (3, 5, 7),
            (4, 16, 16),
            (13, 17, 11),
            (32, 64, 48),
            (67, 33, 96),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bt = rand_vec(&mut rng, n * k);
            let tiled = matmul_nn(&a, &b, m, k, n);
            let scalar = matmul_nn_scalar(&a, &b, m, k, n);
            for (x, y) in tiled.iter().zip(&scalar) {
                assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "nn {m}x{k}x{n}: {x} vs {y}");
            }
            let tiled = matmul_nt(&a, &bt, m, k, n);
            let scalar = matmul_nt_scalar(&a, &bt, m, k, n);
            for (x, y) in tiled.iter().zip(&scalar) {
                assert!((x - y).abs() < 1e-4 * (1.0 + y.abs()), "nt {m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_nt_matches_unpacked() {
        // Shapes hit full panels, a ragged tail panel, row tiles and row
        // tails; the packed kernel must agree with plain NT within fp
        // tolerance (the reduction orders differ by design).
        let mut rng = Rng::new(11, 0);
        for &(m, k, n) in &[
            (1usize, 8usize, 1usize),
            (3, 5, 7),
            (4, 16, 16),
            (8, 32, 384),
            (13, 17, 37),
            (67, 33, 96),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let bt = rand_vec(&mut rng, n * k);
            let packed = pack_nt(&bt, n, k);
            let got = matmul_nt_packed(&a, &packed, m);
            let want = matmul_nt(&a, &bt, m, k, n);
            for (x, y) in got.iter().zip(&want) {
                assert!(
                    (x - y).abs() < 1e-4 * (1.0 + y.abs()),
                    "packed nt {m}x{k}x{n}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn packed_nt_rows_are_shape_independent() {
        // Like the other kernels, a logical row must come out
        // bit-identical at m=1 (single-slot decode) and inside a batch
        // (fused decode / prefill head) — generation parity relies on it.
        let mut rng = Rng::new(12, 0);
        let (m, k, n) = (9, 33, 37);
        let a = rand_vec(&mut rng, m * k);
        let bt = rand_vec(&mut rng, n * k);
        let packed = pack_nt(&bt, n, k);
        let full = matmul_nt_packed(&a, &packed, m);
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            assert_eq!(&matmul_nt_packed(row, &packed, 1), &full[r * n..(r + 1) * n]);
        }
    }

    #[test]
    fn matmul_rows_are_shape_independent() {
        // The same logical row must come out bit-identical whether the
        // kernel sees it alone (m=1, KV decode) or inside a batch
        // (m=rows, prefill) — the KV parity guarantee.
        let mut rng = Rng::new(10, 0);
        let (m, k, n) = (9, 33, 21);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bt = rand_vec(&mut rng, n * k);
        let full_nn = matmul_nn(&a, &b, m, k, n);
        let full_nt = matmul_nt(&a, &bt, m, k, n);
        for r in 0..m {
            let row = &a[r * k..(r + 1) * k];
            assert_eq!(&matmul_nn(row, &b, 1, k, n), &full_nn[r * n..(r + 1) * n]);
            assert_eq!(&matmul_nt(row, &bt, 1, k, n), &full_nt[r * n..(r + 1) * n]);
        }
    }

    #[test]
    fn matmul_parallel_path_matches_serial() {
        // Big enough to cross PAR_MIN_FLOPS with a row count that does
        // not divide evenly across workers.
        let mut rng = Rng::new(2, 0);
        let (m, k, n) = (67, 64, 96);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let got = matmul_nn(&a, &b, m, k, n);
        let want = to_mat(&a, m, k).matmul(&to_mat(&b, k, n));
        close(&got, &want, 1e-3);
    }

    #[test]
    fn rope_cache_matches_fresh_table() {
        let (s, half) = (12, 3);
        let (cos, sin) = rope_table(s, half);
        let cached = rope_tables_cached(s, half);
        assert_eq!(cached.cos, cos);
        assert_eq!(cached.sin, sin);
        // Second lookup returns the same shared table.
        let again = rope_tables_cached(s, half);
        assert!(Arc::ptr_eq(&cached, &again));
    }

    #[test]
    fn rmsnorm_forward_unit_scale() {
        let x = vec![3.0f32, -4.0];
        let w = vec![1.0f32, 1.0];
        let (y, inv) = rmsnorm_fwd(&x, &w, 1, 2);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-4);
        assert!((y[1] + 4.0 / rms).abs() < 1e-4);
        assert!((inv[0] - 1.0 / rms).abs() < 1e-5);
        // The allocation-free variant produces the same output.
        let mut y2 = vec![0.0f32; 2];
        rmsnorm_into(&x, &w, 1, 2, &mut y2);
        assert_eq!(y, y2);
    }

    #[test]
    fn rmsnorm_backward_matches_finite_difference() {
        let mut rng = Rng::new(3, 0);
        let (rows, d) = (2, 5);
        let x = rand_vec(&mut rng, rows * d);
        let w: Vec<f32> = (0..d).map(|i| 0.5 + 0.2 * i as f32).collect();
        // Scalar loss: L = Σ c_i y_i with fixed random c.
        let c = rand_vec(&mut rng, rows * d);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = rmsnorm_fwd(x, &w, rows, d);
            y.iter().zip(&c).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let (_, inv) = rmsnorm_fwd(&x, &w, rows, d);
        let (dx, dw) = rmsnorm_bwd(&c, &x, &w, &inv, rows, d);
        let eps = 1e-3f32;
        for i in [0usize, 3, 7, 9] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            assert!(
                (num - dx[i] as f64).abs() < 1e-2 * (1.0 + num.abs()),
                "dx[{i}]: analytic {} vs numeric {num}",
                dx[i]
            );
        }
        // dw via finite differences on one weight.
        let lw = |w2: &[f32]| -> f64 {
            let (y, _) = rmsnorm_fwd(&x, w2, rows, d);
            y.iter().zip(&c).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let mut wp = w.clone();
        wp[2] += eps;
        let mut wm = w.clone();
        wm[2] -= eps;
        let num = (lw(&wp) - lw(&wm)) / (2.0 * eps as f64);
        assert!((num - dw[2] as f64).abs() < 1e-2 * (1.0 + num.abs()));
    }

    #[test]
    fn rope_roundtrips_and_preserves_norm() {
        let (b, s, nh, dh) = (1, 4, 2, 6);
        let mut rng = Rng::new(4, 0);
        let x0 = rand_vec(&mut rng, b * s * nh * dh);
        let (cos, sin) = rope_table(s, dh / 2);
        let mut x = x0.clone();
        rope_apply(&mut x, b, s, nh, dh, &cos, &sin, 1.0);
        // Norm is preserved (rotations are orthogonal).
        let n0: f32 = x0.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3 * n0);
        // Position 0 is unrotated.
        let d = nh * dh;
        assert_eq!(&x[..d], &x0[..d]);
        // Inverse rotation restores the input.
        rope_apply(&mut x, b, s, nh, dh, &cos, &sin, -1.0);
        for (a, b_) in x.iter().zip(&x0) {
            assert!((a - b_).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_rows_matches_positional_apply() {
        // rope_apply_rows at positions [0, 1, 2, 3] must equal the
        // windowed rope_apply over a (1, 4) batch.
        let (s, nh, dh) = (4, 2, 6);
        let mut rng = Rng::new(5, 0);
        let x0 = rand_vec(&mut rng, s * nh * dh);
        let (cos, sin) = rope_table(s, dh / 2);
        let mut a = x0.clone();
        rope_apply(&mut a, 1, s, nh, dh, &cos, &sin, 1.0);
        let mut b = x0.clone();
        rope_apply_rows(&mut b, &[0, 1, 2, 3], nh, dh, &cos, &sin);
        assert_eq!(a, b);
    }

    #[test]
    fn rope_local_rows_match_table_bitwise() {
        // On-the-fly per-position rows (the unbounded-position decode
        // path) must be bit-identical to the cached table, including
        // at positions far beyond any window, and applying them must
        // equal the table-indexed apply.
        let (nh, dh) = (2, 6);
        let half = dh / 2;
        let positions = [0usize, 3, 7, 1000];
        let (cos, sin) = rope_table(1001, half);
        let mut rcos = vec![0.0f32; positions.len() * half];
        let mut rsin = vec![0.0f32; positions.len() * half];
        for (i, &p) in positions.iter().enumerate() {
            rope_row_into(
                p,
                half,
                &mut rcos[i * half..(i + 1) * half],
                &mut rsin[i * half..(i + 1) * half],
            );
            assert_eq!(&rcos[i * half..(i + 1) * half], &cos[p * half..(p + 1) * half]);
            assert_eq!(&rsin[i * half..(i + 1) * half], &sin[p * half..(p + 1) * half]);
        }
        let mut rng = Rng::new(6, 0);
        let x0 = rand_vec(&mut rng, positions.len() * nh * dh);
        let mut a = x0.clone();
        rope_apply_rows(&mut a, &positions, nh, dh, &cos, &sin);
        let mut b = x0.clone();
        rope_apply_rows_local(&mut b, positions.len(), nh, dh, &rcos, &rsin);
        assert_eq!(a, b);
    }

    #[test]
    fn silu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let num = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((num - silu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }
}

//! Native switched full-model graphs (the PEFT comparisons, Figs 5–7).
//!
//! The pjrt backend runs these as AOT artifacts; this module is the
//! pure-Rust reference: a full-model forward over the (possibly cured)
//! student with one adapter family's deltas blended onto the q/k/gate
//! projections, a [`StepMode`] loss, and backprop restricted to the
//! active adapter's parameters.
//!
//! * Forward: [`layer_forward_cached`] with
//!   [`crate::backend::AdapterView`] deltas — `y = base(x) + delta(x)`
//!   per projection, so a zero-initialized adapter (LoRA `B`, MoRA `M`,
//!   CURLoRA `U`) leaves the student's logits numerically untouched.
//! * Heal loss: `0.9·KD(T=10) + 0.1·CE` against the dense teacher's
//!   logits on the same batch, with `KD = T²·KL(teacher‖student)` over
//!   temperature-`T` softmaxes (the standard distillation scaling).
//! * Task loss: cross-entropy weighted by the caller's answer mask.
//! * Optimizer: Adam on **only** the active family's parameters — ΔU
//!   for `Du` (updated in the student store), A/B for LoRA, M for MoRA,
//!   U for CURLoRA (updated in the adapter store; C/R frozen).
//!
//! Missing tensors are hard errors, never silent zeros: every middle
//! layer must hold the active family's complete tensor set, and every
//! cured projection must have its ΔU — a typo'd name must not quietly
//! train or evaluate the base model.

use super::forward::{
    embed_gather, head_forward, layer_dims, layer_forward_cached, layer_infer_impl, want,
    InferScratch, LayerCache,
};
use super::train::{
    adam_update, dense_layer_params, layer_backward, student_layer_params, AdapterGrad,
    ProjGrad,
};
use crate::backend::{AdapterView, LayerParams, ProjAdapter, StepMode};
use crate::model::ModelConfig;
use crate::peft::Adapter;
use crate::tensor::{Tensor, TensorStore};
use anyhow::{anyhow, ensure, Context, Result};
use std::collections::HashMap;

/// Distillation temperature of the heal loss (paper App. B).
pub(crate) const KD_TEMPERATURE: f64 = 10.0;
/// KD weight in the heal loss mix.
pub(crate) const KD_WEIGHT: f64 = 0.9;
/// CE weight in the heal loss mix.
pub(crate) const CE_WEIGHT: f64 = 0.1;

/// Resolve layer `l`'s blended adapter view. `Du` and non-middle layers
/// get `None`; for the other families every middle layer must hold the
/// complete tensor set — a missing (e.g. misnamed) tensor of the
/// *active* family is a hard error, not a silent zero.
fn adapter_view<'a>(
    cfg: &ModelConfig,
    adapters: &'a TensorStore,
    l: usize,
    adapter: Adapter,
) -> Result<Option<AdapterView<'a>>> {
    if adapter == Adapter::Du || !cfg.middle_layers().contains(&l) {
        return Ok(None);
    }
    let get = |name: String| -> Result<&'a Tensor> {
        adapters
            .get(&name)
            .with_context(|| format!("adapter '{}' tensor '{name}' missing", adapter.label()))
    };
    let mut view = AdapterView::default();
    for proj in ["q", "k", "gate"] {
        let ad = match adapter {
            Adapter::Du => unreachable!(),
            Adapter::Lora => ProjAdapter::Lora {
                a: get(format!("L{l}.lora_a_{proj}"))?,
                b: get(format!("L{l}.lora_b_{proj}"))?,
            },
            Adapter::Mora => ProjAdapter::Mora { m: get(format!("L{l}.mora_m_{proj}"))? },
            Adapter::CurLora => ProjAdapter::CurLora {
                c: get(format!("L{l}.cl_c_{proj}"))?,
                u: get(format!("L{l}.cl_u_{proj}"))?,
                r: get(format!("L{l}.cl_r_{proj}"))?,
            },
        };
        match proj {
            "q" => view.q = Some(ad),
            "k" => view.k = Some(ad),
            _ => view.gate = Some(ad),
        }
    }
    Ok(Some(view))
}

/// Layer params of the blended student: cured-or-dense base from the
/// student store (`U = U₀ + ΔU` merged) plus the active adapter's view.
fn blended_params<'a>(
    cfg: &ModelConfig,
    student: &'a TensorStore,
    adapters: &'a TensorStore,
    l: usize,
    adapter: Adapter,
) -> Result<LayerParams<'a>> {
    let mut p = student_layer_params(student, l)?;
    p.adapter = adapter_view(cfg, adapters, l, adapter)?;
    Ok(p)
}

/// Every cured projection's ΔU names, validated present for **every**
/// adapter family — `student_proj` would otherwise merge `U = U₀` and
/// silently evaluate an un-healed chain when a `du_*` tensor is
/// misnamed (the exact silent-fallback class this PR removes; the pjrt
/// binding enforces the same rule).
fn cured_du_names(student: &TensorStore) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for l in crate::compress::cured_layers_of(student) {
        for proj in ["q", "k", "gate"] {
            if !student.contains(&format!("L{l}.c_{proj}")) {
                continue;
            }
            let name = format!("L{l}.du_{proj}");
            ensure!(
                student.contains(&name),
                "cured layer {l} is missing its '{name}' tensor — the student \
                 store is malformed, refusing to silently skip it"
            );
            names.push(name);
        }
    }
    Ok(names)
}

/// Names of the active adapter's trainable tensors, validated present
/// (hard error naming the first missing one). Also validates the cured
/// layers' ΔU completeness regardless of family.
fn trainable_names(
    cfg: &ModelConfig,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
) -> Result<Vec<String>> {
    let du_names = cured_du_names(student)?;
    match adapter {
        Adapter::Du => {
            ensure!(
                !du_names.is_empty(),
                "adapter 'curing-du' trains ΔU of cured projections, but the student \
                 store has no cured layers"
            );
            Ok(du_names)
        }
        Adapter::Lora | Adapter::Mora | Adapter::CurLora => {
            let mut names = Vec::new();
            for l in cfg.middle_layers() {
                // adapter_view validates the complete per-layer set
                // (including the frozen CURLoRA C/R).
                adapter_view(cfg, adapters, l, adapter)?;
                for proj in ["q", "k", "gate"] {
                    match adapter {
                        Adapter::Lora => {
                            names.push(format!("L{l}.lora_a_{proj}"));
                            names.push(format!("L{l}.lora_b_{proj}"));
                        }
                        Adapter::Mora => names.push(format!("L{l}.mora_m_{proj}")),
                        Adapter::CurLora => names.push(format!("L{l}.cl_u_{proj}")),
                        Adapter::Du => unreachable!(),
                    }
                }
            }
            Ok(names)
        }
    }
}

/// Dense-teacher logits on the inference path (flat bs×vocab).
fn teacher_logits(
    cfg: &ModelConfig,
    teacher: &TensorStore,
    toks: &[i32],
    b: usize,
    s: usize,
) -> Result<Vec<f32>> {
    let (d, bs) = (cfg.d_model, b * s);
    let emb_t = teacher.get("emb")?;
    ensure!(
        emb_t.shape.len() == 2 && emb_t.shape[1] == d,
        "teacher emb must be (vocab, {d}), got {:?}",
        emb_t.shape
    );
    let vocab = emb_t.shape[0];
    let emb = emb_t.f32s()?;
    let mut x = vec![0.0f32; bs * d];
    embed_gather(emb, vocab, d, toks, &mut x)?;
    let mut sc = InferScratch::new();
    for l in 0..cfg.n_layers {
        let p = dense_layer_params(teacher, l)?;
        let dims = layer_dims(cfg.n_heads, &p, b, s, d)?;
        x = layer_infer_impl(dims, &p, &x, None, &mut sc)?;
    }
    let ln_f = want(teacher.get("ln_f")?, &[d], "ln_f")?;
    let (logits, _, _) = head_forward(&x, ln_f, emb, bs, d, vocab);
    Ok(logits)
}

/// Per-row softmax at temperature `temp` into `out`, f64 throughout.
fn softmax_t(row: &[f32], temp: f64, out: &mut [f64]) {
    let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut sum = 0.0f64;
    for (o, &z) in out.iter_mut().zip(row) {
        *o = ((z as f64 - maxv) / temp).exp();
        sum += *o;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Loss + dlogits of one switched step over already-computed student
/// logits. `weights` are per-position loss weights (the task mask, or
/// all-ones), already including the 1/Σw normalization factor `inv_w`.
#[allow(clippy::too_many_arguments)]
fn loss_and_dlogits(
    mode: StepMode,
    logits_s: &[f32],
    logits_t: Option<&[f32]>,
    tgts: &[i32],
    weights: &[f32],
    inv_w: f64,
    bs: usize,
    vocab: usize,
) -> Result<(f64, Vec<f32>)> {
    let mut dlogits = vec![0.0f32; bs * vocab];
    let mut loss = 0.0f64;
    let mut p = vec![0.0f64; vocab];
    let mut qs = vec![0.0f64; vocab];
    let mut qt = vec![0.0f64; vocab];
    let temp = KD_TEMPERATURE;
    for r in 0..bs {
        let w = weights[r] as f64 * inv_w;
        if w == 0.0 {
            continue;
        }
        let tk = tgts[r];
        ensure!((0..vocab as i32).contains(&tk), "target {tk} out of vocab 0..{vocab}");
        let row = &logits_s[r * vocab..(r + 1) * vocab];
        let drow = &mut dlogits[r * vocab..(r + 1) * vocab];
        // CE term (always present; weight 1.0 in task mode).
        softmax_t(row, 1.0, &mut p);
        let ce = -p[tk as usize].max(1e-300).ln();
        let ce_w = match mode {
            StepMode::Heal => CE_WEIGHT,
            StepMode::Task => 1.0,
        };
        loss += w * ce_w * ce;
        for j in 0..vocab {
            drow[j] = (w * ce_w * (p[j] - if j == tk as usize { 1.0 } else { 0.0 })) as f32;
        }
        if mode == StepMode::Heal {
            let t_row = &logits_t.ok_or_else(|| anyhow!("heal mode needs teacher logits"))?
                [r * vocab..(r + 1) * vocab];
            softmax_t(row, temp, &mut qs);
            softmax_t(t_row, temp, &mut qt);
            // KD = T²·KL(teacher ‖ student); dKD/dz_s = T·(q_s − q_t).
            let mut kl = 0.0f64;
            for j in 0..vocab {
                if qt[j] > 0.0 {
                    kl += qt[j] * (qt[j].ln() - qs[j].max(1e-300).ln());
                }
                drow[j] += (w * KD_WEIGHT * temp * (qs[j] - qt[j])) as f32;
            }
            loss += w * KD_WEIGHT * temp * temp * kl;
        }
    }
    Ok((loss, dlogits))
}

/// Forward + loss + adapter-restricted gradients of one switched step.
/// Shared by [`switched_step_impl`] and the finite-difference gradcheck
/// tests (which read only the loss).
#[allow(clippy::too_many_arguments)]
pub(super) fn switched_grads(
    cfg: &ModelConfig,
    teacher: &TensorStore,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    mode: StepMode,
    tokens: &Tensor,
    targets: &Tensor,
    loss_mask: Option<&Tensor>,
) -> Result<(f64, HashMap<String, Vec<f32>>)> {
    ensure!(tokens.shape.len() == 2, "tokens must be (b, s)");
    ensure!(targets.shape == tokens.shape, "targets shape mismatch");
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    let bs = b * s;
    let (d, nl) = (cfg.d_model, cfg.n_layers);
    let toks = tokens.i32s()?;
    let tgts = targets.i32s()?;
    let weights: Vec<f32> = match loss_mask {
        Some(m) => {
            ensure!(m.shape == tokens.shape, "loss mask shape mismatch");
            m.f32s()?.to_vec()
        }
        None => vec![1.0; bs],
    };
    let wsum: f64 = weights.iter().map(|&x| x as f64).sum();
    ensure!(wsum > 0.0, "loss mask selects no positions");
    let inv_w = 1.0 / wsum;

    // Student forward with backward caches, adapter deltas blended.
    let emb_t = student.get("emb")?;
    ensure!(
        emb_t.shape.len() == 2 && emb_t.shape[1] == d,
        "emb must be (vocab, {d}), got {:?}",
        emb_t.shape
    );
    let vocab = emb_t.shape[0];
    let emb = emb_t.f32s()?;
    let mut x0 = vec![0.0f32; bs * d];
    embed_gather(emb, vocab, d, toks, &mut x0)?;
    let mut caches: Vec<LayerCache> = Vec::with_capacity(nl);
    for l in 0..nl {
        let p = blended_params(cfg, student, adapters, l, adapter)?;
        let dims = layer_dims(cfg.n_heads, &p, b, s, d)?;
        let x_in: &[f32] = if l == 0 { &x0 } else { &caches[l - 1].y };
        caches.push(layer_forward_cached(dims, &p, x_in)?);
    }
    let x_final: &[f32] = if nl == 0 { &x0 } else { &caches[nl - 1].y };
    let ln_f = want(student.get("ln_f")?, &[d], "ln_f")?;
    let (logits, xf, invf) = head_forward(x_final, ln_f, emb, bs, d, vocab);
    drop(xf);

    let t_logits = match mode {
        StepMode::Heal => Some(teacher_logits(cfg, teacher, toks, b, s)?),
        StepMode::Task => None,
    };
    let (loss, dlogits) = loss_and_dlogits(
        mode,
        &logits,
        t_logits.as_deref(),
        tgts,
        &weights,
        inv_w,
        bs,
        vocab,
    )?;

    // Head backward. The head (ln_f, tied emb) is frozen in every
    // switched graph, so only the input gradient is propagated.
    let dxf = super::math::matmul_nn(&dlogits, emb, bs, vocab, d);
    let (mut dx, _dlnf) = super::math::rmsnorm_bwd(&dxf, x_final, ln_f, &invf, bs, d);

    let mut grads: HashMap<String, Vec<f32>> = HashMap::new();
    for l in (0..nl).rev() {
        let p = blended_params(cfg, student, adapters, l, adapter)?;
        let x_in: &[f32] = if l == 0 { &x0 } else { &caches[l - 1].y };
        let g = layer_backward(&p, x_in, &caches[l], &dx)?;
        dx = g.dx;
        match adapter {
            Adapter::Du => {
                // ΔU grads are the cured chains' U grads (U = U₀ + ΔU).
                for (proj, pg) in [("q", g.q), ("k", g.k), ("gate", g.gate)] {
                    if let ProjGrad::CuredU(du) = pg {
                        grads.insert(format!("L{l}.du_{proj}"), du);
                    }
                }
            }
            Adapter::Lora | Adapter::Mora | Adapter::CurLora => {
                for (proj, ag) in [("q", g.q_ad), ("k", g.k_ad), ("gate", g.gate_ad)] {
                    match ag {
                        None => {}
                        Some(AdapterGrad::Lora { da, db }) => {
                            grads.insert(format!("L{l}.lora_a_{proj}"), da);
                            grads.insert(format!("L{l}.lora_b_{proj}"), db);
                        }
                        Some(AdapterGrad::Mora { dm }) => {
                            grads.insert(format!("L{l}.mora_m_{proj}"), dm);
                        }
                        Some(AdapterGrad::CurLora { du }) => {
                            grads.insert(format!("L{l}.cl_u_{proj}"), du);
                        }
                    }
                }
            }
        }
    }
    Ok((loss, grads))
}

/// One native switched optimizer step: see [`crate::backend::Backend::switched_step`].
#[allow(clippy::too_many_arguments)]
pub(super) fn switched_step_impl(
    cfg: &ModelConfig,
    teacher: &TensorStore,
    student: &mut TensorStore,
    adapters: &mut TensorStore,
    opt: &mut TensorStore,
    adapter: Adapter,
    mode: StepMode,
    tokens: &Tensor,
    targets: &Tensor,
    loss_mask: Option<&Tensor>,
    lr: f32,
    t: f32,
) -> Result<f64> {
    let trainables = trainable_names(cfg, student, adapters, adapter)?;
    let (loss, mut grads) = switched_grads(
        cfg, teacher, student, adapters, adapter, mode, tokens, targets, loss_mask,
    )?;
    let tag = adapter.tag();
    for name in &trainables {
        let g = grads
            .remove(name)
            .ok_or_else(|| anyhow!("missing gradient for trainable '{name}'"))?;
        let store: &mut TensorStore =
            if adapter == Adapter::Du { &mut *student } else { &mut *adapters };
        adam_update(
            store,
            opt,
            name,
            format!("{tag}.m.{name}"),
            format!("{tag}.v.{name}"),
            &g,
            lr,
            t,
        )?;
    }
    Ok(loss)
}

/// Native switched logits: the adapter-blended student's (b, s, vocab)
/// logits. Strict like the step: missing active-family or cured-factor
/// tensors error instead of silently scoring the base model.
///
/// Runs the cached (train-path) forward because only it blends adapter
/// deltas; the backward caches it allocates are discarded. Teaching the
/// scratch-reusing infer path to blend (and re-proving bitwise parity)
/// is the follow-up if switched eval ever becomes hot — at the
/// reproduction sizes the extra allocation is noise.
pub(super) fn switched_logits_impl(
    cfg: &ModelConfig,
    student: &TensorStore,
    adapters: &TensorStore,
    adapter: Adapter,
    tokens: &Tensor,
) -> Result<Tensor> {
    ensure!(tokens.shape.len() == 2, "tokens must be (b, s)");
    // Same completeness validation as the step (a renamed tensor must
    // error at eval time too).
    trainable_names(cfg, student, adapters, adapter)?;
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    let bs = b * s;
    let d = cfg.d_model;
    let toks = tokens.i32s()?;
    let emb_t = student.get("emb")?;
    ensure!(
        emb_t.shape.len() == 2 && emb_t.shape[1] == d,
        "emb must be (vocab, {d}), got {:?}",
        emb_t.shape
    );
    let vocab = emb_t.shape[0];
    let emb = emb_t.f32s()?;
    let mut x = vec![0.0f32; bs * d];
    embed_gather(emb, vocab, d, toks, &mut x)?;
    for l in 0..cfg.n_layers {
        let p = blended_params(cfg, student, adapters, l, adapter)?;
        let dims = layer_dims(cfg.n_heads, &p, b, s, d)?;
        x = layer_forward_cached(dims, &p, &x)?.y;
    }
    let ln_f = want(student.get("ln_f")?, &[d], "ln_f")?;
    let (logits, _, _) = head_forward(&x, ln_f, emb, bs, d, vocab);
    Ok(Tensor::from_f32(&[b, s, vocab], logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::Calibration;
    use crate::compress::{cure_layers, CompressOptions};
    use crate::peft::init_adapters;
    use crate::util::{Json, Rng};

    fn cfg() -> ModelConfig {
        let j = Json::parse(
            r#"{"configs":{"t":{"vocab":48,"d_model":16,"n_layers":3,"n_heads":2,
            "d_inter":24,"seq":6,"batch":2,"ranks":[4],"default_rank":4,
            "lora_rank":1,"mora_rank":4,"total_params":0}}}"#,
        )
        .unwrap();
        ModelConfig::from_manifest(&j, "t").unwrap()
    }

    fn flat_calib(c: &ModelConfig) -> Calibration {
        Calibration {
            attn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            ffn_norms: vec![vec![1.0; c.d_model]; c.n_layers],
            angular: vec![0.0; c.n_layers],
            n_examples: 1,
        }
    }

    struct Setup {
        cfg: ModelConfig,
        teacher: TensorStore,
        student: TensorStore,
        adapters: TensorStore,
        tokens: Tensor,
        targets: Tensor,
        mask: Tensor,
    }

    fn setup(adapter: Adapter, seed: u64) -> Setup {
        let c = cfg();
        let mut rng = Rng::new(seed, 0);
        let teacher = c.init_dense(&mut rng);
        let mut student = teacher.clone();
        let calib = flat_calib(&c);
        let opts = CompressOptions { r_max: 4, ..Default::default() };
        cure_layers(&mut student, &c, &calib, &[1], &opts).unwrap();
        let mut adapters = init_adapters(adapter, &c, &teacher, &calib, &mut rng).unwrap();
        // Randomize the trainable factors so gradients are nontrivial
        // (they are zero-initialized, where many grads vanish).
        let mut names: Vec<String> = adapters.names().map(|s| s.to_string()).collect();
        names.sort();
        for name in names {
            let t = adapters.get_mut(&name).unwrap();
            for x in t.f32s_mut().unwrap() {
                if *x == 0.0 {
                    *x = rng.normal() * 0.05;
                }
            }
        }
        if adapter == Adapter::Du {
            for proj in ["q", "k", "gate"] {
                let t = student.get_mut(&format!("L1.du_{proj}")).unwrap();
                for x in t.f32s_mut().unwrap() {
                    *x = rng.normal() * 0.05;
                }
            }
        }
        let (b, s) = (c.batch, c.seq);
        let toks: Vec<i32> = (0..b * s).map(|_| rng.below(c.vocab) as i32).collect();
        let mut tgts = toks[1..].to_vec();
        tgts.push(0);
        let mask: Vec<f32> = (0..b * s).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        Setup {
            tokens: Tensor::from_i32(&[b, s], toks),
            targets: Tensor::from_i32(&[b, s], tgts),
            mask: Tensor::from_f32(&[b, s], mask),
            cfg: c,
            teacher,
            student,
            adapters,
        }
    }

    /// Central finite-difference gradcheck of the adapter-restricted
    /// gradients, every family, both step modes.
    fn gradcheck(adapter: Adapter, mode: StepMode, seed: u64) {
        let st = setup(adapter, seed);
        let mask = if mode == StepMode::Task { Some(&st.mask) } else { None };
        let loss_of = |student: &TensorStore, adapters: &TensorStore| -> f64 {
            switched_grads(
                &st.cfg, &st.teacher, student, adapters, adapter, mode, &st.tokens,
                &st.targets, mask,
            )
            .unwrap()
            .0
        };
        let (_, grads) = switched_grads(
            &st.cfg, &st.teacher, &st.student, &st.adapters, adapter, mode, &st.tokens,
            &st.targets, mask,
        )
        .unwrap();
        let names = trainable_names(&st.cfg, &st.student, &st.adapters, adapter).unwrap();
        assert!(!names.is_empty());
        let eps = 1e-2f32;
        for name in &names {
            let g = &grads[name];
            // Probe the three largest-|g| elements: they carry the
            // signal a wrong backward would corrupt, and their magnitude
            // makes the 1e-3 relative tolerance meaningful against f32
            // forward noise.
            let mut order: Vec<usize> = (0..g.len()).collect();
            order.sort_by(|&a, &b| g[b].abs().total_cmp(&g[a].abs()));
            for &i in order.iter().take(3) {
                let in_student = adapter == Adapter::Du;
                let perturb = |delta: f32| -> f64 {
                    let mut s2 = st.student.clone();
                    let mut a2 = st.adapters.clone();
                    let t = if in_student {
                        s2.get_mut(name).unwrap()
                    } else {
                        a2.get_mut(name).unwrap()
                    };
                    t.f32s_mut().unwrap()[i] += delta;
                    loss_of(&s2, &a2)
                };
                let num = (perturb(eps) - perturb(-eps)) / (2.0 * eps as f64);
                let ana = g[i] as f64;
                // rel-err < 1e-3, plus an absolute term for f32 forward
                // rounding through the finite difference (loss noise
                // ~1e-6·|L| divided by 2ε) and truncation (ε²·f'''/6) —
                // a wrong backward is off by O(|g|), far outside this.
                let tol = 1e-3 * (num.abs() + ana.abs()) + 5e-4;
                assert!(
                    (num - ana).abs() < tol,
                    "{:?} {name}[{i}]: analytic {ana} vs numeric {num}",
                    adapter
                );
            }
        }
    }

    #[test]
    fn gradcheck_du_heal() {
        gradcheck(Adapter::Du, StepMode::Heal, 101);
    }

    #[test]
    fn gradcheck_lora_heal() {
        gradcheck(Adapter::Lora, StepMode::Heal, 102);
    }

    #[test]
    fn gradcheck_lora_task() {
        gradcheck(Adapter::Lora, StepMode::Task, 103);
    }

    #[test]
    fn gradcheck_mora_task() {
        gradcheck(Adapter::Mora, StepMode::Task, 104);
    }

    #[test]
    fn gradcheck_curlora_task() {
        gradcheck(Adapter::CurLora, StepMode::Task, 105);
    }

    #[test]
    fn gradcheck_mora_heal() {
        gradcheck(Adapter::Mora, StepMode::Heal, 106);
    }

    #[test]
    fn gradcheck_curlora_heal() {
        gradcheck(Adapter::CurLora, StepMode::Heal, 107);
    }

    #[test]
    fn step_updates_only_active_adapter() {
        // A LoRA step must move A/B and nothing else: the student store
        // (incl. ΔU and the cured factors) stays bit-identical.
        let st = setup(Adapter::Lora, 55);
        let mut student = st.student.clone();
        let mut adapters = st.adapters.clone();
        let mut opt = TensorStore::new();
        let loss = switched_step_impl(
            &st.cfg, &st.teacher, &mut student, &mut adapters, &mut opt, Adapter::Lora,
            StepMode::Heal, &st.tokens, &st.targets, None, 1e-3, 1.0,
        )
        .unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        let mut names: Vec<String> = st.student.names().map(|s| s.to_string()).collect();
        names.sort();
        for name in names {
            assert_eq!(
                student.get(&name).unwrap(),
                st.student.get(&name).unwrap(),
                "LoRA step must not touch student tensor '{name}'"
            );
        }
        let mut moved = 0usize;
        let mut anames: Vec<String> = st.adapters.names().map(|s| s.to_string()).collect();
        anames.sort();
        for name in anames {
            if adapters.get(&name).unwrap() != st.adapters.get(&name).unwrap() {
                moved += 1;
            }
        }
        assert!(moved > 0, "no adapter tensor moved");
    }

    #[test]
    fn du_step_updates_student_delta_u_only() {
        let st = setup(Adapter::Du, 56);
        let mut student = st.student.clone();
        let mut adapters = TensorStore::new();
        let mut opt = TensorStore::new();
        switched_step_impl(
            &st.cfg, &st.teacher, &mut student, &mut adapters, &mut opt, Adapter::Du,
            StepMode::Heal, &st.tokens, &st.targets, None, 1e-3, 1.0,
        )
        .unwrap();
        let mut du_moved = 0usize;
        let mut names: Vec<String> = st.student.names().map(|s| s.to_string()).collect();
        names.sort();
        for name in names {
            let changed = student.get(&name).unwrap() != st.student.get(&name).unwrap();
            let is_du = name.contains(".du_");
            if is_du {
                du_moved += changed as usize;
            } else {
                assert!(!changed, "Du step must not touch '{name}'");
            }
        }
        assert_eq!(du_moved, 3, "all three cured ΔU tensors must move");
        assert!(adapters.is_empty());
    }

    #[test]
    fn task_mask_restricts_loss_positions() {
        // An all-but-one-zero mask: perturbing a masked-out target must
        // not change the loss.
        let st = setup(Adapter::Mora, 57);
        let (b, s) = (st.cfg.batch, st.cfg.seq);
        let mut mask = vec![0.0f32; b * s];
        mask[1] = 1.0;
        let mask = Tensor::from_f32(&[b, s], mask);
        let loss = |targets: &Tensor| -> f64 {
            switched_grads(
                &st.cfg, &st.teacher, &st.student, &st.adapters, Adapter::Mora,
                StepMode::Task, &st.tokens, targets, Some(&mask),
            )
            .unwrap()
            .0
        };
        let l0 = loss(&st.targets);
        let mut tg2 = st.targets.clone();
        // Change a masked-out position's target (position 0).
        let cur = tg2.i32s().unwrap()[0];
        if let crate::tensor::Data::I32(v) = &mut tg2.data {
            v[0] = (cur + 1) % st.cfg.vocab as i32;
        }
        assert_eq!(loss(&tg2), l0, "masked-out target changed the loss");
        // Changing the one live position does change it.
        let cur = tg2.i32s().unwrap()[1];
        if let crate::tensor::Data::I32(v) = &mut tg2.data {
            v[1] = (cur + 1) % st.cfg.vocab as i32;
        }
        assert_ne!(loss(&tg2), l0, "live target did not affect the loss");
    }
}

//! Backward passes and optimizer steps of the native backend.
//!
//! Analytic gradients through the full transformer layer (RMSNorm, RoPE,
//! causal softmax attention, SwiGLU FFN, dense and CURed projection
//! chains) drive two steps:
//!
//! * [`train_step_impl`] — dense-model pretraining: cross-entropy over
//!   the tied head, backprop through every layer, Adam on all params.
//! * [`heal_step_impl`] — layer-wise KD healing (paper §4.5): MSE to the
//!   teacher layer output, gradients restricted to the ΔU factors of the
//!   layer's cured projections, Adam on ΔU only.

use super::forward::{
    embed_gather, head_forward, layer_dims, layer_forward_cached, mora_group, want,
    AdapterCache, Dims, LayerCache, ProjCache,
};
use super::math::{
    add_inplace, matmul_nn, matmul_nt, matmul_tn, rmsnorm_bwd, rope_apply,
    rope_tables_cached, silu, silu_grad,
};
use crate::backend::{HealOut, LayerParams, Proj, ProjAdapter};
use crate::model::ModelConfig;
use crate::tensor::{Tensor, TensorStore};
use anyhow::{anyhow, bail, ensure, Result};
use std::borrow::Cow;
use std::collections::HashMap;

/// Gradient of one projection: the dense weight's, or ΔU's (= U's) when
/// cured (C and R are frozen actual rows/columns of W).
pub(super) enum ProjGrad {
    Dense(Vec<f32>),
    CuredU(Vec<f32>),
}

/// Gradients of one blended adapter's *trainable* factors (frozen
/// factors — CURLoRA's C/R, the MoRA compress/decompress operators —
/// get none by construction).
pub(super) enum AdapterGrad {
    Lora { da: Vec<f32>, db: Vec<f32> },
    Mora { dm: Vec<f32> },
    CurLora { du: Vec<f32> },
}

pub(super) struct LayerGrads {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub q: ProjGrad,
    pub k: ProjGrad,
    pub v: Vec<f32>,
    pub o: Vec<f32>,
    pub gate: ProjGrad,
    pub up: Vec<f32>,
    pub down: Vec<f32>,
    pub q_ad: Option<AdapterGrad>,
    pub k_ad: Option<AdapterGrad>,
    pub gate_ad: Option<AdapterGrad>,
    pub dx: Vec<f32>,
}

/// Backward through a blended adapter delta: trainable-factor grads plus
/// the delta path's contribution to the input grad (added to `dh`).
fn adapter_backward(
    h: &[f32],
    rows: usize,
    dout: &[f32],
    ad: &ProjAdapter,
    cache: &AdapterCache,
    m: usize,
    n: usize,
    dh: &mut [f32],
) -> Result<AdapterGrad> {
    match ad {
        ProjAdapter::Lora { a, b } => {
            let rank = a.shape[1];
            // delta = (h·A)·B with h1 = h·A cached.
            let db = matmul_tn(&cache.h1, dout, rows, rank, n);
            let dh1 = matmul_nt(dout, b.f32s()?, rows, n, rank);
            let da = matmul_tn(h, &dh1, rows, m, rank);
            add_inplace(dh, &matmul_nt(&dh1, a.f32s()?, rows, rank, m));
            Ok(AdapterGrad::Lora { da, db })
        }
        ProjAdapter::Mora { m: mm } => {
            let rank = mm.shape[0];
            // delta = decompress(compress(h)·M): fold dout over output
            // groups, then chain through M and the compress groups.
            let gj = mora_group(n, rank);
            let mut dy = vec![0.0f32; rows * rank];
            for r in 0..rows {
                let dr = &dout[r * n..(r + 1) * n];
                let yr = &mut dy[r * rank..(r + 1) * rank];
                for (j, &v) in dr.iter().enumerate() {
                    yr[j / gj] += v;
                }
            }
            let dm = matmul_tn(&cache.h1, &dy, rows, rank, rank);
            let dh1 = matmul_nt(&dy, mm.f32s()?, rows, rank, rank);
            let gi = mora_group(m, rank);
            for r in 0..rows {
                let sr = &dh1[r * rank..(r + 1) * rank];
                let hr = &mut dh[r * m..(r + 1) * m];
                for (i, o) in hr.iter_mut().enumerate() {
                    *o += sr[i / gi];
                }
            }
            Ok(AdapterGrad::Mora { dm })
        }
        ProjAdapter::CurLora { c, u, r } => {
            let rank = c.shape[1];
            // delta = ((h·C)·U)·R with h1 = h·C cached; C/R frozen.
            let dh2 = matmul_nt(dout, r.f32s()?, rows, n, rank);
            let du = matmul_tn(&cache.h1, &dh2, rows, rank, rank);
            let dh1 = matmul_nt(&dh2, u.f32s()?, rows, rank, rank);
            add_inplace(dh, &matmul_nt(&dh1, c.f32s()?, rows, rank, m));
            Ok(AdapterGrad::CurLora { du })
        }
    }
}

/// Backward through a projection: returns (weight grad, adapter grad,
/// input grad).
fn proj_backward(
    h: &[f32],
    rows: usize,
    dout: &[f32],
    p: &Proj,
    cache: Option<&ProjCache>,
    ad: Option<&ProjAdapter>,
    adcache: Option<&AdapterCache>,
) -> Result<(ProjGrad, Option<AdapterGrad>, Vec<f32>)> {
    let (pg, mut dh, m, n) = match p {
        Proj::Dense(w) => {
            let (m, n) = (w.shape[0], w.shape[1]);
            let wf = w.f32s()?;
            let dw = matmul_tn(h, dout, rows, m, n);
            let dh = matmul_nt(dout, wf, rows, n, m);
            (ProjGrad::Dense(dw), dh, m, n)
        }
        Proj::Cured { c, u, r } => {
            let cache = cache.ok_or_else(|| anyhow!("missing CUR chain cache"))?;
            let (m, rank) = (c.shape[0], c.shape[1]);
            let n = r.shape[1];
            // out = ((h·C)·U)·R with hc = h·C, hcu = hc·U cached.
            let dhcu = matmul_nt(dout, r.f32s()?, rows, n, rank);
            let du = matmul_tn(&cache.hc, &dhcu, rows, rank, rank);
            let dhc = matmul_nt(&dhcu, u.f32s()?, rows, rank, rank);
            let dh = matmul_nt(&dhc, c.f32s()?, rows, rank, m);
            (ProjGrad::CuredU(du), dh, m, n)
        }
    };
    let ag = match ad {
        Some(ad) => {
            let adcache = adcache.ok_or_else(|| anyhow!("missing adapter cache"))?;
            Some(adapter_backward(h, rows, dout, ad, adcache, m, n, &mut dh)?)
        }
        None => None,
    };
    Ok((pg, ag, dh))
}

/// Backward through causal multi-head attention (+ inverse RoPE), from
/// the gradient of the concatenated head outputs to (dq, dk, dv) at the
/// projection outputs (pre-RoPE for q/k).
fn attention_bwd(
    datt: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dims: Dims,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let Dims { b, s, d, nh, dh, .. } = dims;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dq = vec![0.0f32; b * s * d];
    let mut dk = vec![0.0f32; b * s * d];
    let mut dv = vec![0.0f32; b * s * d];
    let mut dp_row = vec![0.0f32; s];
    for bi in 0..b {
        for h in 0..nh {
            let pbase = (bi * nh + h) * s * s;
            for si in 0..s {
                let aoff = (bi * s + si) * d + h * dh;
                let dout = &datt[aoff..aoff + dh];
                let prow = &probs[pbase + si * s..pbase + (si + 1) * s];
                // dP and dV; the softmax-jacobian dot term in one sweep.
                let mut dot_sum = 0.0f32;
                for sj in 0..=si {
                    let voff = (bi * s + sj) * d + h * dh;
                    let mut dp = 0.0f32;
                    for jj in 0..dh {
                        dp += dout[jj] * v[voff + jj];
                        dv[voff + jj] += prow[sj] * dout[jj];
                    }
                    dp_row[sj] = dp;
                    dot_sum += dp * prow[sj];
                }
                // dS = P ⊙ (dP − Σ dP·P); dQ += dS·K·scale; dK += dS·Q·scale.
                for sj in 0..=si {
                    let dsv = prow[sj] * (dp_row[sj] - dot_sum) * scale;
                    if dsv == 0.0 {
                        continue;
                    }
                    let koff = (bi * s + sj) * d + h * dh;
                    for jj in 0..dh {
                        dq[aoff + jj] += dsv * k[koff + jj];
                        dk[koff + jj] += dsv * q[aoff + jj];
                    }
                }
            }
        }
    }
    let rope = rope_tables_cached(s, dh / 2);
    rope_apply(&mut dq, b, s, nh, dh, &rope.cos, &rope.sin, -1.0);
    rope_apply(&mut dk, b, s, nh, dh, &rope.cos, &rope.sin, -1.0);
    (dq, dk, dv)
}

/// Full layer backward: from dL/dy to every parameter gradient plus
/// dL/dx. `x` is the layer's forward input (flat bs×d).
pub(super) fn layer_backward(
    p: &LayerParams,
    x: &[f32],
    cache: &LayerCache,
    dy: &[f32],
) -> Result<LayerGrads> {
    let Dims { b, s, d, di, .. } = cache.dims;
    let bs = b * s;
    ensure!(dy.len() == bs * d && x.len() == bs * d, "layer_backward size mismatch");
    let ln1 = p.ln1.f32s()?;
    let ln2 = p.ln2.f32s()?;
    let wv = p.v.f32s()?;
    let wo = p.o.f32s()?;
    let wup = p.up.f32s()?;
    let wdown = p.down.f32s()?;

    let ad_q = p.adapter.as_ref().and_then(|a| a.q.as_ref());
    let ad_k = p.adapter.as_ref().and_then(|a| a.k.as_ref());
    let ad_g = p.adapter.as_ref().and_then(|a| a.gate.as_ref());

    // FFN: y = x2 + (silu(g) ⊙ up)·Wdown.
    let dact = matmul_nt(dy, wdown, bs, d, di);
    let ddown = matmul_tn(&cache.act, dy, bs, di, d);
    let mut dg = vec![0.0f32; bs * di];
    let mut dup = vec![0.0f32; bs * di];
    for i in 0..bs * di {
        dg[i] = dact[i] * cache.up[i] * silu_grad(cache.g[i]);
        dup[i] = dact[i] * silu(cache.g[i]);
    }
    let (gate_grad, gate_ad, mut dh2) =
        proj_backward(&cache.h2, bs, &dg, &p.gate, cache.gc.as_ref(), ad_g, cache.ga.as_ref())?;
    let dup_w = matmul_tn(&cache.h2, &dup, bs, d, di);
    add_inplace(&mut dh2, &matmul_nt(&dup, wup, bs, di, d));
    let (mut dx2, dln2) = rmsnorm_bwd(&dh2, &cache.x2, ln2, &cache.inv2, bs, d);
    add_inplace(&mut dx2, dy); // residual: y = x2 + ffn

    // Attention: x2 = x + att·Wo.
    let datt = matmul_nt(&dx2, wo, bs, d, d);
    let do_w = matmul_tn(&cache.att, &dx2, bs, d, d);
    let (dq, dk, dv) = attention_bwd(&datt, &cache.q, &cache.k, &cache.v, &cache.probs, cache.dims);
    let (q_grad, q_ad, mut dh1) =
        proj_backward(&cache.h1, bs, &dq, &p.q, cache.qc.as_ref(), ad_q, cache.qa.as_ref())?;
    let (k_grad, k_ad, dh1_k) =
        proj_backward(&cache.h1, bs, &dk, &p.k, cache.kc.as_ref(), ad_k, cache.ka.as_ref())?;
    add_inplace(&mut dh1, &dh1_k);
    let dv_w = matmul_tn(&cache.h1, &dv, bs, d, d);
    add_inplace(&mut dh1, &matmul_nt(&dv, wv, bs, d, d));
    let (mut dx, dln1) = rmsnorm_bwd(&dh1, x, ln1, &cache.inv1, bs, d);
    add_inplace(&mut dx, &dx2); // residual: x2 = x + attn

    Ok(LayerGrads {
        ln1: dln1,
        ln2: dln2,
        q: q_grad,
        k: k_grad,
        v: dv_w,
        o: do_w,
        gate: gate_grad,
        up: dup_w,
        down: ddown,
        q_ad,
        k_ad,
        gate_ad,
        dx,
    })
}

fn adam_kernel(p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], lr: f32, t: f32) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let bc1 = 1.0 - B1.powf(t);
    let bc2 = 1.0 - B2.powf(t);
    for i in 0..p.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        p[i] -= lr * mh / (vh.sqrt() + EPS);
    }
}

/// Adam-update `store[name]` from `g`, with moments in `opt` under
/// `{mkey}`/`{vkey}` (zero-initialized on first touch).
pub(super) fn adam_update(
    store: &mut TensorStore,
    opt: &mut TensorStore,
    name: &str,
    mkey: String,
    vkey: String,
    g: &[f32],
    lr: f32,
    t: f32,
) -> Result<()> {
    let shape = store.get(name)?.shape.clone();
    ensure!(
        shape.iter().product::<usize>() == g.len(),
        "gradient size mismatch for '{name}'"
    );
    let mut m_t = opt.remove(&mkey).unwrap_or_else(|| Tensor::zeros(&shape));
    let mut v_t = opt.remove(&vkey).unwrap_or_else(|| Tensor::zeros(&shape));
    adam_kernel(
        store.get_mut(name)?.f32s_mut()?,
        g,
        m_t.f32s_mut()?,
        v_t.f32s_mut()?,
        lr,
        t,
    );
    opt.insert(mkey, m_t);
    opt.insert(vkey, v_t);
    Ok(())
}

pub(super) fn dense_layer_params(store: &TensorStore, l: usize) -> Result<LayerParams<'_>> {
    Ok(LayerParams {
        ln1: store.get(&format!("L{l}.ln1"))?,
        ln2: store.get(&format!("L{l}.ln2"))?,
        q: Proj::Dense(store.get(&format!("L{l}.w_q"))?),
        k: Proj::Dense(store.get(&format!("L{l}.w_k"))?),
        gate: Proj::Dense(store.get(&format!("L{l}.w_gate"))?),
        v: store.get(&format!("L{l}.w_v"))?,
        o: store.get(&format!("L{l}.w_o"))?,
        up: store.get(&format!("L{l}.w_up"))?,
        down: store.get(&format!("L{l}.w_down"))?,
        adapter: None,
    })
}

/// One projection from a (possibly cured) student store: cured iff its C
/// factor is present; `U = U₀ + ΔU` merged host-side.
fn student_proj<'a>(store: &'a TensorStore, l: usize, name: &str) -> Result<Proj<'a>> {
    if store.contains(&format!("L{l}.c_{name}")) {
        let mut u = store.get(&format!("L{l}.u_{name}"))?.clone();
        if let Ok(du) = store.get(&format!("L{l}.du_{name}")) {
            let us = u.f32s_mut()?;
            for (a, b) in us.iter_mut().zip(du.f32s()?) {
                *a += *b;
            }
        }
        Ok(Proj::Cured {
            c: store.get(&format!("L{l}.c_{name}"))?,
            u: Cow::Owned(u),
            r: store.get(&format!("L{l}.r_{name}"))?,
        })
    } else {
        Ok(Proj::Dense(store.get(&format!("L{l}.w_{name}"))?))
    }
}

/// Layer params from a (possibly cured) student store.
pub(super) fn student_layer_params(store: &TensorStore, l: usize) -> Result<LayerParams<'_>> {
    Ok(LayerParams {
        ln1: store.get(&format!("L{l}.ln1"))?,
        ln2: store.get(&format!("L{l}.ln2"))?,
        q: student_proj(store, l, "q")?,
        k: student_proj(store, l, "k")?,
        gate: student_proj(store, l, "gate")?,
        v: store.get(&format!("L{l}.w_v"))?,
        o: store.get(&format!("L{l}.w_o"))?,
        up: store.get(&format!("L{l}.w_up"))?,
        down: store.get(&format!("L{l}.w_down"))?,
        adapter: None,
    })
}

/// One Adam pretraining step on the dense model. Cross-entropy over all
/// positions, mean-reduced; returns the batch loss.
pub(super) fn train_step_impl(
    cfg: &ModelConfig,
    store: &mut TensorStore,
    opt: &mut TensorStore,
    tokens: &Tensor,
    targets: &Tensor,
    lr: f32,
    t: f32,
) -> Result<f64> {
    ensure!(tokens.shape.len() == 2, "tokens must be (b, s)");
    ensure!(targets.shape == tokens.shape, "targets shape mismatch");
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    let bs = b * s;
    let (d, nl) = (cfg.d_model, cfg.n_layers);
    let toks = tokens.i32s()?;
    let tgts = targets.i32s()?;

    // Forward with caches. Gradients are accumulated by parameter name,
    // Adam runs after every borrow of the store is released.
    let mut grads: HashMap<String, Vec<f32>> = HashMap::new();
    let loss = {
        let emb_t = store.get("emb")?;
        ensure!(
            emb_t.shape.len() == 2 && emb_t.shape[1] == d,
            "emb must be (vocab, {d}), got {:?}",
            emb_t.shape
        );
        let vocab = emb_t.shape[0];
        let emb = emb_t.f32s()?;
        let mut x0 = vec![0.0f32; bs * d];
        embed_gather(emb, vocab, d, toks, &mut x0)?;
        // Layer l's input is x0 for l=0, else the previous cache's `y`
        // (no clones — the caches already hold every activation needed).
        let mut caches: Vec<LayerCache> = Vec::with_capacity(nl);
        for l in 0..nl {
            let p = dense_layer_params(store, l)?;
            let dims = layer_dims(cfg.n_heads, &p, b, s, d)?;
            let x_in: &[f32] = if l == 0 { &x0 } else { &caches[l - 1].y };
            let cache = layer_forward_cached(dims, &p, x_in)?;
            caches.push(cache);
        }
        let x_final: &[f32] = if nl == 0 { &x0 } else { &caches[nl - 1].y };
        let ln_f = want(store.get("ln_f")?, &[d], "ln_f")?;
        let (logits, xf, invf) = head_forward(x_final, ln_f, emb, bs, d, vocab);

        // Cross-entropy + dlogits.
        let mut dlogits = vec![0.0f32; bs * vocab];
        let mut loss_sum = 0.0f64;
        let inv_bs = 1.0 / bs as f32;
        for r in 0..bs {
            let tk = tgts[r];
            ensure!((0..vocab as i32).contains(&tk), "target {tk} out of vocab 0..{vocab}");
            let row = &logits[r * vocab..(r + 1) * vocab];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let sum: f64 = row.iter().map(|&z| ((z - maxv) as f64).exp()).sum();
            loss_sum += maxv as f64 + sum.ln() - row[tk as usize] as f64;
            let drow = &mut dlogits[r * vocab..(r + 1) * vocab];
            for j in 0..vocab {
                let p_j = (((row[j] - maxv) as f64).exp() / sum) as f32;
                drow[j] = (p_j - if j == tk as usize { 1.0 } else { 0.0 }) * inv_bs;
            }
        }
        let loss = loss_sum / bs as f64;

        // Head backward (tied embedding: head grad + gather grad add up).
        let mut demb = matmul_tn(&dlogits, &xf, bs, vocab, d);
        let dxf = matmul_nn(&dlogits, emb, bs, vocab, d);
        let (mut dx, dlnf) = rmsnorm_bwd(&dxf, x_final, ln_f, &invf, bs, d);
        grads.insert("ln_f".to_string(), dlnf);

        for l in (0..nl).rev() {
            let p = dense_layer_params(store, l)?;
            let x_in: &[f32] = if l == 0 { &x0 } else { &caches[l - 1].y };
            let g = layer_backward(&p, x_in, &caches[l], &dx)?;
            dx = g.dx;
            let dense = |pg: ProjGrad| -> Result<Vec<f32>> {
                match pg {
                    ProjGrad::Dense(gw) => Ok(gw),
                    ProjGrad::CuredU(_) => bail!("train_step requires a dense store"),
                }
            };
            grads.insert(format!("L{l}.ln1"), g.ln1);
            grads.insert(format!("L{l}.ln2"), g.ln2);
            grads.insert(format!("L{l}.w_q"), dense(g.q)?);
            grads.insert(format!("L{l}.w_k"), dense(g.k)?);
            grads.insert(format!("L{l}.w_gate"), dense(g.gate)?);
            grads.insert(format!("L{l}.w_v"), g.v);
            grads.insert(format!("L{l}.w_o"), g.o);
            grads.insert(format!("L{l}.w_up"), g.up);
            grads.insert(format!("L{l}.w_down"), g.down);
        }
        // Embedding gather backward.
        for (r, &tk) in toks.iter().enumerate() {
            let base = tk as usize * d;
            for j in 0..d {
                demb[base + j] += dx[r * d + j];
            }
        }
        grads.insert("emb".to_string(), demb);
        loss
    };

    for name in cfg.dense_param_names() {
        let g = grads
            .remove(&name)
            .ok_or_else(|| anyhow!("missing gradient for parameter '{name}'"))?;
        adam_update(store, opt, &name, format!("m.{name}"), format!("v.{name}"), &g, lr, t)?;
    }
    Ok(loss)
}

/// Heal loss + ΔU gradients of one layer (shared by the step and tests):
/// returns (MSE loss, student layer output, per-projection ΔU grads).
pub(super) fn heal_grads(
    n_heads: usize,
    p: &LayerParams,
    b: usize,
    s: usize,
    d: usize,
    x: &[f32],
    y_teacher: &[f32],
) -> Result<(f64, Vec<f32>, Vec<(&'static str, Vec<f32>)>)> {
    let dims = layer_dims(n_heads, p, b, s, d)?;
    let cache = layer_forward_cached(dims, p, x)?;
    let n = cache.y.len();
    ensure!(y_teacher.len() == n, "teacher output size mismatch");
    let mut dy = vec![0.0f32; n];
    let mut loss = 0.0f64;
    let inv_n = 1.0 / n as f32;
    for i in 0..n {
        let diff = cache.y[i] - y_teacher[i];
        loss += (diff as f64) * (diff as f64);
        dy[i] = 2.0 * diff * inv_n;
    }
    loss /= n as f64;
    let g = layer_backward(p, x, &cache, &dy)?;
    let mut dus: Vec<(&'static str, Vec<f32>)> = Vec::new();
    for (name, pg) in [("q", g.q), ("k", g.k), ("gate", g.gate)] {
        if let ProjGrad::CuredU(du) = pg {
            dus.push((name, du));
        }
    }
    Ok((loss, cache.y, dus))
}

/// One layer-wise KD healing step (Adam on ΔU of layer `layer`).
pub(super) fn heal_step_impl(
    cfg: &ModelConfig,
    student: &mut TensorStore,
    opt: &mut TensorStore,
    layer: usize,
    x: &Tensor,
    y_teacher: &Tensor,
    lr: f32,
    t: f32,
) -> Result<HealOut> {
    ensure!(x.shape.len() == 3, "heal input must be (b, s, d)");
    let (b, s, d) = (x.shape[0], x.shape[1], x.shape[2]);
    let (loss, y_vec, dus) = {
        let p = student_layer_params(student, layer)?;
        ensure!(
            p.q.is_cured() || p.k.is_cured() || p.gate.is_cured(),
            "layer {layer} has no cured projections to heal"
        );
        heal_grads(cfg.n_heads, &p, b, s, d, x.f32s()?, y_teacher.f32s()?)?
    };
    for (proj, gdu) in dus {
        let name = format!("L{layer}.du_{proj}");
        if !student.contains(&name) {
            // ΔU is created at compression time; a store without it is
            // malformed rather than silently skippable.
            bail!("student store missing '{name}'");
        }
        adam_update(
            student,
            opt,
            &name,
            format!("heal.L{layer}.m.du_{proj}"),
            format!("heal.L{layer}.v.du_{proj}"),
            &gdu,
            lr,
            t,
        )?;
    }
    Ok(HealOut { loss, y_student: Tensor::from_f32(&x.shape, y_vec) })
}
